#!/usr/bin/env python3
"""Schema gate for the committed bench reports.

Both reports are committed PR-over-PR (pending or measured) and consumed
by regression gates, so they must stay machine-readable in both states.
The ``bench`` field dispatches the per-kind rules:

``hot_paths`` (BENCH_hotpaths.json):

    {"bench": "hot_paths", "unit": "ns_per_call",
     "status": "measured" | "pending-first-run",
     "rows": [{"name": str, "mean": num, "median": num,
               "p95": num, "reps": int, "unit"?: str}, ...]}

A row-level "unit" overrides the report-level one for metric rows that are
not timings (e.g. the batched fan-out's "reads_per_update" rows at batch
1/4/16, or the sparse-payload pipeline's "bytes_per_update" /
"nnz_per_oracle" rows, where mean == median == p95 == the measured value).
Metric units are validated against a closed set so a typo'd unit cannot
slip past the perf regression gate unnoticed.

A measured report must carry the sparse-payload dense-vs-sparse row pairs
(bytes-per-update and fused-apply throughput), which back the payload
pipeline's acceptance criterion.

``robustness`` (BENCH_robustness.json, written by
scripts/replay_fig3.sh — EXPERIMENTS.md §Crash-recovery):

    {"bench": "robustness", "unit": "fig3_replay",
     "status": "measured" | "pending-first-run", "seed": int,
     "rows": [{"name": "fig3 gfl pareto_mean=M", "pareto_mean": num,
               "mean_delay": num, "delay_max": int,
               "final_gap": num, "secs_per_pass": num},
              ...,
              {"name": "crash-recovery gfl crash:K checkpoint_every=N",
               "crash_k": int, "checkpoint_every": int,
               "checkpoints_written": int, "restores": int,
               "stale_fenced": int, "final_gap": num,
               "secs_per_pass": num}]}

A measured robustness report must carry the full Pareto sweep (means
0/1/2/5/10/20) plus the crash-recovery point, and the crash point must
have actually exercised the restore path (``restores >= 1``).

Exit code 0 iff the file conforms. Usage:
    python3 scripts/check_bench_schema.py [path]
"""

import json
import sys

# Closed set of per-row metric units (timing rows inherit ns_per_call).
KNOWN_ROW_UNITS = {
    "reads_per_update",
    "bytes_per_update",
    "bytes_per_oracle",
    "nnz_per_oracle",
    "updates_per_sec",
    "bytes_per_pull",
}

# Row-name pairs a *measured* hot_paths report must contain: the
# dense-vs-sparse payload comparison emitted by benches/hot_paths.rs —
# both the in-process channel estimate and the distributed transport's
# real wire measurement (loopback serve+worker through the TCP codec).
REQUIRED_MEASURED_PREFIXES = [
    "async bytes-per-update payload=dense",
    "async bytes-per-update payload=sparse",
    "ssvm apply fused batch=8 dense",
    "ssvm apply fused batch=8 sparse",
    "net loopback wire bytes-per-update payload=dense",
    "net loopback wire bytes-per-update payload=sparse",
    # The wire-v4 encoding sweep: shipped (post-quantization) update
    # bytes under each `run.wire` mode — exact is the v3 baseline the
    # f16/q8 savings are measured against.
    "net loopback wire bytes-per-update wire=exact",
    "net loopback wire bytes-per-update wire=f16",
    "net loopback wire bytes-per-update wire=q8",
    # The sharded parameter plane's scaling rows: update throughput at
    # S = 1/2/4 and the snapshot fan-out cost at S = 1/2.
    "net sharded updates-per-sec shards=1",
    "net sharded updates-per-sec shards=2",
    "net sharded updates-per-sec shards=4",
    "snapshot fan-out bytes-per-pull shards=1",
    "snapshot fan-out bytes-per-pull shards=2",
    # The delay-adaptive stepping rows: apply throughput with the kappa
    # damping on vs the pinned off default — the visibility gate for any
    # control-plane overhead.
    "async updates-per-sec adapt=off",
    "async updates-per-sec adapt=kappa",
]

# The injected Pareto means a *measured* robustness report must sweep
# (the Fig 3 replay x-axis), plus one crash-recovery point.
ROBUSTNESS_SWEEP_MEANS = (0, 1, 2, 5, 10, 20)


def check_hot_paths(doc: dict) -> None:
    assert doc["unit"] == "ns_per_call", f"unit: {doc['unit']!r}"
    for row in doc["rows"]:
        for key in ("name", "mean", "median", "p95", "reps"):
            assert key in row, f"row missing {key}: {row}"
        assert isinstance(row["name"], str), row
        for key in ("mean", "median", "p95"):
            assert isinstance(row[key], (int, float)), row
        assert isinstance(row["reps"], int), row
        if "unit" in row:
            assert row["unit"] in KNOWN_ROW_UNITS, (
                f"unknown row unit {row['unit']!r} "
                f"(known: {sorted(KNOWN_ROW_UNITS)}): {row}"
            )
    if doc["status"] == "measured":
        assert doc["rows"], "measured report must carry rows"
        names = [row["name"] for row in doc["rows"]]
        for prefix in REQUIRED_MEASURED_PREFIXES:
            assert any(n.startswith(prefix) for n in names), (
                f"measured report missing dense-vs-sparse row {prefix!r}"
            )


def check_robustness(doc: dict) -> None:
    assert doc["unit"] == "fig3_replay", f"unit: {doc['unit']!r}"
    assert isinstance(doc.get("seed"), int), "missing/bad seed"
    for row in doc["rows"]:
        assert isinstance(row.get("name"), str), f"row missing name: {row}"
        for key in ("final_gap", "secs_per_pass"):
            assert isinstance(row.get(key), (int, float)), (
                f"row missing numeric {key}: {row}"
            )
        if row["name"].startswith("fig3 "):
            for key in ("pareto_mean", "mean_delay", "delay_max"):
                assert isinstance(row.get(key), (int, float)), (
                    f"sweep row missing numeric {key}: {row}"
                )
        elif row["name"].startswith("crash-recovery "):
            for key in (
                "crash_k",
                "checkpoint_every",
                "checkpoints_written",
                "restores",
                "stale_fenced",
            ):
                assert isinstance(row.get(key), int), (
                    f"crash row missing integer {key}: {row}"
                )
        else:
            raise AssertionError(
                f"unknown robustness row kind: {row['name']!r}"
            )
    if doc["status"] == "measured":
        assert doc["rows"], "measured report must carry rows"
        names = [row["name"] for row in doc["rows"]]
        for mean in ROBUSTNESS_SWEEP_MEANS:
            needle = f"fig3 gfl pareto_mean={mean}"
            assert needle in names, (
                f"measured report missing sweep row {needle!r}"
            )
        crash = [
            row
            for row in doc["rows"]
            if row["name"].startswith("crash-recovery ")
        ]
        assert crash, "measured report missing the crash-recovery point"
        for row in crash:
            assert row["restores"] >= 1, (
                f"crash-recovery point never restored: {row}"
            )


def check(path: str) -> str:
    with open(path) as f:
        doc = json.load(f)
    for key in ("bench", "unit", "status", "rows"):
        assert key in doc, f"missing key: {key}"
    assert doc["status"] in ("measured", "pending-first-run"), doc["status"]
    assert isinstance(doc["rows"], list), "rows must be a list"
    if doc["bench"] == "hot_paths":
        check_hot_paths(doc)
    elif doc["bench"] == "robustness":
        check_robustness(doc)
    else:
        raise AssertionError(f"bench: {doc['bench']!r}")
    return (
        f"{path} OK ({doc['bench']}, {doc['status']}, "
        f"{len(doc['rows'])} rows)"
    )


if __name__ == "__main__":
    target = sys.argv[1] if len(sys.argv) > 1 else "BENCH_hotpaths.json"
    try:
        print(check(target))
    except (AssertionError, json.JSONDecodeError, OSError) as e:
        print(f"schema check FAILED for {target}: {e}", file=sys.stderr)
        sys.exit(1)
