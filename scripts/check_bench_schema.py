#!/usr/bin/env python3
"""Schema gate for BENCH_hotpaths.json.

The file is committed PR-over-PR (pending or measured) and consumed by the
perf regression gate, so it must stay machine-readable in both states:

    {"bench": "hot_paths", "unit": "ns_per_call",
     "status": "measured" | "pending-first-run",
     "rows": [{"name": str, "mean": num, "median": num,
               "p95": num, "reps": int, "unit"?: str}, ...]}

A row-level "unit" overrides the report-level one for metric rows that are
not timings (e.g. the batched fan-out's "reads_per_update" rows at batch
1/4/16, or the sparse-payload pipeline's "bytes_per_update" /
"nnz_per_oracle" rows, where mean == median == p95 == the measured value).
Metric units are validated against a closed set so a typo'd unit cannot
slip past the perf regression gate unnoticed.

A measured report must carry the sparse-payload dense-vs-sparse row pairs
(bytes-per-update and fused-apply throughput), which back the payload
pipeline's acceptance criterion.

Exit code 0 iff the file conforms. Usage:
    python3 scripts/check_bench_schema.py [path]
"""

import json
import sys

# Closed set of per-row metric units (timing rows inherit ns_per_call).
KNOWN_ROW_UNITS = {
    "reads_per_update",
    "bytes_per_update",
    "bytes_per_oracle",
    "nnz_per_oracle",
    "updates_per_sec",
    "bytes_per_pull",
}

# Row-name pairs a *measured* report must contain: the dense-vs-sparse
# payload comparison emitted by benches/hot_paths.rs — both the
# in-process channel estimate and the distributed transport's real wire
# measurement (loopback serve+worker through the TCP codec).
REQUIRED_MEASURED_PREFIXES = [
    "async bytes-per-update payload=dense",
    "async bytes-per-update payload=sparse",
    "ssvm apply fused batch=8 dense",
    "ssvm apply fused batch=8 sparse",
    "net loopback wire bytes-per-update payload=dense",
    "net loopback wire bytes-per-update payload=sparse",
    # The wire-v4 encoding sweep: shipped (post-quantization) update
    # bytes under each `run.wire` mode — exact is the v3 baseline the
    # f16/q8 savings are measured against.
    "net loopback wire bytes-per-update wire=exact",
    "net loopback wire bytes-per-update wire=f16",
    "net loopback wire bytes-per-update wire=q8",
    # The sharded parameter plane's scaling rows: update throughput at
    # S = 1/2/4 and the snapshot fan-out cost at S = 1/2.
    "net sharded updates-per-sec shards=1",
    "net sharded updates-per-sec shards=2",
    "net sharded updates-per-sec shards=4",
    "snapshot fan-out bytes-per-pull shards=1",
    "snapshot fan-out bytes-per-pull shards=2",
]


def check(path: str) -> str:
    with open(path) as f:
        doc = json.load(f)
    for key in ("bench", "unit", "status", "rows"):
        assert key in doc, f"missing key: {key}"
    assert doc["bench"] == "hot_paths", f"bench: {doc['bench']!r}"
    assert doc["unit"] == "ns_per_call", f"unit: {doc['unit']!r}"
    assert doc["status"] in ("measured", "pending-first-run"), doc["status"]
    assert isinstance(doc["rows"], list), "rows must be a list"
    for row in doc["rows"]:
        for key in ("name", "mean", "median", "p95", "reps"):
            assert key in row, f"row missing {key}: {row}"
        assert isinstance(row["name"], str), row
        for key in ("mean", "median", "p95"):
            assert isinstance(row[key], (int, float)), row
        assert isinstance(row["reps"], int), row
        if "unit" in row:
            assert row["unit"] in KNOWN_ROW_UNITS, (
                f"unknown row unit {row['unit']!r} "
                f"(known: {sorted(KNOWN_ROW_UNITS)}): {row}"
            )
    if doc["status"] == "measured":
        assert doc["rows"], "measured report must carry rows"
        names = [row["name"] for row in doc["rows"]]
        for prefix in REQUIRED_MEASURED_PREFIXES:
            assert any(n.startswith(prefix) for n in names), (
                f"measured report missing dense-vs-sparse row {prefix!r}"
            )
    return f"{path} OK ({doc['status']}, {len(doc['rows'])} rows)"


if __name__ == "__main__":
    target = sys.argv[1] if len(sys.argv) > 1 else "BENCH_hotpaths.json"
    try:
        print(check(target))
    except (AssertionError, json.JSONDecodeError, OSError) as e:
        print(f"schema check FAILED for {target}: {e}", file=sys.stderr)
        sys.exit(1)
