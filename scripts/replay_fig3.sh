#!/usr/bin/env bash
# Measured Fig 3 replay sweep (EXPERIMENTS.md §Crash-recovery).
#
# Sweeps the injected Pareto mean stall over self-hosted 4-worker GFL
# fleets (run.chaos=delay:pareto:M:0.5), records the measured delay
# telemetry (the empirical expected-delay kappa) against convergence,
# adds one crash-recovery point (run.chaos=crash:K with durable
# checkpoints — the drill must report restores >= 1), and writes
# BENCH_robustness.json at the repo root. The committed copy is gated by
# scripts/check_bench_schema.py in both its pending and measured states.
#
# Usage (from the repo root, after `cargo build --release`):
#     scripts/replay_fig3.sh
# Env overrides: BIN, OUT, SEED, MEANS, CRASH_K, CKPT_EVERY.
set -eu

BIN="${BIN:-./target/release/apbcfw}"
OUT="${OUT:-BENCH_robustness.json}"
SEED="${SEED:-3}"
MEANS="${MEANS:-0 1 2 5 10 20}"
CRASH_K="${CRASH_K:-45}"
CKPT_EVERY="${CKPT_EVERY:-10}"

# Paper-shaped but CI-sized: the sweep's signal is the *relative*
# degradation across injected means, not absolute wall clock.
SMALL="--set gfl.d=4 --set gfl.n=20 --set run.max_secs=60"
COMMON="--self-host --workers 4 --tau 4 --epochs 20 --seed $SEED"

log=$(mktemp)
ckdir=$(mktemp -d)
trap 'rm -f "$log"; rm -rf "$ckdir"' EXIT

# Field extractors over a captured solve summary (`summarize` in
# rust/src/main.rs). tail -n1: the summary prints once, after any
# restart-loop log lines.
gap_of()   { sed -n 's/.*gap=\([0-9.eE+-]*\) t=.*/\1/p' "$1" | tail -n1; }
spp_of()   { sed -n 's|.*secs/pass=\([0-9.eE+-]*\).*|\1|p' "$1" | tail -n1; }
dmean_of() { sed -n 's/.*delay: mean \([0-9.eE+-]*\),.*/\1/p' "$1" | tail -n1; }
dmax_of()  { sed -n 's/.*delay: mean .* max \([0-9]*\).*/\1/p' "$1" | tail -n1; }
rec_of()   { sed -n "s/.*recovery: .*$2=\([0-9]*\).*/\1/p" "$1" | tail -n1; }

require() { # require VALUE LABEL — a missing field means the parse broke
  [ -n "$1" ] || { echo "replay_fig3: missing $2 in solve summary" >&2
                   cat "$log" >&2; exit 1; }
}

nl='
'
rows=""
sep=""

for mean in $MEANS; do
  echo "[replay_fig3] pareto mean ${mean} ms (p=0.5)" >&2
  # shellcheck disable=SC2086
  "$BIN" serve gfl $COMMON $SMALL \
         --set "run.chaos=delay:pareto:${mean}:0.5" >"$log" 2>&1 \
    || { cat "$log" >&2; exit 1; }
  cat "$log" >&2
  gap=$(gap_of "$log"); spp=$(spp_of "$log")
  dmean=$(dmean_of "$log"); dmax=$(dmax_of "$log")
  require "$gap" final_gap; require "$spp" secs_per_pass
  require "$dmean" mean_delay; require "$dmax" delay_max
  rows="${rows}${sep}    {\"name\": \"fig3 gfl pareto_mean=${mean}\", \
\"pareto_mean\": ${mean}, \"mean_delay\": ${dmean}, \"delay_max\": ${dmax}, \
\"final_gap\": ${gap}, \"secs_per_pass\": ${spp}}"
  sep=",$nl"
done

echo "[replay_fig3] crash drill: crash:${CRASH_K}, checkpoint_every=${CKPT_EVERY}" >&2
# shellcheck disable=SC2086
"$BIN" serve gfl $COMMON $SMALL \
       --checkpoint-dir "$ckdir" --checkpoint-every "$CKPT_EVERY" \
       --set "run.chaos=crash:${CRASH_K}" >"$log" 2>&1 \
  || { cat "$log" >&2; exit 1; }
cat "$log" >&2
gap=$(gap_of "$log"); spp=$(spp_of "$log")
written=$(rec_of "$log" checkpoints_written)
restores=$(rec_of "$log" restores)
fenced=$(rec_of "$log" stale_fenced)
require "$gap" final_gap; require "$spp" secs_per_pass
require "$written" checkpoints_written
require "$restores" restores
require "$fenced" stale_fenced
if [ "$restores" -lt 1 ]; then
  echo "replay_fig3: crash drill reported restores=${restores} (< 1)" >&2
  exit 1
fi
rows="${rows}${sep}    {\"name\": \
\"crash-recovery gfl crash:${CRASH_K} checkpoint_every=${CKPT_EVERY}\", \
\"crash_k\": ${CRASH_K}, \"checkpoint_every\": ${CKPT_EVERY}, \
\"checkpoints_written\": ${written}, \"restores\": ${restores}, \
\"stale_fenced\": ${fenced}, \"final_gap\": ${gap}, \
\"secs_per_pass\": ${spp}}"

cat > "$OUT" <<EOF
{
  "bench": "robustness",
  "unit": "fig3_replay",
  "status": "measured",
  "seed": ${SEED},
  "rows": [
${rows}
  ]
}
EOF

echo "[replay_fig3] wrote ${OUT}" >&2
