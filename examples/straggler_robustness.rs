//! Straggler robustness demo (paper §3.3 / Fig 3): run AP-BCFW and SP-BCFW
//! against an increasingly slow straggler and print the time per effective
//! data pass — async stays flat, sync degrades linearly.
//!
//! ```bash
//! cargo run --release --example straggler_robustness
//! ```

use apbcfw::data::ocr_like;
use apbcfw::problems::ssvm::chain::ChainSsvm;
use apbcfw::run::{Engine, Runner, RunSpec, StragglerSpec};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let data = Arc::new(ocr_like::generate(200, 26, 128, 9, 0.15, 99));
    let problem = ChainSsvm::new(data, 1.0);
    let workers = 4;
    let passes = 8.0;

    println!("T={workers} workers, tau={workers}, {passes} data passes");
    println!("{:<12} {:>14} {:>14}", "straggler", "async s/pass", "sync s/pass");
    let mut base: Option<(f64, f64)> = None;
    for &p in &[1.0, 0.25, 0.1] {
        // Same knobs, two engines; the straggler model's arity is derived
        // from the engine's worker count by the spec builder.
        let spec = |engine: Engine| {
            RunSpec::new(engine.with_straggler(StragglerSpec::Single { p }))
                .tau(workers)
                .line_search(true)
                .sample_every(64)
                .max_epochs(passes)
                .max_secs(120.0)
                .seed(5)
        };
        let ra = Runner::new(spec(Engine::asynchronous(workers)))?
            .solve_problem(&problem)?;
        let rs = Runner::new(spec(Engine::synchronous(workers)))?
            .solve_problem(&problem)?;
        if base.is_none() {
            base = Some((ra.secs_per_pass, rs.secs_per_pass));
        }
        let (ba, bs) = base.unwrap();
        println!(
            "p = {p:<8} {:>10.3} ({:>4.2}x) {:>8.3} ({:>4.2}x)",
            ra.secs_per_pass,
            ra.secs_per_pass / ba,
            rs.secs_per_pass,
            rs.secs_per_pass / bs,
        );
    }
    println!(
        "\nasync tracks the *average* worker speed; sync is gated on the slowest\n(paper Fig 3a; on a single-core container the contrast is attenuated\nbecause dropped async solves also consume the shared CPU)."
    );
    Ok(())
}
