//! Group Fused Lasso signal recovery (paper Fig 5): generate a
//! piecewise-constant multivariate signal, denoise it by solving the GFL
//! dual with AP-BCFW, and report change-point detection quality.
//!
//! ```bash
//! cargo run --release --example gfl_signal_recovery
//! ```

use apbcfw::data::signal;
use apbcfw::problems::gfl::Gfl;
use apbcfw::run::{Engine, Runner, RunSpec};
use apbcfw::util::la;

fn main() -> anyhow::Result<()> {
    let (d, n) = (10, 120);
    let sig = signal::piecewise_constant(d, n, 6, 3.0, 0.8, 7);

    // Sweep lambda: small = under-smoothed, large = over-smoothed.
    println!("lambda    dual f     primal P   rec.MSE   change-points");
    for &lam in &[0.5, 1.0, 2.0, 4.0, 8.0, 12.0] {
        let p = Gfl::new(d, n, lam, sig.noisy.clone());
        let spec = RunSpec::new(Engine::sequential())
            .tau(8)
            .line_search(true)
            .sample_every(64)
            .max_epochs(1500.0)
            .max_secs(30.0)
            .seed(3);
        let r = Runner::new(spec)?.solve_problem(&p)?;
        let x = p.primal_signal(&r.raw_param);
        let mse = x
            .iter()
            .zip(&sig.clean)
            .map(|(v, c)| ((v - c) as f64).powi(2))
            .sum::<f64>()
            / (d * n) as f64;
        // detected change points: ||x_{t+1} - x_t|| above a small threshold
        let mut detected = vec![];
        for t in 0..n - 1 {
            let jump: Vec<f32> = (0..d)
                .map(|r| x[(t + 1) * d + r] - x[t * d + r])
                .collect();
            if la::norm2(&jump) > 0.3 {
                detected.push(t + 1);
            }
        }
        println!(
            "{lam:<8} {:>9.4} {:>10.4} {:>9.4}   {} detected / {} true",
            r.trace.last().unwrap().objective,
            p.primal_objective(&r.raw_param),
            mse,
            detected.len(),
            sig.change_points.len(),
        );
    }
    println!(
        "\ntrue change points: {:?}\n(noisy MSE = {:.4})",
        sig.change_points,
        sig.noisy
            .iter()
            .zip(&sig.clean)
            .map(|(v, c)| ((v - c) as f64).powi(2))
            .sum::<f64>()
            / (d * n) as f64
    );
    Ok(())
}
