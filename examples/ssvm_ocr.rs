//! End-to-end driver (DESIGN.md §5): train a structural SVM sequence
//! labeler on the OCR-like dataset with the full AP-BCFW stack — multiple
//! asynchronous workers, minibatch server, line search — logging the dual
//! objective, duality-gap estimate and Hamming error as the epoch budget
//! grows. When AOT artifacts are present, the loss-augmented Viterbi oracle
//! runs through the XLA-compiled Pallas kernel; otherwise the native rust
//! DP (same numerics, cross-validated in rust/tests/xla_integration.rs).
//!
//! ```bash
//! make artifacts && cargo run --release --example ssvm_ocr
//! ```

use apbcfw::data::ocr_like::{self, ChainDataset};
use apbcfw::problems::ssvm::chain::ChainSsvm;
use apbcfw::problems::Problem;
use apbcfw::run::{Engine, Runner, RunSpec};
use apbcfw::runtime::service;
use apbcfw::runtime::xla_backends::XlaChainDecoder;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // OCR-like task: K=26 letters, 128 pixels/letter, length-9 words
    // (the artifact shapes exported by python/compile/aot.py defaults).
    let (n_train, n_test, k, d, ell) = (1000usize, 200usize, 26, 128, 9);
    let full = ocr_like::generate(n_train + n_test, k, d, ell, 0.35, 2024);

    let train_data = Arc::new(ChainDataset {
        n: n_train,
        k,
        d,
        ell,
        features: full.features[..n_train * ell * d].to_vec(),
        labels: full.labels[..n_train * ell].to_vec(),
    });
    let test_data = Arc::new(ChainDataset {
        n: n_test,
        k,
        d,
        ell,
        features: full.features[n_train * ell * d..].to_vec(),
        labels: full.labels[n_train * ell..].to_vec(),
    });

    let lam = 0.01;
    let mut train_problem = ChainSsvm::new(train_data.clone(), lam);
    let eval_problem = ChainSsvm::new(test_data, lam); // native decode for eval

    // Prefer the AOT Pallas/XLA decoder for the training oracle.
    let artifacts = std::path::Path::new("artifacts");
    let mut backend = "native rust Viterbi";
    if artifacts.join("manifest.txt").exists() {
        match service::spawn(artifacts)
            .and_then(|h| XlaChainDecoder::new(h, train_data.clone()))
        {
            Ok(dec) => {
                train_problem = train_problem.with_decoder(Arc::new(dec));
                backend = "XLA artifact (Pallas Viterbi kernel via PJRT)";
            }
            Err(e) => println!("note: falling back to native oracle: {e}"),
        }
    }
    println!("oracle backend: {backend}");
    println!(
        "training structural SVM: n={n_train}, K={k}, d={d}, L={ell}, lambda={lam}"
    );

    let train_idx: Vec<usize> = (0..n_train).collect();
    let test_idx: Vec<usize> = (0..n_test).collect();
    let w0 = train_problem.init_param();
    println!(
        "epoch budget 0: train err {:.3}, test err {:.3} (random-init)",
        train_problem.hamming_error(&w0, &train_idx),
        eval_problem.hamming_error(&w0, &test_idx)
    );

    let workers = std::thread::available_parallelism()
        .map(|v| v.get().min(8))
        .unwrap_or(4);
    let mut total_secs = 0.0;
    for &budget in &[1.0f64, 2.0, 4.0, 8.0, 16.0] {
        let spec = RunSpec::new(Engine::asynchronous(workers))
            .tau(2 * workers)
            .line_search(true)
            .sample_every(32)
            .max_epochs(budget)
            .max_secs(300.0)
            .seed(7);
        let r = Runner::new(spec)?.solve_problem(&train_problem)?;
        total_secs += r.elapsed_s;
        let last = r.last().unwrap();
        println!(
            "epoch budget {budget:>4}: dual f = {:>10.6} | est.gap = {:>9.2e} | train err {:.3} | test err {:.3} | {:>5.1}s | {} iters, {} oracle calls, {} collisions",
            last.objective,
            last.gap,
            train_problem.hamming_error(&r.param, &train_idx),
            eval_problem.hamming_error(&r.param, &test_idx),
            r.elapsed_s,
            r.iterations(),
            r.oracle_calls(),
            r.counters.collisions,
        );
    }
    println!("total training time across budgets: {total_secs:.1}s (T={workers})");
    Ok(())
}
