//! Quickstart: solve a Group Fused Lasso instance through the unified
//! `run` API — one `RunSpec` per execution engine, one `Report` shape
//! back, and a live `Observer` watching convergence while the async solve
//! runs.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! The same specs drive the distributed mode: `apbcfw serve gfl
//! --self-host --workers 2` runs this solve with the worker fleet behind
//! the TCP wire protocol (`docs/WIRE.md`), and `apbcfw serve` / `apbcfw
//! worker` split it across machines.

use apbcfw::data::signal;
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::Problem;
use apbcfw::run::{Engine, Observer, Runner, RunSpec};
use apbcfw::util::metrics::Sample;

/// A minimal live observer: prints every 4th convergence sample as the
/// server records it (a dashboard would stream these instead).
struct LivePrinter {
    seen: usize,
}

impl Observer for LivePrinter {
    fn on_sample(&mut self, s: &Sample) {
        if self.seen % 4 == 0 {
            println!(
                "  [live] iter={:<6} f={:+.5} gap={:.2e} t={:.2}s",
                s.iter, s.objective, s.gap, s.elapsed_s
            );
        }
        self.seen += 1;
    }
}

fn main() -> anyhow::Result<()> {
    // 1. A piecewise-constant signal with shared change points + noise.
    let (d, n, lam) = (10, 100, 1.0);
    let sig = signal::piecewise_constant(d, n, 6, 2.0, 0.5, 42);
    println!(
        "signal: d={d} n={n}, {} true change points at {:?}",
        sig.change_points.len(),
        sig.change_points
    );

    // 2. The GFL dual problem (paper Eq. 10): one l2-ball block per
    //    potential change point; linear oracle = ball-boundary point.
    let problem = Gfl::new(d, n, lam, sig.noisy.clone());
    println!(
        "problem: {} blocks of dim {d}, f(0) = {}",
        problem.num_blocks(),
        problem.objective(&(), &problem.init_param())
    );

    // 3. Sequential BCFW (tau = 1) — the Lacoste-Julien et al. baseline.
    //    Engine-specific knobs live in the Engine; shared knobs on the
    //    spec builder.
    let seq_spec = RunSpec::new(Engine::sequential())
        .tau(1)
        .line_search(true)
        .sample_every(32)
        .exact_gap(true)
        .eps_gap(1e-2)
        .max_epochs(2000.0)
        .max_secs(60.0)
        .seed(1);
    let r_seq = Runner::new(seq_spec)?.solve_problem(&problem)?;
    let last = r_seq.last().unwrap();
    println!(
        "BCFW (tau=1):      f={:.5} gap={:.2e} after {:.1} epochs, {:.2}s",
        last.objective,
        last.gap,
        r_seq.epochs(problem.num_blocks()),
        last.elapsed_s
    );

    // 4. AP-BCFW: asynchronous workers + minibatch server (tau = 8,
    //    T = 4), with a live observer streaming samples mid-solve.
    let async_spec = RunSpec::new(Engine::asynchronous(4))
        .tau(8)
        .line_search(true)
        .sample_every(16)
        .exact_gap(true)
        .eps_gap(1e-2)
        .max_epochs(20_000.0)
        .max_secs(60.0)
        .seed(2);
    println!("AP-BCFW (T=4,tau=8) running with a live observer:");
    let mut live = LivePrinter { seen: 0 };
    let r_async =
        Runner::new(async_spec)?.solve_problem_observed(&problem, &mut live)?;
    let last = r_async.last().unwrap();
    println!(
        "AP-BCFW (T=4,tau=8): f={:.5} gap={:.2e} in {} server iters, {:.2}s",
        last.objective,
        last.gap,
        r_async.iterations(),
        last.elapsed_s
    );
    println!(
        "  counters: {} oracle calls, {} applied, {} collisions, {} dropped",
        r_async.oracle_calls(),
        r_async.counters.updates_applied,
        r_async.counters.collisions,
        r_async.dropped()
    );

    // 5. Recover the denoised signal from the dual iterate.
    let x = problem.primal_signal(&r_async.param);
    let mse = |a: &[f32]| {
        a.iter()
            .zip(&sig.clean)
            .map(|(v, c)| ((v - c) as f64).powi(2))
            .sum::<f64>()
            / (d * n) as f64
    };
    println!(
        "denoising: noisy MSE {:.4} -> recovered MSE {:.4}",
        mse(&sig.noisy),
        mse(&x)
    );
    Ok(())
}
