//! Quickstart: solve a Group Fused Lasso instance with AP-BCFW in three
//! execution modes and print convergence summaries.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use apbcfw::coordinator::{apbcfw as coord, RunConfig};
use apbcfw::data::signal;
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::Problem;
use apbcfw::sim::straggler::StragglerModel;
use apbcfw::solver::{minibatch, SolveOptions, StopCond};

fn main() {
    // 1. A piecewise-constant signal with shared change points + noise.
    let (d, n, lam) = (10, 100, 1.0);
    let sig = signal::piecewise_constant(d, n, 6, 2.0, 0.5, 42);
    println!(
        "signal: d={d} n={n}, {} true change points at {:?}",
        sig.change_points.len(),
        sig.change_points
    );

    // 2. The GFL dual problem (paper Eq. 10): one l2-ball block per
    //    potential change point; linear oracle = ball-boundary point.
    let problem = Gfl::new(d, n, lam, sig.noisy.clone());
    println!(
        "problem: {} blocks of dim {d}, f(0) = {}",
        problem.num_blocks(),
        problem.objective(&(), &problem.init_param())
    );

    // 3. Sequential BCFW (tau = 1) — the Lacoste-Julien et al. baseline.
    let r_seq = minibatch::solve(
        &problem,
        &SolveOptions {
            tau: 1,
            line_search: true,
            sample_every: 32,
            exact_gap: true,
            stop: StopCond {
                eps_gap: Some(1e-2),
                max_epochs: 2000.0,
                max_secs: 60.0,
                ..Default::default()
            },
            seed: 1,
            ..Default::default()
        },
    );
    let last = r_seq.trace.last().unwrap();
    println!(
        "BCFW (tau=1):      f={:.5} gap={:.2e} after {:.1} epochs, {:.2}s",
        last.objective,
        last.gap,
        last.oracle_calls as f64 / problem.num_blocks() as f64,
        last.elapsed_s
    );

    // 4. AP-BCFW: asynchronous workers + minibatch server (tau = 8, T = 4).
    let r_async = coord::run(
        &problem,
        &RunConfig {
            workers: 4,
            tau: 8,
            line_search: true,
            straggler: StragglerModel::none(4),
            sample_every: 16,
            exact_gap: true,
            stop: StopCond {
                eps_gap: Some(1e-2),
                max_epochs: 20_000.0,
                max_secs: 60.0,
                ..Default::default()
            },
            seed: 2,
            ..Default::default()
        },
    );
    let last = r_async.trace.last().unwrap();
    println!(
        "AP-BCFW (T=4,tau=8): f={:.5} gap={:.2e} in {} server iters, {:.2}s",
        last.objective, last.gap, last.iter, last.elapsed_s
    );
    println!(
        "  counters: {} oracle calls, {} applied, {} collisions, {} dropped",
        r_async.counters.oracle_calls,
        r_async.counters.updates_applied,
        r_async.counters.collisions,
        r_async.counters.dropped
    );

    // 5. Recover the denoised signal from the dual iterate.
    let x = problem.primal_signal(&r_async.param);
    let mse = |a: &[f32]| {
        a.iter()
            .zip(&sig.clean)
            .map(|(v, c)| ((v - c) as f64).powi(2))
            .sum::<f64>()
            / (d * n) as f64
    };
    println!(
        "denoising: noisy MSE {:.4} -> recovered MSE {:.4}",
        mse(&sig.noisy),
        mse(&x)
    );
}
