"""AOT exporter tests: HLO text artifacts parse-ready for the rust runtime."""

import os

import pytest

from compile import aot


TINY = dict(
    gfl_d=3, gfl_n=8,
    chain_k=4, chain_d=5, chain_l=3, chain_batches=(1, 2),
    mc_k=3, mc_d=4, mc_batches=(1,),
)


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.export_all(out, TINY)
    return out


def test_all_artifacts_emitted(exported):
    names = sorted(os.listdir(exported))
    assert "manifest.txt" in names
    hlos = [n for n in names if n.endswith(".hlo.txt")]
    # gfl_step, gfl_primal, 2 chain batches, 1 multiclass batch
    assert len(hlos) == 5


def test_hlo_text_structure(exported):
    for name in os.listdir(exported):
        if not name.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(exported, name)).read()
        assert "ENTRY" in text, name
        assert "ROOT" in text, name
        # Tuple return (return_tuple=True) so rust unwraps with to_tuple().
        assert "tuple" in text, name


def test_manifest_lines_parse(exported):
    lines = open(os.path.join(exported, "manifest.txt")).read().splitlines()
    assert len(lines) == 5
    for line in lines:
        name, ins, outs = line.split("\t")
        assert ins.startswith("in=")
        assert outs.startswith("out=")
        for spec in ins[3:].split(";"):
            shape, dtype = spec.split(":")
            assert dtype in ("float32", "int32")
            assert all(p.isdigit() for p in shape.split("x"))


def test_no_serialized_proto_used(exported):
    """Artifacts must be text, not binary serialized protos (see DESIGN.md)."""
    for name in os.listdir(exported):
        path = os.path.join(exported, name)
        with open(path, "rb") as f:
            head = f.read(64)
        head.decode("utf-8")  # raises on binary


def test_roundtrip_artifact_reparse(exported):
    """jax's own HLO parser accepts the emitted text (id-reassignment path)."""
    from jax._src.lib import xla_client as xc
    name = next(n for n in os.listdir(exported) if n.startswith("gfl_step"))
    text = open(os.path.join(exported, name)).read()
    # No python-side HLO text parser is exposed; minimally assert the entry
    # computation signature matches the manifest's input count.
    assert text.count("parameter(") >= 3
    del xc
