"""Multiclass loss-augmented decode Pallas kernel vs reference."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import multiclass_decode
from compile.kernels.ref import multiclass_decode_ref


def _mk(k, d, b, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, d)).astype(np.float32)
    x = rng.normal(size=(b, d)).astype(np.float32)
    y = rng.integers(0, k, size=b).astype(np.int32)
    return w, x, y


def _check(w, x, y, lw, block_b=64):
    ys, h = multiclass_decode(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
                              lw, block_b=block_b)
    ysr, hr = multiclass_decode_ref(w, x, y, lw)
    np.testing.assert_array_equal(np.asarray(ys), ysr)
    np.testing.assert_allclose(np.asarray(h), hr, rtol=1e-4, atol=1e-4)


def test_paper_shape():
    w, x, y = _mk(10, 64, 32, 0)
    _check(w, x, y, 1.0)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(2, 30),
    d=st.integers(1, 40),
    b=st.integers(1, 70),
    lw=st.sampled_from([0.0, 1.0, 2.5]),
    block_b=st.sampled_from([1, 7, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(k, d, b, lw, block_b, seed):
    w, x, y = _mk(k, d, b, seed)
    _check(w, x, y, lw, block_b=block_b)


def test_h_nonnegative():
    for seed in range(5):
        w, x, y = _mk(7, 9, 21, seed)
        _, h = multiclass_decode(jnp.asarray(w), jnp.asarray(x),
                                 jnp.asarray(y), 1.0)
        assert np.all(np.asarray(h) >= -1e-6)


def test_zero_loss_weight_is_argmax():
    w, x, y = _mk(6, 8, 17, 2)
    ys, _ = multiclass_decode(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
                              0.0)
    np.testing.assert_array_equal(np.asarray(ys), np.argmax(x @ w.T, axis=1))


def test_large_loss_dominates():
    """Huge loss weight forces y* != ytrue whenever K > 1."""
    w, x, y = _mk(5, 4, 30, 4)
    ys, _ = multiclass_decode(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
                              1e6)
    assert np.all(np.asarray(ys) != y)


@pytest.mark.parametrize("b", [1, 63, 64, 65, 128])
def test_batch_padding(b):
    w, x, y = _mk(4, 5, b, b)
    _check(w, x, y, 1.0)
