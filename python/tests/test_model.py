"""L2 model-level tests: objective/gradient/oracle/primal consistency."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels.ref import gfl_objective_ref


def _gfl_instance(d=10, n=100, lam=0.01, seed=0):
    rng = np.random.default_rng(seed)
    y = rng.normal(size=(d, n)).astype(np.float32)
    u = rng.normal(size=(d, n - 1)).astype(np.float32)
    u = u / np.maximum(1.0, np.linalg.norm(u, axis=0) / lam)
    b = (y[:, 1:] - y[:, :-1]).astype(np.float32)
    return u, y, b


def test_gfl_step_objective_matches_definition():
    u, y, b = _gfl_instance()
    lam = jnp.asarray([0.01], jnp.float32)
    _, _, _, f1 = model.gfl_step(jnp.asarray(u), jnp.asarray(b), lam)
    fr = gfl_objective_ref(u, y)
    np.testing.assert_allclose(float(f1[0]), fr, rtol=1e-4, atol=1e-4)


def test_gfl_gradient_is_finite_difference():
    """Directional finite differences agree with the kernel gradient."""
    u, y, b = _gfl_instance(d=4, n=20, seed=1)
    lam = jnp.asarray([0.01], jnp.float32)
    g, _, _, _ = model.gfl_step(jnp.asarray(u), jnp.asarray(b), lam)
    g = np.asarray(g, np.float64)
    rng = np.random.default_rng(2)
    eps = 1e-4
    for _ in range(5):
        v = rng.normal(size=u.shape)
        v /= np.linalg.norm(v)
        fp = gfl_objective_ref(u + eps * v, y)
        fm = gfl_objective_ref(u - eps * v, y)
        fd = (fp - fm) / (2 * eps)
        np.testing.assert_allclose(fd, np.sum(g * v), rtol=1e-3, atol=1e-3)


def test_gfl_primal_dual_relation():
    """Weak duality: primal(X(U)) >= -f(U) ... actually primal >= -min f.

    For this dual pair, p(X) + f(U) >= 0 with equality at the optimum.
    """
    u, y, b = _gfl_instance(seed=3)
    lam = jnp.asarray([0.01], jnp.float32)
    _, _, _, f1 = model.gfl_step(jnp.asarray(u), jnp.asarray(b), lam)
    x, p1 = model.gfl_primal(jnp.asarray(u), jnp.asarray(y), lam)
    assert float(p1[0]) + float(f1[0]) >= -1e-4


def test_gfl_primal_recovery_shape_and_zero_dual():
    """U = 0 gives X = Y exactly (no smoothing)."""
    _, y, _ = _gfl_instance(seed=4)
    lam = jnp.asarray([0.5], jnp.float32)
    u0 = jnp.zeros((y.shape[0], y.shape[1] - 1), jnp.float32)
    x, p1 = model.gfl_primal(u0, jnp.asarray(y), lam)
    np.testing.assert_allclose(np.asarray(x), y, atol=1e-6)


def test_gfl_fw_step_decreases_objective():
    """A Frank-Wolfe step with the paper's step size decreases f."""
    u, y, b = _gfl_instance(seed=5)
    lam_v = 0.01
    lam = jnp.asarray([lam_v], jnp.float32)
    n_blocks = u.shape[1]
    uj, bj = jnp.asarray(u), jnp.asarray(b)
    _, s, _, f0 = model.gfl_step(uj, bj, lam)
    # batch step tau = n: gamma = 2n*tau/(tau^2 k + 2n) with k=0 -> 1.0;
    # use a small gamma to stay in the descent regime of the quadratic.
    gamma = 2.0 * n_blocks * n_blocks / (n_blocks**2 * 5 + 2 * n_blocks)
    u1 = uj + gamma * (s - uj)
    _, _, _, f1 = model.gfl_step(u1, bj, lam)
    assert float(f1[0]) < float(f0[0])


def test_chain_oracle_batch_consistency():
    """Decoding a batch equals decoding each element alone."""
    rng = np.random.default_rng(6)
    k, d, ell, b = 6, 10, 5, 7
    wu = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    tr = jnp.asarray(rng.normal(size=(k, k)).astype(np.float32))
    x = rng.normal(size=(b, ell, d)).astype(np.float32)
    y = rng.integers(0, k, size=(b, ell)).astype(np.int32)
    lw = jnp.asarray([1.0], jnp.float32)
    ys_all, h_all = model.ssvm_chain_oracle(
        wu, tr, jnp.asarray(x), jnp.asarray(y), lw)
    for i in range(b):
        ys_i, h_i = model.ssvm_chain_oracle(
            wu, tr, jnp.asarray(x[i:i + 1]), jnp.asarray(y[i:i + 1]), lw)
        np.testing.assert_array_equal(np.asarray(ys_all)[i],
                                      np.asarray(ys_i)[0])
        np.testing.assert_allclose(float(h_all[i]), float(h_i[0]),
                                   rtol=1e-4, atol=1e-4)


def test_multiclass_oracle_batch_consistency():
    rng = np.random.default_rng(7)
    k, d, b = 8, 12, 9
    w = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    x = rng.normal(size=(b, d)).astype(np.float32)
    y = rng.integers(0, k, size=b).astype(np.int32)
    lw = jnp.asarray([1.0], jnp.float32)
    ys_all, h_all = model.ssvm_multiclass_oracle(
        w, jnp.asarray(x), jnp.asarray(y), lw)
    for i in range(b):
        ys_i, h_i = model.ssvm_multiclass_oracle(
            w, jnp.asarray(x[i:i + 1]), jnp.asarray(y[i:i + 1]), lw)
        assert int(ys_all[i]) == int(ys_i[0])
        np.testing.assert_allclose(float(h_all[i]), float(h_i[0]),
                                   rtol=1e-4, atol=1e-4)
