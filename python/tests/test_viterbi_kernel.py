"""Batched loss-augmented Viterbi Pallas kernel vs per-sequence DP reference."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import viterbi_decode
from compile.kernels.ref import viterbi_decode_ref


def _mk(k, d, ell, b, seed):
    rng = np.random.default_rng(seed)
    wu = rng.normal(size=(k, d)).astype(np.float32)
    tr = rng.normal(size=(k, k)).astype(np.float32)
    x = rng.normal(size=(b, ell, d)).astype(np.float32)
    y = rng.integers(0, k, size=(b, ell)).astype(np.int32)
    return wu, tr, x, y


def _check(wu, tr, x, y, lw, block_b=16):
    ys, h = viterbi_decode(jnp.asarray(wu), jnp.asarray(tr), jnp.asarray(x),
                           jnp.asarray(y), lw, block_b=block_b)
    ysr, hr = viterbi_decode_ref(wu, tr, x, y, lw)
    # With continuous random scores ties have measure zero; paths must match.
    np.testing.assert_array_equal(np.asarray(ys), ysr)
    np.testing.assert_allclose(np.asarray(h), hr, rtol=1e-4, atol=1e-4)


def test_paper_shape():
    """OCR-like configuration: K=26 letters, d=128, L=9."""
    wu, tr, x, y = _mk(26, 128, 9, 8, 0)
    _check(wu, tr, x, y, 1.0)


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(2, 12),
    d=st.integers(1, 20),
    ell=st.integers(2, 10),
    b=st.integers(1, 9),
    lw=st.sampled_from([0.0, 0.5, 1.0, 3.0]),
    block_b=st.sampled_from([1, 2, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(k, d, ell, b, lw, block_b, seed):
    wu, tr, x, y = _mk(k, d, ell, b, seed)
    _check(wu, tr, x, y, lw, block_b=block_b)


def test_zero_loss_weight_is_plain_inference():
    """lw=0: decode maximizes the raw chain score independent of ytrue."""
    wu, tr, x, y = _mk(5, 6, 7, 4, 3)
    y2 = (y + 1) % 5
    ys_a, _ = viterbi_decode(jnp.asarray(wu), jnp.asarray(tr), jnp.asarray(x),
                             jnp.asarray(y), 0.0)
    ys_b, _ = viterbi_decode(jnp.asarray(wu), jnp.asarray(tr), jnp.asarray(x),
                             jnp.asarray(y2), 0.0)
    np.testing.assert_array_equal(np.asarray(ys_a), np.asarray(ys_b))


def test_h_nonnegative():
    """H_i = max_y [...] >= value at y = ytrue = 0 (loss(ytrue)=0)."""
    for seed in range(4):
        wu, tr, x, y = _mk(6, 5, 8, 5, seed)
        _, h = viterbi_decode(jnp.asarray(wu), jnp.asarray(tr),
                              jnp.asarray(x), jnp.asarray(y), 1.0)
        assert np.all(np.asarray(h) >= -1e-5)


def test_decode_beats_exhaustive_enumeration():
    """Small instance: Viterbi equals brute force over all K^L labelings."""
    k, d, ell, b = 3, 4, 4, 3
    wu, tr, x, y = _mk(k, d, ell, b, 9)
    ys, h = viterbi_decode(jnp.asarray(wu), jnp.asarray(tr), jnp.asarray(x),
                           jnp.asarray(y), 1.0)
    ys, h = np.asarray(ys), np.asarray(h)
    import itertools
    for i in range(b):
        unary = x[i] @ wu.T
        best_v, best_y = -np.inf, None
        for lab in itertools.product(range(k), repeat=ell):
            v = sum(unary[t, lab[t]] for t in range(ell))
            v += sum(tr[lab[t - 1], lab[t]] for t in range(1, ell))
            v += sum(1.0 / ell for t in range(ell) if lab[t] != y[i, t])
            if v > best_v:
                best_v, best_y = v, lab
        score_true = sum(unary[t, y[i, t]] for t in range(ell)) + sum(
            tr[y[i, t - 1], y[i, t]] for t in range(1, ell))
        assert tuple(ys[i]) == best_y
        np.testing.assert_allclose(h[i], best_v - score_true,
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b", [1, 15, 16, 17, 32])
def test_batch_padding(b):
    wu, tr, x, y = _mk(4, 3, 5, b, b)
    _check(wu, tr, x, y, 1.0, block_b=16)
