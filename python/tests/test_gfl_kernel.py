"""GFL fused-step Pallas kernel vs pure-numpy reference."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gfl_fused_step
from compile.kernels.ref import gfl_fused_step_ref


def _mk(d, m, lam, seed, feasible=True):
    rng = np.random.default_rng(seed)
    u = rng.normal(size=(d, m)).astype(np.float32)
    if feasible:
        norms = np.maximum(np.linalg.norm(u, axis=0) / max(lam, 1e-9), 1.0)
        u = u / norms
    b = rng.normal(size=(d, m)).astype(np.float32)
    return u, b


def _check(u, b, lam, block_m=32):
    g, s, gap, f = gfl_fused_step(jnp.asarray(u), jnp.asarray(b), lam,
                                  block_m=block_m)
    gr, sr, gapr, fr = gfl_fused_step_ref(u, b, lam)
    np.testing.assert_allclose(np.asarray(g), gr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gap), gapr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(f), fr, rtol=1e-4, atol=1e-4)
    return g, s, gap, f


def test_paper_shape():
    """The Fig 1(b)/Fig 4 configuration: d=10, n=100 (m=99), lam=0.01."""
    u, b = _mk(10, 99, 0.01, 0)
    _check(u, b, 0.01)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 24),
    m=st.integers(1, 70),
    lam=st.floats(1e-3, 10.0),
    block_m=st.sampled_from([1, 3, 8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(d, m, lam, block_m, seed):
    """Sweep shapes, tile sizes (incl. non-dividing) and radii."""
    u, b = _mk(d, m, lam, seed)
    _check(u, b, lam, block_m=block_m)


def test_zero_gradient_column_oracle_is_zero():
    """0/0 guard: a zero gradient column must yield a zero oracle column."""
    d, m = 4, 6
    u = np.zeros((d, m), np.float32)
    b = np.zeros((d, m), np.float32)
    g, s, gap, f = gfl_fused_step(jnp.asarray(u), jnp.asarray(b), 1.0)
    assert np.all(np.asarray(s) == 0.0)
    assert np.all(np.asarray(gap) == 0.0)
    assert float(f) == 0.0


def test_oracle_columns_on_ball_boundary():
    u, b = _mk(8, 33, 0.5, 3)
    g, s, _, _ = gfl_fused_step(jnp.asarray(u), jnp.asarray(b), 0.5)
    norms = np.linalg.norm(np.asarray(s), axis=0)
    np.testing.assert_allclose(norms, 0.5, rtol=1e-5)


def test_oracle_minimizes_linear_form():
    """<s_t, g_t> must be <= <v, g_t> for random feasible v (oracle optimality)."""
    lam = 0.3
    u, b = _mk(6, 20, lam, 7)
    g, s, _, _ = gfl_fused_step(jnp.asarray(u), jnp.asarray(b), lam)
    g, s = np.asarray(g), np.asarray(s)
    rng = np.random.default_rng(11)
    for _ in range(20):
        v = rng.normal(size=6).astype(np.float32)
        v = v / np.linalg.norm(v) * lam
        t = rng.integers(0, 20)
        assert s[:, t] @ g[:, t] <= v @ g[:, t] + 1e-5


def test_gap_nonnegative_for_feasible_u():
    for seed in range(5):
        u, b = _mk(12, 40, 0.7, seed)
        _, _, gap, _ = gfl_fused_step(jnp.asarray(u), jnp.asarray(b), 0.7)
        assert np.all(np.asarray(gap) >= -1e-5)


def test_dtype_bf16():
    """Kernel runs in bf16 with loose tolerance (TPU-native dtype)."""
    u, b = _mk(8, 16, 0.1, 5)
    ub, bb = jnp.asarray(u, jnp.bfloat16), jnp.asarray(b, jnp.bfloat16)
    g, s, gap, f = gfl_fused_step(ub, bb, 0.1)
    gr, sr, gapr, fr = gfl_fused_step_ref(u, b, 0.1)
    np.testing.assert_allclose(
        np.asarray(g, np.float32), gr, rtol=0.1, atol=0.1)
    np.testing.assert_allclose(
        np.asarray(s, np.float32), sr, rtol=0.15, atol=0.02)


@pytest.mark.parametrize("m", [1, 2, 31, 32, 33, 64])
def test_tile_boundaries(m):
    """Exactness at every padding relationship between m and block_m=32."""
    u, b = _mk(5, m, 0.2, m)
    _check(u, b, 0.2)
