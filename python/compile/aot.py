"""AOT exporter: lower the L2 model functions to HLO *text* artifacts.

HLO text (not `.serialize()`d HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts

Emits one `<name>.hlo.txt` per (function, shape-variant) plus a `manifest.txt`
the rust runtime uses to discover artifacts and their shapes.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default shape configuration — mirrored by rust/src/util/config.rs defaults.
GFL_D = 10
GFL_N = 100            # signal length; m = n - 1 blocks
CHAIN_K = 26           # letter labels (OCR-like)
CHAIN_D = 128          # per-letter feature dim
CHAIN_L = 9            # fixed sequence length (see DESIGN.md substitutions)
CHAIN_BATCHES = (1, 16, 64)
MC_K = 10
MC_D = 64
MC_BATCHES = (1, 64)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_specs(cfg):
    """Yield (artifact_name, function, example_args, output_desc)."""
    d, n = cfg["gfl_d"], cfg["gfl_n"]
    m = n - 1
    yield (
        f"gfl_step_d{d}_n{n}",
        model.gfl_step,
        (f32(d, m), f32(d, m), f32(1)),
        "g(d,m) s(d,m) gap(m) f(1)",
    )
    yield (
        f"gfl_primal_d{d}_n{n}",
        model.gfl_primal,
        (f32(d, m), f32(d, n), f32(1)),
        "x(d,n) p(1)",
    )
    k, cd, ell = cfg["chain_k"], cfg["chain_d"], cfg["chain_l"]
    for b in cfg["chain_batches"]:
        yield (
            f"ssvm_chain_K{k}_d{cd}_L{ell}_B{b}",
            model.ssvm_chain_oracle,
            (f32(k, cd), f32(k, k), f32(b, ell, cd), i32(b, ell), f32(1)),
            "ystar(B,L)i32 h(B)",
        )
    mk, md = cfg["mc_k"], cfg["mc_d"]
    for b in cfg["mc_batches"]:
        yield (
            f"ssvm_multiclass_K{mk}_d{md}_B{b}",
            model.ssvm_multiclass_oracle,
            (f32(mk, md), f32(b, md), i32(b), f32(1)),
            "ystar(B)i32 h(B)",
        )


def export_all(out_dir, cfg):
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, fn, args, outs in build_specs(cfg):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shapes = ";".join(
            f"{'x'.join(map(str, a.shape)) or '0'}:{a.dtype}" for a in args
        )
        manifest.append(f"{name}\tin={shapes}\tout={outs}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {out_dir}/manifest.txt ({len(manifest)} artifacts)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--gfl-d", type=int, default=GFL_D)
    ap.add_argument("--gfl-n", type=int, default=GFL_N)
    ap.add_argument("--chain-k", type=int, default=CHAIN_K)
    ap.add_argument("--chain-d", type=int, default=CHAIN_D)
    ap.add_argument("--chain-l", type=int, default=CHAIN_L)
    ap.add_argument("--mc-k", type=int, default=MC_K)
    ap.add_argument("--mc-d", type=int, default=MC_D)
    args = ap.parse_args()
    cfg = dict(
        gfl_d=args.gfl_d, gfl_n=args.gfl_n,
        chain_k=args.chain_k, chain_d=args.chain_d, chain_l=args.chain_l,
        chain_batches=CHAIN_BATCHES,
        mc_k=args.mc_k, mc_d=args.mc_d, mc_batches=MC_BATCHES,
    )
    export_all(args.out_dir, cfg)


if __name__ == "__main__":
    main()
