"""Loss-augmented multiclass argmax oracle as a Pallas kernel.

Structural-SVM special case used by the paper's Example 1 (multi-label
classification with random per-class feature vectors). For each datapoint in
the minibatch the linear oracle is

    y*_i = argmax_j [ loss_weight * 1{j != y_i} + <w_j, x_i> - <w_{y_i}, x_i> ]
    H_i  = the attained maximum value,

i.e. loss-augmented decoding over K classes. The kernel is one MXU matmul
(bb, d) @ (d, K) plus a masked argmax — the canonical TPU-friendly shape.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, x_ref, y_ref, lw_ref, ys_ref, h_ref):
    w = w_ref[...]                       # (K, d)
    x = x_ref[...]                       # (bb, d)
    y = y_ref[...]                       # (bb,) int32
    lw = lw_ref[0]
    bb = x.shape[0]
    k = w.shape[0]

    scores = jax.lax.dot_general(
        x, w.transpose(), (((1,), (0,)), ((), ())))       # (bb, K)
    labels = jax.lax.broadcasted_iota(jnp.int32, (bb, k), 1)
    aug = scores + lw * (labels != y[:, None]).astype(scores.dtype)

    ystar = jnp.argmax(aug, axis=1).astype(jnp.int32)
    vmax = jnp.max(aug, axis=1)
    score_true = jnp.take_along_axis(scores, y[:, None], axis=1)[:, 0]

    ys_ref[...] = ystar
    h_ref[...] = vmax - score_true


@functools.partial(jax.jit, static_argnames=("block_b",))
def multiclass_decode(w, x, ytrue, loss_weight, block_b=64):
    """Loss-augmented multiclass decode.

    Args:
      w: (K, d) class weight matrix.
      x: (B, d) features.
      ytrue: (B,) int32 labels.
      loss_weight: scalar 0/1 loss magnitude (0.0 = plain argmax inference).
      block_b: batch tile size.

    Returns:
      (ystar, h): (B,) int32 argmaxes and (B,) oracle values H_i.
    """
    b, d = x.shape
    k = w.shape[0]
    dtype = x.dtype
    bb = min(block_b, b)
    bp = ((b + bb - 1) // bb) * bb
    pad = bp - b
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), dtype)], axis=0)
        ytrue = jnp.concatenate([ytrue, jnp.zeros((pad,), jnp.int32)], axis=0)

    lw = jnp.asarray(loss_weight, dtype).reshape((1,))
    grid = (bp // bb,)

    ystar, h = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.int32),
            jax.ShapeDtypeStruct((bp,), dtype),
        ],
        interpret=True,
    )(w, x, ytrue, lw)

    return ystar[:b], h[:b]
