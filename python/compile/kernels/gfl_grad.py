"""Group Fused Lasso dual: fused block-gradient + linear-oracle Pallas kernel.

Problem (paper Eq. 10-dual): variables U in R^{d x m} (m = n-1 blocks, one
per change-point), constraint ||U[:, t]||_2 <= lambda. Objective

    f(U) = 1/2 ||U D^T||_F^2 - <U, B>,   B := Y D  (d x m)

with D the n x (n-1) forward-differencing matrix. The gradient is the
tridiagonal stencil

    G[:, t] = -U[:, t-1] + 2 U[:, t] - U[:, t+1] - B[:, t]

and the per-block Frank-Wolfe linear oracle over the l2 ball is

    S[:, t] = -lambda * G[:, t] / ||G[:, t]||_2          (0 if G[:, t] = 0)

with per-block surrogate gap  gap[t] = <U[:, t], G[:, t]> + lambda ||G[:, t]||.

Kernel layout: the stencil shifts are materialized as two shifted views
(Uprev, Unext) by the L2 caller — on a real TPU these would be overlapped
BlockSpec halos; shifting in XLA keeps edge handling exact while the kernel
stays a pure fused elementwise + column-reduction tile program. The grid
tiles the *time* axis; each program owns a (d, bm) VMEM tile and produces the
gradient tile, the oracle tile, the per-column gap and the two scalar
contractions <U, G>, <U, B> needed to reconstruct f(U) = (<U,G> - <U,B>)/2.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(uprev_ref, u_ref, unext_ref, b_ref, lam_ref,
            g_ref, s_ref, gap_ref, ug_ref, ub_ref):
    u = u_ref[...]
    b = b_ref[...]
    lam = lam_ref[0]
    # Tridiagonal stencil (shifted views carry the halo columns).
    g = 2.0 * u - uprev_ref[...] - unext_ref[...] - b
    g_ref[...] = g
    # Per-column l2 norms -> ball oracle. Guard the 0/0 case.
    norms = jnp.sqrt(jnp.sum(g * g, axis=0))
    safe = jnp.where(norms > 0.0, norms, 1.0)
    s_ref[...] = -lam * g / safe[None, :]
    # Surrogate duality-gap contribution per block: <u_t - s_t, g_t>.
    gap_ref[...] = jnp.sum(u * g, axis=0) + lam * norms
    # Scalar contractions for the objective value.
    ug_ref[0] = jnp.sum(u * g)
    ub_ref[0] = jnp.sum(u * b)


@functools.partial(jax.jit, static_argnames=("block_m",))
def gfl_fused_step(u, b, lam, block_m=32):
    """Fused GFL dual step quantities for all m blocks.

    Args:
      u: (d, m) dual iterate, columns feasible (||u_t|| <= lam).
      b: (d, m) precomputed B = Y D.
      lam: scalar l2-ball radius (the fused-lasso penalty).
      block_m: time-axis tile width (VMEM tile is d x block_m).

    Returns:
      (g, s, gap, f): gradient (d,m), oracle solutions (d,m), per-block
      gaps (m,), objective value f(U) (scalar).
    """
    d, m = u.shape
    dtype = u.dtype
    # Shifted halo views; zero-padded at the boundary (u_0 = u_{m+1} = 0).
    zcol = jnp.zeros((d, 1), dtype)
    uprev = jnp.concatenate([zcol, u[:, :-1]], axis=1)
    unext = jnp.concatenate([u[:, 1:], zcol], axis=1)
    lam_arr = jnp.asarray(lam, dtype).reshape((1,))

    # Pad the time axis to a tile multiple; padded columns are zero and
    # contribute zero gap / zero scalar mass (B padded with zero too).
    bm = min(block_m, m)
    mp = ((m + bm - 1) // bm) * bm
    pad = mp - m
    if pad:
        zpad = jnp.zeros((d, pad), dtype)
        u_p = jnp.concatenate([u, zpad], axis=1)
        b_p = jnp.concatenate([b, zpad], axis=1)
        uprev_p = jnp.concatenate([uprev, zpad], axis=1)
        unext_p = jnp.concatenate([unext, zpad], axis=1)
    else:
        u_p, b_p, uprev_p, unext_p = u, b, uprev, unext

    grid = (mp // bm,)
    col_spec = pl.BlockSpec((d, bm), lambda i: (0, i))
    vec_spec = pl.BlockSpec((bm,), lambda i: (i,))
    scal_spec = pl.BlockSpec((1,), lambda i: (0,))

    g, s, gap, ug, ub = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[col_spec, col_spec, col_spec, col_spec,
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[col_spec, col_spec, vec_spec, scal_spec, scal_spec],
        out_shape=[
            jax.ShapeDtypeStruct((d, mp), dtype),
            jax.ShapeDtypeStruct((d, mp), dtype),
            jax.ShapeDtypeStruct((mp,), dtype),
            jax.ShapeDtypeStruct((1,), dtype),
            jax.ShapeDtypeStruct((1,), dtype),
        ],
        interpret=True,
    )(uprev_p, u_p, unext_p, b_p, lam_arr)

    # Scalar tiles are overwritten per grid step in interpret mode; recompute
    # the two contractions from the (exact) tile outputs instead.
    g = g[:, :m]
    s = s[:, :m]
    gap = gap[:m]
    del ug, ub
    ug_v = jnp.sum(u * g)
    ub_v = jnp.sum(u * b)
    f = 0.5 * (ug_v - ub_v)
    return g, s, gap, f
