"""Batched loss-augmented Viterbi decoding as a Pallas kernel.

This is the structural-SVM linear oracle (paper Appendix C): for each
datapoint i in the minibatch, maximize over labelings y of the chain

    H_i(y; w) = L_i(y) - <w, psi_i(y)>
              = [ L_i(y) + score_w(x_i, y) ] - score_w(x_i, y_i)

where score_w(x, y) = sum_t <w_u[y_t], x_t> + sum_t T[y_{t-1}, y_t] and
L_i(y) is the normalized Hamming loss (weight `loss_weight`; set it to 0 for
plain max-score inference). The maximization over y is exact max-sum dynamic
programming (Viterbi).

Kernel layout: the grid tiles the *batch* axis; each program owns a
(bb, L, d) slab of sequences in VMEM. The hot contraction is the unary score
einsum (bb*L, d) @ (d, K) — MXU-shaped — followed by an L-step max-plus scan
whose inner op is a (bb, K, K) reduction (on TPU this is a max-plus "matmul"
against the K x K transition matrix). Backpointers live in an int32 output
tile that the L2 caller simply drops.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(wu_ref, tr_ref, x_ref, y_ref, lw_ref,
            ys_ref, h_ref, ptr_ref):
    x = x_ref[...]                      # (bb, L, d)
    ytrue = y_ref[...]                  # (bb, L) int32
    wu = wu_ref[...]                    # (K, d)
    tr = tr_ref[...]                    # (K, K)
    lw = lw_ref[0]
    bb, ell, _d = x.shape
    k = wu.shape[0]

    # Unary scores for all positions: the MXU contraction.
    unary = jax.lax.dot_general(
        x.reshape(bb * ell, -1), wu.transpose(),
        (((1,), (0,)), ((), ())),
    ).reshape(bb, ell, k)               # (bb, L, K)

    labels = jax.lax.broadcasted_iota(jnp.int32, (bb, ell, k), 2)
    loss = (lw / ell) * (labels != ytrue[:, :, None]).astype(unary.dtype)
    theta = unary + loss                # loss-augmented node scores

    # Forward max-sum recursion with backpointers.
    alpha0 = theta[:, 0, :]             # (bb, K)

    def fwd(t, alpha):
        cand = alpha[:, :, None] + tr[None, :, :]      # (bb, j, k)
        best_j = jnp.argmax(cand, axis=1).astype(jnp.int32)
        alpha_new = theta[:, t, :] + jnp.max(cand, axis=1)
        ptr_ref[t] = best_j
        return alpha_new

    alpha = jax.lax.fori_loop(1, ell, fwd, alpha0)

    v = jnp.max(alpha, axis=1)                         # (bb,)
    y_last = jnp.argmax(alpha, axis=1).astype(jnp.int32)
    ys_ref[:, ell - 1] = y_last

    def back(i, y_next):
        t = ell - 2 - i
        ptr_t = ptr_ref[t + 1]                         # (bb, K)
        y_t = jnp.take_along_axis(ptr_t, y_next[:, None], axis=1)[:, 0]
        ys_ref[:, t] = y_t
        return y_t

    jax.lax.fori_loop(0, ell - 1, back, y_last)

    # Score of the ground-truth labeling (no loss term).
    un_true = jnp.take_along_axis(unary, ytrue[:, :, None], axis=2)[:, :, 0]
    pair = tr[ytrue[:, :-1], ytrue[:, 1:]]             # (bb, L-1)
    score_true = jnp.sum(un_true, axis=1) + jnp.sum(pair, axis=1)

    h_ref[...] = v - score_true


@functools.partial(jax.jit, static_argnames=("block_b",))
def viterbi_decode(wu, trans, x, ytrue, loss_weight, block_b=16):
    """Loss-augmented Viterbi decode for a batch of fixed-length chains.

    Args:
      wu: (K, d) unary weights.
      trans: (K, K) transition weights, trans[j, k] scores j -> k.
      x: (B, L, d) feature sequences.
      ytrue: (B, L) int32 ground-truth labels.
      loss_weight: scalar; 1.0 for loss-augmented decoding, 0.0 for plain
        inference.
      block_b: batch tile size.

    Returns:
      (ystar, h): (B, L) int32 argmax labelings and (B,) values
      H_i(y*; w) = max_y [L_i(y) - <w, psi_i(y)>].
    """
    b, ell, d = x.shape
    k = wu.shape[0]
    dtype = x.dtype
    bb = min(block_b, b)
    bp = ((b + bb - 1) // bb) * bb
    pad = bp - b
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, ell, d), dtype)], axis=0)
        ytrue = jnp.concatenate(
            [ytrue, jnp.zeros((pad, ell), jnp.int32)], axis=0)

    lw = jnp.asarray(loss_weight, dtype).reshape((1,))
    grid = (bp // bb,)

    ystar, h, _ptr = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((bb, ell, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, ell), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, ell), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((ell, bb, k), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, ell), jnp.int32),
            jax.ShapeDtypeStruct((bp,), dtype),
            jax.ShapeDtypeStruct((ell, bp, k), jnp.int32),
        ],
        interpret=True,
    )(wu, trans, x, ytrue, lw)

    return ystar[:b], h[:b]
