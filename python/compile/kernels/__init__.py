"""Layer-1 Pallas kernels for AP-BCFW compute hot-spots.

Every kernel here is written with `pl.pallas_call(..., interpret=True)` so it
lowers to plain HLO ops executable by the CPU PJRT plugin (the image has no
TPU). The BlockSpec structure is still the real TPU schedule: tiles are sized
for VMEM and the inner contractions are MXU-shaped (see DESIGN.md
§Hardware-Adaptation).
"""

from .gfl_grad import gfl_fused_step
from .viterbi import viterbi_decode
from .multiclass import multiclass_decode

__all__ = ["gfl_fused_step", "viterbi_decode", "multiclass_decode"]
