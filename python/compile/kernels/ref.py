"""Pure numpy reference oracles — ground truth for every Pallas kernel.

Deliberately written as straight-line, loop-heavy numpy: slow, obvious, and
independent of JAX tracing, so a bug in a kernel cannot be mirrored here.
"""

import numpy as np


def gfl_fused_step_ref(u, b, lam):
    """Reference for kernels.gfl_grad.gfl_fused_step.

    Returns (g, s, gap, f) with the same semantics.
    """
    u = np.asarray(u, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    d, m = u.shape
    g = np.zeros((d, m))
    for t in range(m):
        g[:, t] = 2.0 * u[:, t] - b[:, t]
        if t > 0:
            g[:, t] -= u[:, t - 1]
        if t + 1 < m:
            g[:, t] -= u[:, t + 1]
    s = np.zeros_like(g)
    gap = np.zeros(m)
    for t in range(m):
        nrm = np.linalg.norm(g[:, t])
        if nrm > 0:
            s[:, t] = -lam * g[:, t] / nrm
        gap[t] = u[:, t] @ g[:, t] + lam * nrm
    f = 0.5 * (np.sum(u * g) - np.sum(u * b))
    return g, s, gap, f


def gfl_objective_ref(u, y, lam_unused=None):
    """Dual objective via the definition f(U) = 1/2||U D^T||_F^2 - <U, YD>."""
    u = np.asarray(u, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    d, n = y.shape
    m = n - 1
    udt = np.zeros((d, n))
    for j in range(n):
        if j >= 1:
            udt[:, j] += u[:, j - 1]
        if j < m:
            udt[:, j] -= u[:, j]
    b = y[:, 1:] - y[:, :-1]
    return 0.5 * np.sum(udt * udt) - np.sum(u * b)


def viterbi_decode_ref(wu, trans, x, ytrue, loss_weight):
    """Reference for kernels.viterbi.viterbi_decode: per-sequence DP loops."""
    wu = np.asarray(wu, dtype=np.float64)
    trans = np.asarray(trans, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    ytrue = np.asarray(ytrue)
    bsz, ell, _d = x.shape
    k = wu.shape[0]
    ystar = np.zeros((bsz, ell), dtype=np.int32)
    hval = np.zeros(bsz)
    for i in range(bsz):
        unary = x[i] @ wu.T                     # (L, K)
        theta = unary.copy()
        for t in range(ell):
            for c in range(k):
                if c != ytrue[i, t]:
                    theta[t, c] += loss_weight / ell
        alpha = theta[0].copy()
        ptr = np.zeros((ell, k), dtype=np.int32)
        for t in range(1, ell):
            for c in range(k):
                cand = alpha + trans[:, c]
                ptr[t, c] = int(np.argmax(cand))
                alpha_c = cand[ptr[t, c]] + theta[t, c]
                if c == 0:
                    new_alpha = np.zeros(k)
                new_alpha[c] = alpha_c
            alpha = new_alpha
        ystar[i, ell - 1] = int(np.argmax(alpha))
        v = alpha[ystar[i, ell - 1]]
        for t in range(ell - 2, -1, -1):
            ystar[i, t] = ptr[t + 1, ystar[i, t + 1]]
        score_true = sum(unary[t, ytrue[i, t]] for t in range(ell))
        score_true += sum(
            trans[ytrue[i, t - 1], ytrue[i, t]] for t in range(1, ell))
        hval[i] = v - score_true
    return ystar, hval


def multiclass_decode_ref(w, x, ytrue, loss_weight):
    """Reference for kernels.multiclass.multiclass_decode."""
    w = np.asarray(w, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    ytrue = np.asarray(ytrue)
    bsz = x.shape[0]
    k = w.shape[0]
    ystar = np.zeros(bsz, dtype=np.int32)
    hval = np.zeros(bsz)
    for i in range(bsz):
        scores = w @ x[i]
        aug = scores.copy()
        for c in range(k):
            if c != ytrue[i]:
                aug[c] += loss_weight
        ystar[i] = int(np.argmax(aug))
        hval[i] = aug[ystar[i]] - scores[ytrue[i]]
    return ystar, hval
