"""Layer-2 JAX compute graphs for AP-BCFW, calling the L1 Pallas kernels.

Each public function here is one AOT artifact: `aot.py` lowers it once to HLO
text and the rust runtime (rust/src/runtime) compiles and executes it on the
request path. Python never runs at serve time.

Scalar runtime knobs (lambda, loss weight) are passed as shape-(1,) f32
inputs so the rust side can set them per call without recompiling.
"""

import jax.numpy as jnp

from .kernels import gfl_fused_step, multiclass_decode, viterbi_decode


def gfl_step(u, b, lam):
    """One Group-Fused-Lasso dual evaluation over all blocks.

    Args:
      u: (d, m) dual iterate.
      b: (d, m) B = Y D.
      lam: (1,) l2-ball radius.

    Returns:
      (g, s, gap, f1): gradient, oracle columns, per-block gaps, and the
      objective value as a (1,) vector.
    """
    g, s, gap, f = gfl_fused_step(u, b, lam[0])
    return g, s, gap, f.reshape((1,))


def gfl_primal(u, y, lam):
    """Primal recovery + primal objective for GFL.

    X = Y - U D^T is the primal signal estimate; the primal objective is
    1/2 ||X - Y||_F^2 + lam * sum_t ||X[:, t+1] - X[:, t]||_2.

    Args:
      u: (d, n-1) dual iterate.  y: (d, n) observations.  lam: (1,).

    Returns:
      (x, p1): primal estimate (d, n) and primal objective as (1,).
    """
    d, n = y.shape
    zcol = jnp.zeros((d, 1), u.dtype)
    # (U D^T)[:, j] = u_{j-1} - u_j with u_0 = u_n = 0.
    udt = jnp.concatenate([zcol, u], axis=1) - jnp.concatenate([u, zcol], axis=1)
    x = y - udt
    diffs = x[:, 1:] - x[:, :-1]
    tv = jnp.sum(jnp.sqrt(jnp.sum(diffs * diffs, axis=0)))
    p = 0.5 * jnp.sum(udt * udt) + lam[0] * tv
    return x, p.reshape((1,))


def ssvm_chain_oracle(wu, trans, x, ytrue, loss_weight):
    """Structural-SVM chain oracle: batched loss-augmented Viterbi.

    Args:
      wu: (K, d) unary weights.  trans: (K, K) transition weights.
      x: (B, L, d) features.  ytrue: (B, L) int32.  loss_weight: (1,).

    Returns:
      (ystar, h): (B, L) int32 decodes and (B,) oracle values.
    """
    return viterbi_decode(wu, trans, x, ytrue, loss_weight[0])


def ssvm_multiclass_oracle(w, x, ytrue, loss_weight):
    """Structural-SVM multiclass oracle: loss-augmented argmax.

    Args:
      w: (K, d).  x: (B, d).  ytrue: (B,) int32.  loss_weight: (1,).

    Returns:
      (ystar, h): (B,) int32 and (B,) oracle values.
    """
    return multiclass_decode(w, x, ytrue, loss_weight[0])
