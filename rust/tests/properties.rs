//! Property tests over coordinator and problem invariants (the offline
//! substitute for `proptest` — see `apbcfw::util::proptest`).

use apbcfw::coordinator::buffer::BatchAssembler;
use apbcfw::coordinator::UpdateMsg;
use apbcfw::data::signal;
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::simplex_qp::SimplexQp;
use apbcfw::problems::ssvm::{ssvm_apply, SsvmState};
use apbcfw::problems::{ApplyOptions, BlockOracle, Problem};
use apbcfw::sim::adapt::{
    accept_delay_adjusted, damping_factor, next_batch, DelayWindowRing,
};
use apbcfw::sim::delay::{accept_delay, DelayModel};
use apbcfw::solver::schedule_gamma;
use apbcfw::util::la;
use apbcfw::util::proptest::check;

#[test]
fn prop_buffer_batches_are_disjoint_and_sized() {
    check(200, 101, |g| {
        let n = g.usize_in(2, 40);
        let tau = g.usize_in(1, n);
        let inserts = g.usize_in(0, 120);
        let mut asm = BatchAssembler::new();
        let mut inserted = std::collections::HashSet::new();
        for _ in 0..inserts {
            // Payloads of 1..=3 distinct blocks (the batched fan-out
            // message shape; 1 is the historical single-block message).
            let payload = g.usize_in(1, 3.min(n));
            let mut blocks = std::collections::HashSet::new();
            while blocks.len() < payload {
                blocks.insert(g.usize_in(0, n - 1));
            }
            inserted.extend(blocks.iter().copied());
            // Mixed payload representations through one assembler: the
            // buffer is representation-agnostic.
            asm.insert(UpdateMsg {
                oracles: blocks
                    .into_iter()
                    .map(|block| {
                        if g.bool() {
                            BlockOracle::dense(block, vec![0.0], 0.0)
                        } else {
                            BlockOracle {
                                block,
                                s: apbcfw::problems::OraclePayload::Sparse {
                                    idx: vec![],
                                    val: vec![],
                                    dim: 1,
                                },
                                ls: 0.0,
                            }
                        }
                    })
                    .collect(),
                k_read: 0,
                worker: 0,
                generation: 0,
            });
        }
        assert_eq!(asm.len(), inserted.len(), "pending = distinct inserted");
        match asm.take_batch(tau) {
            Some(batch) => {
                assert!(batch.len() >= tau);
                let blocks: Vec<usize> =
                    batch.iter().map(|m| m.oracle.block).collect();
                let mut sorted = blocks.clone();
                sorted.sort_unstable();
                assert_eq!(
                    blocks, sorted,
                    "take_batch must drain in block order"
                );
                let len = sorted.len();
                sorted.dedup();
                assert_eq!(sorted.len(), len, "duplicate block in batch");
                assert!(asm.is_empty());
            }
            None => assert!(inserted.len() < tau),
        }
    });
}

#[test]
fn prop_schedule_gamma_bounds_and_monotonicity() {
    check(300, 102, |g| {
        let n = g.usize_in(1, 10_000);
        let tau = g.usize_in(1, n);
        let k = g.usize_in(0, 1_000_000) as u64;
        let gamma = schedule_gamma(n, tau, k);
        assert!((0.0..=1.0).contains(&gamma), "gamma={gamma}");
        assert!(gamma > 0.0);
        let gamma_next = schedule_gamma(n, tau, k + 1);
        assert!(gamma_next <= gamma, "schedule must be non-increasing");
    });
}

#[test]
fn prop_delay_drop_rule() {
    check(300, 103, |g| {
        let k = g.usize_in(0, 10_000) as u64;
        let delay = g.usize_in(0, 10_000) as u64;
        let accepted = accept_delay(k, delay);
        assert_eq!(accepted, 2 * delay <= k);
        // monotone: if a delay is accepted, any smaller delay is too
        if accepted && delay > 0 {
            assert!(accept_delay(k, delay - 1));
        }
    });
}

#[test]
fn prop_delay_models_nonnegative_and_mean_finite() {
    check(60, 104, |g| {
        let kappa = g.f64_in(0.1, 30.0);
        let model = *g.pick(&[
            DelayModel::Poisson { kappa },
            DelayModel::pareto_with_mean(kappa),
            DelayModel::Fixed(kappa as u64),
        ]);
        for _ in 0..50 {
            let s = model.sample(g.rng());
            let _ = s; // non-negative by type
        }
        assert!(model.mean().is_finite());
    });
}

#[test]
fn prop_gfl_iterates_stay_feasible_under_any_interleaving() {
    check(40, 105, |g| {
        let d = g.usize_in(1, 6);
        let n = g.usize_in(3, 25);
        let lam = g.f64_in(0.01, 2.0);
        let sig =
            signal::piecewise_constant(d, n, 3, 1.0, 0.3, g.case_seed);
        let gfl = Gfl::new(d, n, lam, sig.noisy.clone());
        let mut param = gfl.init_param();
        let steps = g.usize_in(1, 60);
        for k in 0..steps {
            let tau = g.usize_in(1, gfl.m.min(8));
            let blocks = g.subset(gfl.m, tau);
            let batch: Vec<_> =
                blocks.iter().map(|&t| gfl.oracle(&param, t)).collect();
            let gamma = if g.bool() {
                schedule_gamma(gfl.m, tau, k as u64)
            } else {
                g.f32_in(0.0, 1.0)
            };
            gfl.apply(
                &mut (),
                &mut param,
                &batch,
                ApplyOptions {
                    gamma,
                    line_search: g.bool(),
                },
            );
        }
        for t in 0..gfl.m {
            let nrm = la::norm2(&param[t * d..(t + 1) * d]);
            assert!(
                nrm <= lam + 1e-4,
                "block {t}: ||u|| = {nrm} > lam = {lam}"
            );
        }
    });
}

#[test]
fn prop_qp_iterates_stay_on_simplices() {
    check(40, 106, |g| {
        let n = g.usize_in(2, 12);
        let m = g.usize_in(2, 6);
        let qp = SimplexQp::random(
            n,
            m,
            g.f64_in(0.1, 2.0),
            g.f64_in(0.0, 1.0),
            3,
            g.case_seed,
        );
        let mut x = qp.init_param();
        for k in 0..g.usize_in(1, 50) {
            let tau = g.usize_in(1, n);
            let blocks = g.subset(n, tau);
            let batch: Vec<_> =
                blocks.iter().map(|&i| qp.oracle(&x, i)).collect();
            qp.apply(
                &mut (),
                &mut x,
                &batch,
                ApplyOptions {
                    gamma: schedule_gamma(n, tau, k as u64),
                    line_search: g.bool(),
                },
            );
        }
        for b in 0..n {
            let blk = &x[b * m..(b + 1) * m];
            let sum: f64 = blk.iter().map(|&v| v as f64).sum();
            assert!((sum - 1.0).abs() < 1e-3, "block {b} sum {sum}");
            assert!(blk.iter().all(|&v| v >= -1e-5));
        }
    });
}

#[test]
fn prop_ssvm_state_w_always_equals_sum_wi() {
    check(60, 107, |g| {
        let n = g.usize_in(1, 8);
        let dim = g.usize_in(1, 12);
        let lam = g.f64_in(0.01, 2.0);
        let mut st = SsvmState::new(n, dim);
        let mut w = vec![0.0f32; dim];
        for k in 0..g.usize_in(1, 30) {
            let tau = g.usize_in(1, n);
            let blocks = g.subset(n, tau);
            let batch: Vec<BlockOracle> = blocks
                .iter()
                .map(|&b| {
                    BlockOracle::dense(
                        b,
                        g.f32_vec(dim, -1.0, 1.0),
                        g.f64_in(0.0, 1.0),
                    )
                })
                .collect();
            let gamma = schedule_gamma(n, tau, k as u64);
            ssvm_apply(lam, &mut st, &mut w, &batch, gamma, g.bool());
        }
        let mut sum = vec![0.0f32; dim];
        for i in 0..n {
            la::axpy(1.0, st.wi(i), &mut sum);
        }
        for (a, b) in w.iter().zip(sum.iter()) {
            assert!(
                (a - b).abs() < 1e-3,
                "w != sum w_i: {a} vs {b}"
            );
        }
        let l_sum: f64 = st.li.iter().sum();
        assert!((st.l - l_sum).abs() < 1e-6);
    });
}

#[test]
fn prop_block_gap_nonnegative_at_oracle_solution() {
    check(40, 108, |g| {
        let d = g.usize_in(1, 5);
        let n = g.usize_in(3, 20);
        let lam = g.f64_in(0.05, 1.0);
        let sig =
            signal::piecewise_constant(d, n, 3, 1.0, 0.3, g.case_seed + 7);
        let gfl = Gfl::new(d, n, lam, sig.noisy.clone());
        // random feasible point
        let mut param = gfl.init_param();
        for _ in 0..g.usize_in(0, 20) {
            let t = g.usize_in(0, gfl.m - 1);
            let o = gfl.oracle(&param, t);
            gfl.apply(
                &mut (),
                &mut param,
                &[o],
                ApplyOptions {
                    gamma: g.f32_in(0.0, 1.0),
                    line_search: false,
                },
            );
        }
        let t = g.usize_in(0, gfl.m - 1);
        let o = gfl.oracle(&param, t);
        let gap = gfl.block_gap(&(), &param, &o);
        assert!(gap >= -1e-6, "gap_i(x) = {gap} < 0");
    });
}

#[test]
fn prop_line_search_never_worse_than_schedule() {
    check(30, 109, |g| {
        let n = g.usize_in(3, 10);
        let m = g.usize_in(2, 5);
        let qp = SimplexQp::random(n, m, 1.0, g.f64_in(0.0, 0.5), 3, g.case_seed);
        let mut x = qp.init_param();
        // a few warmup steps
        for k in 0..g.usize_in(0, 10) {
            let i = g.usize_in(0, n - 1);
            let o = qp.oracle(&x, i);
            qp.apply(
                &mut (),
                &mut x,
                &[o],
                ApplyOptions {
                    gamma: schedule_gamma(n, 1, k as u64),
                    line_search: false,
                },
            );
        }
        let tau = g.usize_in(1, n);
        let blocks = g.subset(n, tau);
        let batch: Vec<_> = blocks.iter().map(|&i| qp.oracle(&x, i)).collect();
        let mut x_ls = x.clone();
        qp.apply(
            &mut (),
            &mut x_ls,
            &batch,
            ApplyOptions {
                gamma: 0.0,
                line_search: true,
            },
        );
        let mut x_fixed = x.clone();
        qp.apply(
            &mut (),
            &mut x_fixed,
            &batch,
            ApplyOptions {
                gamma: g.f32_in(0.0, 1.0),
                line_search: false,
            },
        );
        assert!(
            qp.objective_of(&x_ls) <= qp.objective_of(&x_fixed) + 1e-6,
            "line search must dominate any fixed step"
        );
    });
}

#[test]
fn prop_kappa_damping_monotone_and_clamped() {
    check(300, 110, |g| {
        let exp = g.f64_in(0.5, 64.0);
        let lo = g.f64_in(0.0, 200.0);
        let hi = lo + g.f64_in(0.0, 200.0);
        let d_lo = damping_factor(exp, lo);
        let d_hi = damping_factor(exp, hi);
        // Worse observed delay can never damp *less*.
        assert!(
            d_hi <= d_lo + 1e-15,
            "damping not nonincreasing: obs {lo} -> {d_lo}, \
             obs {hi} -> {d_hi}"
        );
        // Always inside the clamp band, whatever the inputs.
        for d in [d_lo, d_hi] {
            assert!((0.1..=1.0).contains(&d), "damping {d} escaped clamp");
        }
        // No observed delay (including the pre-first-update EMA state,
        // which reports 0) means the schedule is untouched.
        assert_eq!(damping_factor(exp, 0.0), 1.0);
        assert_eq!(damping_factor(exp, -1.0), 1.0);
    });
}

#[test]
fn prop_quantile_drop_generalizes_k_over_2() {
    check(200, 111, |g| {
        let mut ring = DelayWindowRing::new(g.usize_in(1, 64));
        for _ in 0..g.usize_in(0, 100) {
            ring.push(g.usize_in(0, 40) as u64);
        }
        let k = g.usize_in(0, 2_000) as u64;
        let delay = g.usize_in(0, 60) as u64;
        let plain = accept_delay(k, delay);

        // Q = 0.5 re-centers by T_med - T_med = 0: exactly the k/2 rule,
        // for ANY delay history.
        assert_eq!(ring.adjustment(0.5), 0);
        assert_eq!(accept_delay_adjusted(k, delay, ring.adjustment(0.5)), plain);

        // Permissive quantiles (Q > 0.5) accept a superset of k/2;
        // strict ones (Q < 0.5) a subset. Quantile monotonicity makes
        // the adjustment sign structural, and the sign makes the
        // verdict one-directional.
        let permissive = ring.adjustment(g.f64_in(0.5, 1.0));
        assert!(permissive >= 0);
        if plain {
            assert!(
                accept_delay_adjusted(k, delay, permissive),
                "permissive quantile dropped a k/2-accepted update \
                 (k={k} delay={delay} adj={permissive})"
            );
        }
        let strict = ring.adjustment(g.f64_in(0.0, 0.5));
        assert!(strict <= 0);
        if accept_delay_adjusted(k, delay, strict) {
            assert!(
                plain,
                "strict quantile accepted a k/2-dropped update \
                 (k={k} delay={delay} adj={strict})"
            );
        }
    });
}

#[test]
fn prop_adaptive_batch_stays_in_bounds() {
    check(300, 112, |g| {
        let n = g.usize_in(1, 200);
        let workers = g.usize_in(1, 8);
        let min = g.usize_in(1, 16);
        let max = min + g.usize_in(0, 16);
        // The session ceiling the net worker computes: MAX capped so the
        // fleet's combined fan-out cannot exceed the block count.
        let cap = max.min((n / workers).max(1));
        let floor = min.min(cap).max(1);
        let mut batch = g.usize_in(1, 2 * max);
        for _ in 0..g.usize_in(1, 40) {
            let best = g.f64_in(0.0, 0.01);
            let ema = g.f64_in(0.0, 0.03);
            batch = next_batch(batch, min, cap, ema, best);
            assert!(
                (floor..=cap).contains(&batch),
                "batch {batch} escaped [{floor}, {cap}]"
            );
            if n >= workers {
                assert!(
                    batch * workers <= n.max(workers),
                    "fleet fan-out {batch}x{workers} exceeds n={n}"
                );
            }
        }
    });
}
