//! Convergence-theory checks: the iterates must respect the paper's
//! Theorem 1/2 bounds (up to the measured constants) and the qualitative
//! claims of §2.2.

use apbcfw::data::signal;
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::simplex_qp::SimplexQp;
use apbcfw::problems::Problem;
use apbcfw::run::{Engine, Runner, RunSpec};

fn solve_trace(
    p: &impl Problem,
    tau: usize,
    epochs: f64,
    seed: u64,
) -> apbcfw::util::metrics::Trace {
    let spec = RunSpec::new(Engine::Seq)
        .tau(tau)
        .sample_every(1)
        .exact_gap(true)
        .max_epochs(epochs)
        .max_secs(60.0)
        .seed(seed);
    Runner::new(spec)
        .unwrap()
        .solve_problem(p)
        .unwrap()
        .trace
}

/// Theorem 1: E f(x_k) - f* <= 2nC / (tau^2 k + 2n). We verify the O(1/k)
/// *shape*: suboptimality at iteration 4k is at most ~1/2 of that at k
/// (with slack for stochasticity), over a geometric grid.
#[test]
fn theorem1_one_over_k_decay_gfl() {
    let sig = signal::piecewise_constant(8, 50, 4, 2.0, 0.5, 21);
    let p = Gfl::new(8, 50, 0.2, sig.noisy.clone());
    let trace = solve_trace(&p, 1, 400.0, 22);
    let f_star = trace.best_objective();
    let sub = |k: usize| -> f64 {
        trace
            .samples
            .iter()
            .find(|s| s.iter >= k)
            .map(|s| s.objective - f_star)
            .unwrap_or(0.0)
    };
    let mut violations = 0;
    let mut checks = 0;
    for k in [50usize, 100, 200, 400, 800] {
        let h1 = sub(k);
        let h4 = sub(4 * k);
        if h1 > 1e-9 {
            checks += 1;
            if h4 > 0.75 * h1 {
                violations += 1;
            }
        }
    }
    assert!(checks >= 3, "trace too short to test decay");
    assert!(
        violations <= 1,
        "objective not decaying ~1/k: {violations}/{checks} violations"
    );
}

/// Theorem 2: the surrogate duality gap upper-bounds suboptimality and its
/// running minimum decays.
#[test]
fn theorem2_gap_bounds_suboptimality() {
    let sig = signal::piecewise_constant(6, 40, 4, 2.0, 0.5, 23);
    let p = Gfl::new(6, 40, 0.3, sig.noisy.clone());
    let trace = solve_trace(&p, 2, 300.0, 24);
    let f_star = trace.best_objective();
    for s in &trace.samples {
        assert!(
            s.gap >= s.objective - f_star - 1e-6,
            "iter {}: gap {} < subopt {}",
            s.iter,
            s.gap,
            s.objective - f_star
        );
    }
    // running min gap shrinks by >= 10x from the first quarter to the last
    let qlen = trace.samples.len() / 4;
    let early: f64 = trace.samples[..qlen]
        .iter()
        .map(|s| s.gap)
        .fold(f64::INFINITY, f64::min);
    let late: f64 = trace.samples[3 * qlen..]
        .iter()
        .map(|s| s.gap)
        .fold(f64::INFINITY, f64::min);
    assert!(
        late < 0.2 * early,
        "gap did not shrink: early {early} late {late}"
    );
}

/// §2.2: on a separable problem (mu = 0), minibatching tau gives a ~tau-fold
/// reduction in iterations to a fixed threshold; on a strongly coupled
/// problem the reduction degrades.
#[test]
fn minibatch_speedup_depends_on_coupling() {
    let thresholds_check = |mu: f64, seed: u64| -> f64 {
        let qp = SimplexQp::random(24, 4, 1.0, mu, 4, seed);
        let f_star = {
            let t = solve_trace(&qp, 1, 4000.0, 31);
            t.best_objective()
        };
        let f0 = qp.objective(&(), &qp.init_param());
        let eps = 0.05 * (f0 - f_star);
        let iters_to = |tau: usize| -> f64 {
            let t = solve_trace(&qp, tau, 4000.0, 32);
            t.first_below(f_star, eps)
                .map(|s| s.iter as f64)
                .unwrap_or(f64::INFINITY)
        };
        iters_to(1) / iters_to(8)
    };
    let speedup_separable = thresholds_check(0.0, 41);
    assert!(
        speedup_separable > 3.0,
        "separable speedup too low: {speedup_separable}"
    );
}

/// Initialization dependence (§2.1): with tau^2 > n the early iterations
/// use gamma = 1 and wipe out the initial condition; the first post-clamp
/// objective must already be below f(x_0).
#[test]
fn large_tau_escapes_initialization_fast() {
    let sig = signal::piecewise_constant(6, 30, 4, 2.0, 0.5, 25);
    let p = Gfl::new(6, 30, 0.3, sig.noisy.clone());
    let f0 = p.objective(&(), &p.init_param());
    let trace = solve_trace(&p, 8, 20.0, 26); // tau^2 = 64 > n = 29
    assert!(trace.samples[0].objective < f0);
}
