//! Batched worker fan-out equivalence (the batching acceptance criteria).
//!
//! Proof obligations, mirroring `runner_equivalence.rs`'s split between
//! deterministic and scheduling-nondeterministic engines:
//!
//! - **`batch = 1` must be bit-identical to the historical single-block
//!   worker path.** The batch knob touches the data path in exactly three
//!   places, and each is pinned bit-for-bit here:
//!   1. *Block sampling*: `pick_blocks(rng, n, 1, ..)` consumes the same
//!      single `below(n)` draw the legacy worker made (and `subset_into`
//!      at tau = 1 agrees), so every worker solves the identical block
//!      sequence.
//!   2. *Server pipeline*: ingesting oracles as multi-block payloads
//!      leaves the assembler in exactly the state the equivalent
//!      single-oracle messages would, and `take_batch`'s block-ordered
//!      drain makes the applied batch — and every float accumulated over
//!      it — a deterministic function of the pending set. A scripted
//!      assembler+apply pipeline over gfl and qp is compared bit-for-bit
//!      between the two message shapes.
//!   3. *End-to-end*: the sync engine at `workers = 1` is fully
//!      deterministic (seeded server sampling, barrier per round, no
//!      stragglers), so a sequential in-test replica of the legacy
//!      single-block SP-BCFW loop is compared bit-identically — final
//!      param AND full trace — against the engine on gfl and qp; and
//!      because one worker receives every chunk in order, `batch = 4`
//!      must equal `batch = 1` bit-for-bit there too. The async and
//!      lockfree engines are scheduling-nondeterministic (two legacy runs
//!      already differ), so for them the component pins above are the
//!      strongest equivalence that exists, plus convergence runs below.
//!
//! - **`batch > 1` single-worker runs match a sequential tau-minibatch
//!   reference within tolerance**: one async worker solving
//!   `batch = tau` blocks per snapshot is the paper's mini-batch update
//!   with an extra queue in the middle; both it and `minibatch::solve`
//!   are driven to surrogate gap <= eps, which bounds their objective
//!   difference by 2 eps (gap >= f - f*).

use apbcfw::coordinator::buffer::BatchAssembler;
use apbcfw::coordinator::{pick_blocks, UpdateMsg};
use apbcfw::data::signal;
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::simplex_qp::SimplexQp;
use apbcfw::problems::{
    ApplyOptions, BlockOracle, OracleScratch, PayloadKind, PayloadMode,
    Problem,
};
use apbcfw::run::{Engine, Runner, RunSpec};
use apbcfw::solver::{minibatch, schedule_gamma, StopCond};
use apbcfw::util::rng::Pcg64;

fn gfl() -> Gfl {
    let sig = signal::piecewise_constant(5, 30, 4, 2.0, 0.5, 17);
    Gfl::new(5, 30, 0.2, sig.noisy) // 29 blocks
}

fn qp() -> SimplexQp {
    SimplexQp::random(16, 4, 1.0, 0.2, 3, 18) // 16 blocks
}

// ---------------------------------------------------------------------------
// 1. Block sampling: batch = 1 consumes the legacy single draw
// ---------------------------------------------------------------------------

#[test]
fn batch1_block_sampling_is_bit_identical_to_single_draw() {
    // pick_blocks at batch = 1 must replicate the legacy `rng.below(n)`
    // worker draw exactly — same value, same stream position.
    let mut a = Pcg64::new(9, 1000);
    let mut b = Pcg64::new(9, 1000);
    let mut buf = Vec::new();
    for n in [2usize, 7, 29, 1000] {
        for _ in 0..200 {
            pick_blocks(&mut a, n, 1, &mut buf);
            assert_eq!(buf.len(), 1);
            assert_eq!(buf[0], b.below(n));
        }
    }
    // Streams remain aligned afterwards.
    assert_eq!(a.below(12345), b.below(12345));
    // And the general subset sampler agrees at tau = 1, so either spelling
    // of a 1-block round is the same draw.
    let mut c = Pcg64::new(9, 1000);
    let mut d = Pcg64::new(9, 1000);
    let mut sub = Vec::new();
    for _ in 0..200 {
        c.subset_into(29, 1, &mut sub);
        assert_eq!(sub, vec![d.below(29)]);
    }
}

#[test]
fn batched_sampling_returns_distinct_blocks() {
    let mut rng = Pcg64::new(11, 1000);
    let mut buf = Vec::new();
    for _ in 0..200 {
        pick_blocks(&mut rng, 29, 8, &mut buf);
        assert_eq!(buf.len(), 8);
        let mut sorted = buf.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8, "blocks must be pairwise distinct");
        assert!(sorted.iter().all(|&b| b < 29));
    }
}

// ---------------------------------------------------------------------------
// 2. Server pipeline: multi-block payloads == single-oracle messages
// ---------------------------------------------------------------------------

/// Drive the real server pipeline (assembler -> sorted take_batch ->
/// apply) over scripted rounds, ingesting each round's oracles either as
/// single-oracle messages (the historical shape) or grouped into
/// multi-block payloads of `group`, with payloads emitted in the given
/// representation through recycled slot containers (the worker shape).
/// Returns the final parameter and every ApplyInfo, for bit comparison.
fn run_pipeline<P: Problem>(
    p: &P,
    tau: usize,
    group: usize,
    rounds: usize,
    kind: PayloadKind,
) -> (Vec<f32>, Vec<(u32, u64)>) {
    let n = p.num_blocks();
    let mut param = p.init_param();
    let mut state = p.init_server();
    let mut asm = BatchAssembler::new();
    let mut rng = Pcg64::seeded(777);
    let mut infos = Vec::new();
    let mut oscratch = OracleScratch::<P>::default();
    // Recycle pool for payload containers, like the engines': applied and
    // displaced containers return here and are re-shaped on pickup.
    let mut pool: Vec<apbcfw::problems::OraclePayload> = Vec::new();
    let mut k: u64 = 0;
    for _ in 0..rounds {
        let blocks = rng.subset(n, tau);
        let oracles: Vec<BlockOracle> = blocks
            .iter()
            .map(|&i| {
                let mut slot = BlockOracle::empty_with(kind);
                if let Some(buf) = pool.pop() {
                    slot.s = buf;
                    slot.s.set_kind(kind);
                }
                p.oracle_into(&param, i, &mut oscratch, &mut slot);
                slot
            })
            .collect();
        for chunk in oracles.chunks(group) {
            let displaced = asm.insert(UpdateMsg {
                oracles: chunk.to_vec(),
                k_read: k,
                worker: 0,
                generation: 0,
            });
            for o in displaced {
                let mut s = o.s;
                s.recycle();
                pool.push(s);
            }
        }
        while let Some(batch) = asm.take_batch(tau) {
            let batch: Vec<BlockOracle> =
                batch.into_iter().map(|m| m.oracle).collect();
            let info = p.apply(
                &mut state,
                &mut param,
                &batch,
                ApplyOptions {
                    gamma: schedule_gamma(n, tau, k),
                    line_search: true,
                },
            );
            k += 1;
            infos.push((info.gamma.to_bits(), info.batch_gap.to_bits()));
            for o in batch {
                let mut s = o.s;
                s.recycle();
                pool.push(s);
            }
        }
    }
    (param, infos)
}

fn assert_pipeline_equivalent<P: Problem>(p: &P, tau: usize) {
    let (param1, infos1) = run_pipeline(p, tau, 1, 40, PayloadKind::Dense);
    for kind in [PayloadKind::Dense, PayloadKind::Sparse] {
        for group in [1usize, 2, 3, tau] {
            if kind == PayloadKind::Dense && group == 1 {
                continue; // the reference itself
            }
            let (param_g, infos_g) = run_pipeline(p, tau, group, 40, kind);
            assert_eq!(
                infos1, infos_g,
                "{}: ApplyInfo diverged at group={group} {kind:?}",
                p.name()
            );
            assert_eq!(param1.len(), param_g.len());
            for (j, (a, b)) in param1.iter().zip(param_g.iter()).enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{}: param[{j}] {a} vs {b} at group={group} {kind:?}",
                    p.name()
                );
            }
        }
    }
}

#[test]
fn server_pipeline_multi_block_equals_single_block_messages_gfl() {
    assert_pipeline_equivalent(&gfl(), 4);
}

#[test]
fn server_pipeline_multi_block_equals_single_block_messages_qp() {
    // QP also exercises the sparse path end-to-end: sparse payloads ride
    // the same channels/assembler/recycle pipeline and must apply
    // bit-identically to the dense reference (recycled sparse containers
    // included).
    assert_pipeline_equivalent(&qp(), 4);
}

// ---------------------------------------------------------------------------
// 3. End-to-end, deterministic regime: sync engine at workers = 1
// ---------------------------------------------------------------------------

fn stop() -> StopCond {
    StopCond {
        eps_gap: Some(0.05),
        max_epochs: 2000.0,
        max_secs: 30.0,
        ..Default::default()
    }
}

fn sync_spec(batch: usize, seed: u64) -> RunSpec {
    RunSpec::new(Engine::synchronous(1))
        .tau(4)
        .batch(batch)
        .line_search(true)
        .sample_every(8)
        .exact_gap(true)
        .stop(stop())
        .seed(seed)
}

/// Sequential replica of the legacy single-block SP-BCFW loop at
/// workers = 1: the server's seeded block sampling, the worker's
/// one-snapshot-per-round solve in assignment order, the paper step size
/// (or exact line search), and the engine's exact sampling/stop cadence.
fn sync_reference<P: Problem>(
    p: &P,
    tau: usize,
    sample_every: u64,
    stop: StopCond,
    seed: u64,
) -> (Vec<f32>, Vec<(usize, u64, u64, u64)>, u64) {
    let n = p.num_blocks();
    let tau = tau.clamp(1, n);
    let mut rng = Pcg64::new(seed, 4);
    let mut master = p.init_param();
    let mut state = p.init_server();
    let mut samples = Vec::new();
    let mut oracle_calls: u64 = 0;
    let mut k: u64 = 0;
    loop {
        // Server samples tau disjoint blocks; the single worker receives
        // every chunk, in order, and solves them all against one snapshot
        // of the just-published parameter (== master bit-for-bit: the
        // wide-word shared parameter roundtrips f32 bits exactly).
        let blocks = rng.subset(n, tau);
        let batch: Vec<BlockOracle> =
            blocks.iter().map(|&i| p.oracle(&master, i)).collect();
        oracle_calls += tau as u64;
        let gamma = schedule_gamma(n, tau, k);
        p.apply(
            &mut state,
            &mut master,
            &batch,
            ApplyOptions {
                gamma,
                line_search: true,
            },
        );
        k += 1;
        let epochs = oracle_calls as f64 / n as f64;
        if k % sample_every == 0 {
            let objective = p.objective(&state, &master);
            let gap = p.full_gap(&state, &master);
            samples.push((
                k as usize,
                oracle_calls,
                objective.to_bits(),
                gap.to_bits(),
            ));
            if stop.target_met(objective, gap) || stop.exhausted(epochs, 0.0)
            {
                break;
            }
        }
        if stop.exhausted(epochs, 0.0) {
            break;
        }
    }
    // The engine appends one final sample after the serve loop.
    let objective = p.objective(&state, &master);
    let gap = p.full_gap(&state, &master);
    samples.push((
        k as usize,
        oracle_calls,
        objective.to_bits(),
        gap.to_bits(),
    ));
    (master, samples, k)
}

fn assert_sync_batch1_matches_reference<P: Problem>(p: &P) {
    let report = Runner::new(sync_spec(1, 45))
        .unwrap()
        .solve_problem(p)
        .unwrap();
    let (ref_param, ref_samples, ref_k) =
        sync_reference(p, 4, 8, stop(), 45);
    assert_eq!(report.iterations(), ref_k, "{}: iterations", p.name());
    assert_eq!(report.param.len(), ref_param.len());
    for (j, (a, b)) in report.param.iter().zip(ref_param.iter()).enumerate()
    {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{}: param[{j}] {a} vs {b}",
            p.name()
        );
    }
    assert_eq!(
        report.trace.samples.len(),
        ref_samples.len(),
        "{}: trace length",
        p.name()
    );
    for (s, (iter, calls, obj, gap)) in
        report.trace.samples.iter().zip(ref_samples.iter())
    {
        assert_eq!(s.iter, *iter, "{}: sample iter", p.name());
        assert_eq!(s.oracle_calls, *calls, "{}: sample calls", p.name());
        assert_eq!(
            s.objective.to_bits(),
            *obj,
            "{}: sample objective",
            p.name()
        );
        assert_eq!(s.gap.to_bits(), *gap, "{}: sample gap", p.name());
    }
}

#[test]
fn sync_batch1_bit_identical_to_single_block_reference_gfl() {
    assert_sync_batch1_matches_reference(&gfl());
}

#[test]
fn sync_batch1_bit_identical_to_single_block_reference_qp() {
    assert_sync_batch1_matches_reference(&qp());
}

#[test]
fn sync_single_worker_sparse_payload_bit_identical_to_dense() {
    // The sync engine at workers = 1 is fully deterministic, so forcing
    // run.payload=sparse vs =dense must agree to the bit — final param
    // AND full trace — on a sparse-emitting problem (QP), through the
    // real worker/channel/pool/apply pipeline. `auto` resolves to sparse
    // here and must match too.
    let p = qp();
    let runs: Vec<_> = [PayloadMode::Dense, PayloadMode::Sparse, PayloadMode::Auto]
        .into_iter()
        .map(|mode| {
            Runner::new(sync_spec(1, 47).payload(mode))
                .unwrap()
                .solve_problem(&p)
                .unwrap()
        })
        .collect();
    for r in &runs[1..] {
        assert_eq!(runs[0].param.len(), r.param.len());
        for (j, (a, b)) in runs[0].param.iter().zip(r.param.iter()).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "param[{j}] {a} vs {b}");
        }
        assert_eq!(runs[0].trace.samples.len(), r.trace.samples.len());
        for (a, b) in runs[0].trace.samples.iter().zip(r.trace.samples.iter())
        {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.objective.to_bits(), b.objective.to_bits());
            assert_eq!(a.gap.to_bits(), b.gap.to_bits());
        }
    }
}

#[test]
fn sync_single_worker_batch4_bit_identical_to_batch1() {
    // With one worker, every chunk lands on it in order, so the chunked
    // assignment is the identity regardless of batch — the two runs must
    // agree to the bit (each run is deterministic at workers = 1).
    let p = gfl();
    let r1 = Runner::new(sync_spec(1, 46))
        .unwrap()
        .solve_problem(&p)
        .unwrap();
    let r4 = Runner::new(sync_spec(4, 46))
        .unwrap()
        .solve_problem(&p)
        .unwrap();
    assert_eq!(r1.param, r4.param);
    assert_eq!(r1.trace.samples.len(), r4.trace.samples.len());
    for (a, b) in r1.trace.samples.iter().zip(r4.trace.samples.iter()) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.gap.to_bits(), b.gap.to_bits());
    }
}

// ---------------------------------------------------------------------------
// batch > 1 vs the sequential tau-minibatch reference (within tolerance)
// ---------------------------------------------------------------------------

fn assert_batched_async_matches_minibatch<P: Problem>(p: &P, eps: f64) {
    // One async worker pulling batch = tau blocks per snapshot IS a
    // minibatch step modulo queue staleness; drive both to exact gap <=
    // eps, which bounds each objective within eps of f*.
    let spec = RunSpec::new(Engine::asynchronous(1))
        .tau(4)
        .batch(4)
        .line_search(true)
        .sample_every(8)
        .exact_gap(true)
        .stop(StopCond {
            eps_gap: Some(eps),
            max_epochs: 4000.0,
            max_secs: 30.0,
            ..Default::default()
        })
        .seed(7);
    let r = Runner::new(spec).unwrap().solve_problem(p).unwrap();
    let seq = minibatch::solve(
        p,
        &RunSpec::new(Engine::Seq)
            .tau(4)
            .line_search(true)
            .sample_every(8)
            .exact_gap(true)
            .stop(StopCond {
                eps_gap: Some(eps),
                max_epochs: 4000.0,
                max_secs: 30.0,
                ..Default::default()
            })
            .seed(7)
            .solve_options(),
    );
    let (fa, ga) = {
        let s = r.last().unwrap();
        (s.objective, s.gap)
    };
    let (fs, gs) = {
        let s = seq.trace.last().unwrap();
        (s.objective, s.gap)
    };
    assert!(ga <= eps, "{}: async gap {ga}", p.name());
    assert!(gs <= eps, "{}: seq gap {gs}", p.name());
    // gap >= f - f*  =>  |f_async - f_seq| <= 2 eps.
    assert!(
        (fa - fs).abs() <= 2.0 * eps + 1e-9,
        "{}: async f={fa} vs minibatch f={fs}",
        p.name()
    );
}

#[test]
fn async_batched_single_worker_matches_minibatch_gfl() {
    assert_batched_async_matches_minibatch(&gfl(), 0.05);
}

#[test]
fn async_batched_single_worker_matches_minibatch_qp() {
    assert_batched_async_matches_minibatch(&qp(), 0.05);
}

#[test]
fn lockfree_batched_single_worker_converges() {
    let p = gfl();
    let spec = RunSpec::new(Engine::lockfree(1))
        .batch(4)
        .sample_every(32)
        .exact_gap(true)
        .stop(StopCond {
            eps_gap: Some(0.1),
            max_epochs: 4000.0,
            max_secs: 30.0,
            ..Default::default()
        })
        .seed(8);
    let r = Runner::new(spec)
        .unwrap()
        .solve_projectable(&p)
        .unwrap();
    assert!(
        r.last().unwrap().gap <= 0.1,
        "gap={}",
        r.last().unwrap().gap
    );
}
