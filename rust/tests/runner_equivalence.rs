//! Seeded equivalence: the unified `Runner` path must reproduce the legacy
//! per-engine entry points exactly, so rewiring the experiments through
//! `RunSpec` cannot silently change paper figures.
//!
//! Proof obligations per engine family:
//!
//! - **Sequential engines** (seq, batch, delayed, pbcd) are deterministic
//!   given a seed, so we run the legacy entry point with hand-built
//!   options AND the `Runner` with the equivalent spec, then compare the
//!   final/raw parameters and the whole trace **bit-identically**
//!   (`f64::to_bits` on objectives/gaps).
//! - **Threaded engines** (async, sync, lockfree) are scheduling-
//!   nondeterministic — two legacy runs already differ — so bit-equality
//!   between runs is not a meaningful claim. There the `Runner` path *is*
//!   the legacy function invoked with a lowered `RunConfig`; we prove the
//!   lowering is field-for-field identical to the hand-built legacy
//!   config (`RunConfig: PartialEq`) and that the `Runner` run completes
//!   and converges. Identical config + identical code path is the
//!   strongest equivalence that exists for these engines.
//!
//! This file (plus `rust/src/run/`) is the only place allowed to construct
//! `SolveOptions`/`RunConfig` directly.

use apbcfw::coordinator::{apbcfw as coord, lockfree, sync, RunConfig};
use apbcfw::data::{mixture, ocr_like, signal};
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::simplex_qp::SimplexQp;
use apbcfw::problems::ssvm::chain::ChainSsvm;
use apbcfw::problems::ssvm::multiclass::MulticlassSsvm;
use apbcfw::problems::PayloadMode;
use apbcfw::run::{
    CollectObserver, Engine, ProblemInstance, Report, Runner, RunSpec,
    StragglerSpec,
};
use apbcfw::sim::adapt::{AdaptSpec, StepPolicy};
use apbcfw::sim::delay::DelayModel;
use apbcfw::sim::straggler::StragglerModel;
use apbcfw::solver::delayed::DelayOptions;
use apbcfw::solver::{batch_fw, delayed, minibatch, pbcd, SolveOptions, StopCond};
use apbcfw::util::config::Config;
use std::sync::Arc;

// ---------- small instances (one per problem family) ----------

fn gfl() -> Gfl {
    let sig = signal::piecewise_constant(5, 30, 4, 2.0, 0.5, 17);
    Gfl::new(5, 30, 0.2, sig.noisy)
}

fn qp() -> SimplexQp {
    SimplexQp::random(16, 4, 1.0, 0.2, 3, 18)
}

fn chain() -> ChainSsvm {
    let data = Arc::new(ocr_like::generate(20, 3, 6, 4, 0.1, 19));
    ChainSsvm::new(data, 0.1)
}

fn multiclass() -> MulticlassSsvm {
    let data = Arc::new(mixture::generate(24, 3, 6, 0.1, 20));
    MulticlassSsvm::new(data, 0.1)
}

// ---------- shared knobs, built both ways ----------

fn stop() -> StopCond {
    StopCond {
        max_epochs: 15.0,
        max_secs: 30.0,
        ..Default::default()
    }
}

/// Legacy options matching `spec(engine)` below.
fn legacy_opts(tau: usize) -> SolveOptions {
    SolveOptions {
        tau,
        payload: PayloadMode::Auto,
        line_search: true,
        weighted_averaging: false,
        sample_every: 4,
        exact_gap: true,
        stop: stop(),
        seed: 33,
    }
}

/// The unified spec whose lowering must equal `legacy_opts(tau)`.
fn spec(engine: Engine, tau: usize) -> RunSpec {
    RunSpec::new(engine)
        .tau(tau)
        .line_search(true)
        .sample_every(4)
        .exact_gap(true)
        .stop(stop())
        .seed(33)
}

/// Bit-identical comparison of a Runner report vs a legacy solve result.
fn assert_bit_identical(
    label: &str,
    report: &Report,
    legacy: &apbcfw::solver::SolveResult,
) {
    assert_eq!(report.param, legacy.param, "{label}: param");
    assert_eq!(report.raw_param, legacy.raw_param, "{label}: raw_param");
    assert_eq!(report.oracle_calls(), legacy.oracle_calls, "{label}: calls");
    assert_eq!(report.iterations(), legacy.iterations, "{label}: iters");
    assert_eq!(report.dropped(), legacy.dropped, "{label}: dropped");
    assert_eq!(
        report.trace.samples.len(),
        legacy.trace.samples.len(),
        "{label}: trace length"
    );
    for (i, (a, b)) in report
        .trace
        .samples
        .iter()
        .zip(legacy.trace.samples.iter())
        .enumerate()
    {
        assert_eq!(a.iter, b.iter, "{label}: sample {i} iter");
        assert_eq!(
            a.oracle_calls, b.oracle_calls,
            "{label}: sample {i} oracle_calls"
        );
        assert_eq!(
            a.objective.to_bits(),
            b.objective.to_bits(),
            "{label}: sample {i} objective"
        );
        assert_eq!(
            a.gap.to_bits(),
            b.gap.to_bits(),
            "{label}: sample {i} gap"
        );
    }
}

// ---------- sequential engines: bit-identical runs ----------

#[test]
fn seq_engine_matches_minibatch_on_all_problem_families() {
    let tau = 2;
    let opts = legacy_opts(tau);
    let runner = Runner::new(spec(Engine::Seq, tau)).unwrap();

    let p = gfl();
    assert_bit_identical(
        "seq/gfl",
        &runner.solve_problem(&p).unwrap(),
        &minibatch::solve(&p, &opts),
    );
    let p = qp();
    assert_bit_identical(
        "seq/qp",
        &runner.solve_problem(&p).unwrap(),
        &minibatch::solve(&p, &opts),
    );
    let p = chain();
    assert_bit_identical(
        "seq/ssvm",
        &runner.solve_problem(&p).unwrap(),
        &minibatch::solve(&p, &opts),
    );
    let p = multiclass();
    assert_bit_identical(
        "seq/multiclass",
        &runner.solve_problem(&p).unwrap(),
        &minibatch::solve(&p, &opts),
    );
}

#[test]
fn seq_engine_matches_with_weighted_averaging() {
    let mut opts = legacy_opts(1);
    opts.weighted_averaging = true;
    let runner =
        Runner::new(spec(Engine::Seq, 1).weighted_averaging(true)).unwrap();
    let p = chain();
    assert_bit_identical(
        "seq+avg/ssvm",
        &runner.solve_problem(&p).unwrap(),
        &minibatch::solve(&p, &opts),
    );
}

#[test]
fn batch_engine_matches_batch_fw() {
    let opts = legacy_opts(1);
    let runner = Runner::new(spec(Engine::Batch, 1)).unwrap();
    let p = gfl();
    assert_bit_identical(
        "batch/gfl",
        &runner.solve_problem(&p).unwrap(),
        &batch_fw::solve(&p, &opts),
    );
    let p = qp();
    assert_bit_identical(
        "batch/qp",
        &runner.solve_problem(&p).unwrap(),
        &batch_fw::solve(&p, &opts),
    );
}

#[test]
fn delayed_engine_matches_delayed_solver() {
    let model = DelayModel::Poisson { kappa: 3.0 };
    let dopts = DelayOptions {
        model,
        history: 256,
        enforce_drop_rule: true,
        adapt: AdaptSpec::default(),
    };
    let engine = Engine::delayed(model).with_delay_history(256);
    let runner = Runner::new(spec(engine.clone(), 2)).unwrap();
    // The spec's delay lowering is exactly the hand-built DelayOptions.
    assert_eq!(spec(engine, 2).delay_options().unwrap(), dopts);

    let opts = legacy_opts(2);
    let p = gfl();
    assert_bit_identical(
        "delayed/gfl",
        &runner.solve_problem(&p).unwrap(),
        &delayed::solve(&p, &opts, &dopts),
    );
    let p = chain();
    assert_bit_identical(
        "delayed/ssvm",
        &runner.solve_problem(&p).unwrap(),
        &delayed::solve(&p, &opts, &dopts),
    );
}

#[test]
fn pbcd_engine_matches_pbcd_solver() {
    // pbcd has no line search (validate rejects it), so both paths run
    // with it off — matching the legacy d4 experiment's o_bcd config.
    let mut opts = legacy_opts(3);
    opts.line_search = false;
    let runner =
        Runner::new(spec(Engine::Pbcd, 3).line_search(false)).unwrap();
    let p = qp();
    assert_bit_identical(
        "pbcd/qp",
        &runner.solve_projectable(&p).unwrap(),
        &pbcd::solve(&p, &opts),
    );
    let p = gfl();
    assert_bit_identical(
        "pbcd/gfl",
        &runner.solve_projectable(&p).unwrap(),
        &pbcd::solve(&p, &opts),
    );
}

// ---------- threaded engines: lowering equality + live run ----------

fn threaded_stop() -> StopCond {
    StopCond {
        eps_gap: Some(0.1),
        max_epochs: 4000.0,
        max_secs: 30.0,
        ..Default::default()
    }
}

#[test]
fn async_spec_lowers_to_legacy_run_config_and_converges() {
    let legacy = RunConfig {
        workers: 3,
        tau: 4,
        line_search: true,
        straggler: StragglerModel::single(3, 0.5),
        sample_every: 8,
        exact_gap: true,
        queue_factor: 16,
        stop: threaded_stop(),
        seed: 44,
        ..Default::default()
    };
    let spec = RunSpec::new(
        Engine::asynchronous(3)
            .with_straggler(StragglerSpec::Single { p: 0.5 })
            .with_queue_factor(16),
    )
    .tau(4)
    .line_search(true)
    .sample_every(8)
    .exact_gap(true)
    .stop(threaded_stop())
    .seed(44);
    assert_eq!(spec.run_config().unwrap(), legacy);

    // Identical config + shared code path (`run` delegates to
    // `run_observed`): the runner run must still converge like a direct
    // coord::run with this config would.
    let p = gfl();
    let r = Runner::new(spec).unwrap().solve_problem(&p).unwrap();
    assert!(r.last().unwrap().gap <= 0.1, "gap={}", r.last().unwrap().gap);
    let direct = coord::run(&p, &legacy);
    assert!(direct.trace.last().unwrap().gap <= 0.1);
}

#[test]
fn sync_spec_lowers_to_legacy_run_config_and_converges() {
    let legacy = RunConfig {
        workers: 2,
        tau: 3,
        line_search: true,
        straggler: StragglerModel::none(2),
        sample_every: 8,
        exact_gap: true,
        stop: threaded_stop(),
        seed: 45,
        ..Default::default()
    };
    let spec = RunSpec::new(Engine::synchronous(2))
        .tau(3)
        .line_search(true)
        .sample_every(8)
        .exact_gap(true)
        .stop(threaded_stop())
        .seed(45);
    assert_eq!(spec.run_config().unwrap(), legacy);

    let p = gfl();
    let r = Runner::new(spec).unwrap().solve_problem(&p).unwrap();
    assert!(r.last().unwrap().gap <= 0.1);
    let direct = sync::run(&p, &legacy);
    assert!(direct.trace.last().unwrap().gap <= 0.1);
}

#[test]
fn lockfree_spec_lowers_to_legacy_run_config_and_converges() {
    let legacy = RunConfig {
        workers: 2,
        tau: 1,
        straggler: StragglerModel::none(2),
        sample_every: 32,
        exact_gap: true,
        stop: threaded_stop(),
        seed: 46,
        ..Default::default()
    };
    let spec = RunSpec::new(Engine::lockfree(2))
        .sample_every(32)
        .exact_gap(true)
        .stop(threaded_stop())
        .seed(46);
    assert_eq!(spec.run_config().unwrap(), legacy);

    let p = gfl();
    let r = Runner::new(spec).unwrap().solve_projectable(&p).unwrap();
    assert!(r.last().unwrap().gap <= 0.2, "gap={}", r.last().unwrap().gap);
    let direct = lockfree::run(&p, &legacy);
    assert!(direct.trace.last().unwrap().gap <= 0.2);
}

// ---------- observer: live samples during a run ----------

#[test]
fn observer_receives_live_samples_and_applies_seq() {
    let p = gfl();
    let mut obs = CollectObserver::new();
    let r = Runner::new(spec(Engine::Seq, 2))
        .unwrap()
        .solve_problem_observed(&p, &mut obs)
        .unwrap();
    // Every trace sample was streamed live, in order.
    assert_eq!(obs.samples.len(), r.trace.samples.len());
    for (a, b) in obs.samples.iter().zip(r.trace.samples.iter()) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }
    // One apply event per server iteration, with a usable step size.
    assert_eq!(obs.applies.len(), r.iterations() as usize);
    assert!(obs.applies.iter().all(|(_, g, _)| (0.0..=1.0).contains(g)));
}

#[test]
fn observer_receives_live_samples_async() {
    let p = gfl();
    let mut obs = CollectObserver::new();
    let spec = RunSpec::new(Engine::asynchronous(2))
        .tau(2)
        .sample_every(8)
        .exact_gap(true)
        .stop(threaded_stop())
        .seed(47);
    let r = Runner::new(spec)
        .unwrap()
        .solve_problem_observed(&p, &mut obs)
        .unwrap();
    assert!(!obs.samples.is_empty());
    assert_eq!(obs.samples.len(), r.trace.samples.len());
    assert!(!obs.applies.is_empty());
}

// ---------- run.batch: lowering + validation ----------

#[test]
fn default_batch_lowering_is_field_for_field_unchanged() {
    // A spec that never mentions batch must lower to the legacy config
    // exactly — batch = 1 is the historical single-block worker, and the
    // PartialEq covers every RunConfig field including the new one.
    let legacy = RunConfig {
        workers: 3,
        tau: 4,
        stop: threaded_stop(),
        straggler: StragglerModel::none(3),
        seed: 50,
        ..Default::default()
    };
    assert_eq!(legacy.batch, 1, "legacy default is single-block");
    let spec = RunSpec::new(Engine::asynchronous(3))
        .tau(4)
        .stop(threaded_stop())
        .seed(50);
    assert_eq!(spec.run_config().unwrap(), legacy);
    // Same from the config path.
    let cfg = Config::parse("[run]\nmode = async\nworkers = 3\ntau = 4\n")
        .unwrap();
    assert_eq!(RunSpec::from_config(&cfg).unwrap().batch, 1);
}

#[test]
fn batch_lowers_into_run_config_for_all_threaded_engines() {
    let cfg = Config::parse(
        "[run]\nmode = async\nworkers = 2\ntau = 4\nbatch = 4\n",
    )
    .unwrap();
    let spec = RunSpec::from_config(&cfg).unwrap();
    assert_eq!(spec.batch, 4);
    assert_eq!(spec.run_config().unwrap().batch, 4);
    for engine in
        [Engine::asynchronous(2), Engine::synchronous(2), Engine::lockfree(2)]
    {
        let spec = RunSpec::new(engine).batch(3);
        assert_eq!(spec.run_config().unwrap().batch, 3);
    }
}

#[test]
fn batch_rejected_on_sequential_engines() {
    // Builder path: validate (via Runner::new) refuses batch > 1 off the
    // threaded family.
    for engine in
        [Engine::Seq, Engine::Batch, Engine::delayed(DelayModel::None), Engine::pbcd()]
    {
        let name = engine.name();
        let err = Runner::new(RunSpec::new(engine).batch(2))
            .err()
            .expect("must be rejected")
            .to_string();
        assert!(err.contains("threaded"), "{name}: {err}");
    }
    // Config path: run.batch is an engine-scoped key, rejected outright on
    // sequential modes even at its default value.
    for mode in ["seq", "batch", "delayed", "pbcd"] {
        let cfg =
            Config::parse(&format!("[run]\nmode = {mode}\nbatch = 2\n"))
                .unwrap();
        let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("run.batch"), "{mode}: {err}");
    }
}

#[test]
fn batch_times_workers_above_n_is_rejected_at_dispatch() {
    // Only the Runner holds the problem, so the n-dependent half of the
    // validation errors there (not in validate, not in the engine assert).
    let p = gfl(); // 29 blocks
    let spec = RunSpec::new(Engine::asynchronous(8))
        .tau(4)
        .batch(4) // 8 x 4 = 32 > 29
        .stop(threaded_stop());
    let runner = Runner::new(spec).unwrap(); // spec alone is fine
    let err = runner.solve_problem(&p).unwrap_err().to_string();
    assert!(err.contains("exceeds"), "{err}");
    assert!(err.contains("29"), "{err}");
    // The same fleet on a big enough problem is accepted.
    let spec = RunSpec::new(Engine::asynchronous(2))
        .tau(4)
        .batch(4) // 2 x 4 = 8 <= 29
        .exact_gap(true)
        .sample_every(8)
        .stop(threaded_stop());
    let r = Runner::new(spec).unwrap().solve_problem(&p).unwrap();
    assert!(r.last().unwrap().gap <= 0.1);
}

// ---------- spec hygiene: straggler arity & registry errors ----------

#[test]
fn straggler_model_size_follows_worker_count() {
    for workers in [1usize, 2, 5] {
        let spec = RunSpec::new(
            Engine::asynchronous(workers)
                .with_straggler(StragglerSpec::Heterogeneous { theta: 0.3 }),
        );
        let cfg = spec.run_config().unwrap();
        assert_eq!(cfg.straggler.probs.len(), workers);
        assert_eq!(cfg.straggler, StragglerModel::heterogeneous(workers, 0.3));
    }
}

#[test]
fn mismatched_explicit_straggler_is_rejected_not_asserted() {
    // The historical footgun: RunConfig::default() pairs a 2-worker
    // straggler model with whatever `workers` the caller overrides,
    // panicking inside the engine. The spec builder turns this into a
    // validation error instead.
    let spec = RunSpec::new(Engine::asynchronous(4).with_straggler(
        StragglerSpec::Explicit(StragglerModel::none(2)),
    ));
    let err = Runner::new(spec).err().expect("must be rejected");
    let msg = err.to_string();
    assert!(msg.contains("straggler"), "{msg}");
    assert!(msg.contains('2') && msg.contains('4'), "{msg}");
}

#[test]
fn registry_rejects_parameter_space_engines_for_ssvm() {
    let cfg = Config::parse(
        "[run]\nseed = 2\n[ssvm]\nn = 12\nk = 3\nd = 6\nell = 4\n\
         [multiclass]\nn = 12\nk = 3\nd = 6\n",
    )
    .unwrap();
    for problem in ["ssvm", "multiclass"] {
        let instance = ProblemInstance::from_config(problem, &cfg).unwrap();
        for engine in [Engine::pbcd(), Engine::lockfree(2)] {
            let runner = Runner::new(
                RunSpec::new(engine).max_epochs(1.0).max_secs(5.0),
            )
            .unwrap();
            let err = runner.solve(&instance).unwrap_err().to_string();
            assert!(
                err.contains("parameter-space"),
                "{problem}: {err}"
            );
        }
    }
}

// ---------- run.payload: lowering + validation + equivalence ----------

#[test]
fn payload_lowers_into_both_option_families() {
    let cfg = Config::parse(
        "[run]\nmode = async\nworkers = 2\ntau = 2\npayload = sparse\n",
    )
    .unwrap();
    let spec = RunSpec::from_config(&cfg).unwrap();
    assert_eq!(spec.payload, PayloadMode::Sparse);
    assert_eq!(spec.run_config().unwrap().payload, PayloadMode::Sparse);
    let cfg =
        Config::parse("[run]\nmode = seq\npayload = dense\n").unwrap();
    let spec = RunSpec::from_config(&cfg).unwrap();
    assert_eq!(spec.solve_options().payload, PayloadMode::Dense);
    // Default lowering carries Auto — field-for-field equal to the legacy
    // defaults (covered by RunConfig/SolveOptions PartialEq elsewhere).
    assert_eq!(RunConfig::default().payload, PayloadMode::Auto);
    assert_eq!(SolveOptions::default().payload, PayloadMode::Auto);
}

#[test]
fn invalid_payload_value_is_rejected_at_parse() {
    let cfg = Config::parse("[run]\nmode = seq\npayload = csc\n").unwrap();
    let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
    assert!(err.contains("run.payload"), "{err}");
}

#[test]
fn seq_engines_payload_sparse_bit_identical_to_dense() {
    // The deterministic sequential engines must produce bit-identical
    // runs under every payload mode, on both sparse-emitting problem
    // families (QP: 1-hot vertices; multiclass: two-class-row payloads).
    // This is the engine-level pin of the representation contract; GFL is
    // the dense-fallback proof (sparse request → dense payloads).
    fn run_modes<P: apbcfw::problems::Problem>(
        p: &P,
        engine: Engine,
    ) -> Vec<Report> {
        [PayloadMode::Dense, PayloadMode::Sparse, PayloadMode::Auto]
            .into_iter()
            .map(|m| {
                Runner::new(spec(engine.clone(), 2).payload(m))
                    .unwrap()
                    .solve_problem(p)
                    .unwrap()
            })
            .collect()
    }
    let qp = qp();
    let mc = multiclass();
    let g = gfl();
    let mut reports = Vec::new();
    for engine in [Engine::Seq, Engine::Batch, Engine::delayed(DelayModel::Fixed(1))]
    {
        reports.push((format!("qp/{}", engine.name()), run_modes(&qp, engine.clone())));
        reports.push((format!("mc/{}", engine.name()), run_modes(&mc, engine.clone())));
        reports.push((format!("gfl/{}", engine.name()), run_modes(&g, engine)));
    }
    for (label, rs) in &reports {
        for r in &rs[1..] {
            assert_eq!(rs[0].param.len(), r.param.len(), "{label}");
            for (j, (a, b)) in
                rs[0].param.iter().zip(r.param.iter()).enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{label}: param[{j}] {a} vs {b}"
                );
            }
            assert_eq!(
                rs[0].trace.samples.len(),
                r.trace.samples.len(),
                "{label}: trace length"
            );
            for (sa, sb) in
                rs[0].trace.samples.iter().zip(r.trace.samples.iter())
            {
                assert_eq!(sa.iter, sb.iter, "{label}");
                assert_eq!(
                    sa.objective.to_bits(),
                    sb.objective.to_bits(),
                    "{label}: objective {} vs {}",
                    sa.objective,
                    sb.objective
                );
                assert_eq!(
                    sa.gap.to_bits(),
                    sb.gap.to_bits(),
                    "{label}: gap {} vs {}",
                    sa.gap,
                    sb.gap
                );
            }
        }
    }
}

// ---------- run.adapt.*: fixed-delay pins + default bit-identity ----------

#[test]
fn kappa_damping_is_constant_and_exact_under_fixed_delay() {
    // Under a constant injected delay the EMA is seeded at exactly that
    // delay by the first applied update and never moves, so every apply
    // uses the same damping factor. With tau = 1 and Fixed(3) the factor
    // is exactly tau/(tau+3) = 0.25 — a power of two, so the damped
    // gamma is the undamped one scaled bit-exactly.
    let p = gfl();
    let engine = Engine::delayed(DelayModel::Fixed(3));
    let run = |adapt: AdaptSpec| {
        let mut obs = CollectObserver::new();
        let r = Runner::new(
            spec(engine.clone(), 1).line_search(false).adapt(adapt),
        )
        .unwrap()
        .solve_problem_observed(&p, &mut obs)
        .unwrap();
        (r, obs)
    };
    let (off, obs_off) = run(AdaptSpec::default());
    let (kap, obs_kap) = run(AdaptSpec {
        step: StepPolicy::Kappa,
        ..Default::default()
    });

    // Same seed, same delay draws, same k/2 verdicts: the apply streams
    // align one-to-one (only the step size differs).
    assert_eq!(obs_off.applies.len(), obs_kap.applies.len());
    assert!(!obs_kap.applies.is_empty());
    for ((iter_o, g_o, _), (iter_k, g_k, _)) in
        obs_off.applies.iter().zip(obs_kap.applies.iter())
    {
        assert_eq!(iter_o, iter_k, "apply streams must align");
        let expected = (f64::from(*g_o) * 0.25) as f32;
        assert_eq!(
            g_k.to_bits(),
            expected.to_bits(),
            "damping must be exactly 0.25 at every apply \
             (off gamma {g_o}, kappa gamma {g_k})"
        );
    }
    // Telemetry accounting: 750 damping-deficit permille per applied
    // update, no adaptive drops (the drop policy stayed k2), and an
    // untouched off run.
    assert_eq!(
        kap.counters.gamma_damped_sum,
        750 * kap.counters.updates_applied
    );
    assert_eq!(kap.counters.drops_adaptive, 0);
    assert_eq!(off.counters.gamma_damped_sum, 0);
    assert_eq!(off.counters.drops_adaptive, 0);
}

#[test]
fn default_adapt_runs_bit_identical_to_adapt_free_legacy_paths() {
    // The non-negotiable pin of the adaptive layer: with run.adapt.* at
    // its defaults (off/k2/off) every deterministic engine reproduces
    // the legacy entry points bit-for-bit, on both problem families. An
    // explicit all-off AdaptSpec must be indistinguishable from never
    // mentioning adapt at all.
    let opts = legacy_opts(2);
    let dopts = DelayOptions {
        model: DelayModel::Poisson { kappa: 3.0 },
        history: 256,
        enforce_drop_rule: true,
        adapt: AdaptSpec::default(),
    };
    pin_engines(&gfl(), &opts, &dopts, "gfl");
    pin_engines(&qp(), &opts, &dopts, "qp");

    fn pin_engines<P: apbcfw::problems::Problem>(
        p: &P,
        opts: &SolveOptions,
        dopts: &DelayOptions,
        name: &str,
    ) {
        let explicit_off = AdaptSpec::default();
        let seq = Runner::new(
            spec(Engine::Seq, 2).adapt(explicit_off),
        )
        .unwrap()
        .solve_problem(p)
        .unwrap();
        assert_bit_identical(
            &format!("adapt-off seq/{name}"),
            &seq,
            &minibatch::solve(p, opts),
        );
        assert_eq!(seq.counters.gamma_damped_sum, 0);

        let batch = Runner::new(
            spec(Engine::Batch, 1).adapt(explicit_off),
        )
        .unwrap()
        .solve_problem(p)
        .unwrap();
        let mut bopts = opts.clone();
        bopts.tau = 1;
        assert_bit_identical(
            &format!("adapt-off batch/{name}"),
            &batch,
            &batch_fw::solve(p, &bopts),
        );

        let engine = Engine::delayed(dopts.model).with_delay_history(256);
        let del = Runner::new(spec(engine, 2).adapt(explicit_off))
            .unwrap()
            .solve_problem(p)
            .unwrap();
        assert_bit_identical(
            &format!("adapt-off delayed/{name}"),
            &del,
            &delayed::solve(p, opts, dopts),
        );
        assert_eq!(del.counters.gamma_damped_sum, 0);
        assert_eq!(del.counters.drops_adaptive, 0);
    }

    // The async engine is scheduling-nondeterministic, so its pin is the
    // strongest available: an adapt-less spec lowers field-for-field to
    // the legacy RunConfig (whose PartialEq covers the new adapt field
    // at its default).
    let legacy = RunConfig {
        workers: 2,
        tau: 4,
        stop: threaded_stop(),
        straggler: StragglerModel::none(2),
        seed: 51,
        ..Default::default()
    };
    assert_eq!(legacy.adapt, AdaptSpec::default());
    let spec = RunSpec::new(Engine::asynchronous(2))
        .tau(4)
        .stop(threaded_stop())
        .seed(51);
    assert_eq!(spec.run_config().unwrap(), legacy);
}

#[test]
fn registry_dispatch_matches_generic_path_bit_identically() {
    // Solving through the registry (ProblemInstance) and through the
    // generic solve_problem path must be the same computation.
    let cfg = Config::parse(
        "[run]\nseed = 17\n[gfl]\nd = 5\nn = 30\nlambda = 0.2\n",
    )
    .unwrap();
    let instance = ProblemInstance::from_config("gfl", &cfg).unwrap();
    let runner = Runner::new(spec(Engine::Seq, 2)).unwrap();
    let via_registry = runner.solve(&instance).unwrap();
    let ProblemInstance::Gfl(ref p) = instance else {
        panic!("expected gfl")
    };
    let direct = runner.solve_problem(p).unwrap();
    assert_eq!(via_registry.param, direct.param);
    assert_eq!(via_registry.oracle_calls(), direct.oracle_calls());
}
