//! End-to-end coordinator integration: all execution modes solve the same
//! instances to comparable quality, counters are consistent, and the
//! straggler/delay machinery behaves as the paper describes.

use apbcfw::coordinator::{apbcfw as coord, lockfree, sync};
use apbcfw::data::{mixture, ocr_like, signal};
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::simplex_qp::SimplexQp;
use apbcfw::problems::ssvm::chain::ChainSsvm;
use apbcfw::problems::ssvm::multiclass::MulticlassSsvm;
use apbcfw::problems::Problem;
use apbcfw::run::{Engine, RunSpec, StragglerSpec};
use apbcfw::sim::delay::DelayModel;
use apbcfw::solver::delayed::{self, DelayOptions};
use apbcfw::solver::{batch_fw, minibatch, StopCond};
use apbcfw::util::rng::Pcg64;
use std::sync::Arc;

fn gfl_instance(seed: u64) -> Gfl {
    let sig = signal::piecewise_constant(8, 60, 5, 2.0, 0.5, seed);
    Gfl::new(8, 60, 0.1, sig.noisy.clone())
}

fn stop_gap(eps: f64) -> StopCond {
    StopCond {
        eps_gap: Some(eps),
        max_epochs: 20_000.0,
        max_secs: 60.0,
        ..Default::default()
    }
}

#[test]
fn all_modes_reach_same_quality_on_gfl() {
    let p = gfl_instance(1);
    let eps = 0.05;

    let seq = minibatch::solve(
        &p,
        &RunSpec::new(Engine::Seq)
            .tau(4)
            .sample_every(16)
            .exact_gap(true)
            .stop(stop_gap(eps))
            .seed(2)
            .solve_options(),
    );
    assert!(seq.trace.last().unwrap().gap <= eps);

    let mk_cfg = |engine: Engine| {
        RunSpec::new(engine)
            .tau(4)
            .sample_every(16)
            .exact_gap(true)
            .stop(stop_gap(eps))
            .seed(3)
            .run_config()
            .unwrap()
    };
    let a = coord::run(&p, &mk_cfg(Engine::asynchronous(3)));
    assert!(a.trace.last().unwrap().gap <= eps, "async");
    let s = sync::run(&p, &mk_cfg(Engine::synchronous(3)));
    assert!(s.trace.last().unwrap().gap <= eps, "sync");
    let lf = lockfree::run(&p, &mk_cfg(Engine::lockfree(2)));
    assert!(
        lf.trace.last().unwrap().gap <= 2.0 * eps,
        "lockfree gap {}",
        lf.trace.last().unwrap().gap
    );

    let b = batch_fw::solve(
        &p,
        &RunSpec::new(Engine::Batch)
            .line_search(true)
            .sample_every(1)
            .exact_gap(true)
            .stop(stop_gap(eps))
            .seed(4)
            .solve_options(),
    );
    assert!(b.trace.last().unwrap().gap <= eps, "batch");
}

#[test]
fn chain_ssvm_async_end_to_end_improves_error() {
    let data = Arc::new(ocr_like::generate(80, 6, 24, 6, 0.1, 5));
    let p = ChainSsvm::new(data, 0.05);
    let idx: Vec<usize> = (0..80).collect();
    let err0 = p.hamming_error(&p.init_param(), &idx);
    let cfg = RunSpec::new(Engine::asynchronous(4))
        .tau(8)
        .line_search(true)
        .sample_every(16)
        .max_epochs(40.0)
        .max_secs(60.0)
        .seed(6)
        .run_config()
        .unwrap();
    let r = coord::run(&p, &cfg);
    let err1 = p.hamming_error(&r.param, &idx);
    assert!(err1 < err0, "hamming {err0} -> {err1}");
    // dual objective must have decreased below f(0) = 0
    assert!(r.trace.last().unwrap().objective < 0.0);
}

#[test]
fn multiclass_ssvm_sync_end_to_end() {
    let data = Arc::new(mixture::generate(120, 6, 24, 0.1, 7));
    let p = MulticlassSsvm::new(data, 0.02);
    let idx: Vec<usize> = (0..120).collect();
    let err0 = p.zero_one_error(&p.init_param(), &idx);
    let cfg = RunSpec::new(Engine::synchronous(3))
        .tau(6)
        .line_search(true)
        .sample_every(16)
        .max_epochs(60.0)
        .max_secs(60.0)
        .seed(8)
        .run_config()
        .unwrap();
    let r = sync::run(&p, &cfg);
    let err1 = p.zero_one_error(&r.param, &idx);
    assert!(err1 < err0, "0/1 error {err0} -> {err1}");
}

#[test]
fn async_is_robust_to_straggler_sync_is_not() {
    // The paper's Fig 3(a) invariant: async time/pass stays ~flat as one
    // straggler slows; sync time/pass grows with the slowdown. Needs an
    // oracle whose cost dominates coordination — the chain SSVM Viterbi.
    let data = Arc::new(ocr_like::generate(150, 10, 48, 7, 0.15, 9));
    let p = ChainSsvm::new(data, 1.0);
    let run_pair = |straggler: StragglerSpec| {
        let mk = |engine: Engine| {
            RunSpec::new(engine.with_straggler(straggler.clone()))
                .tau(4)
                .sample_every(64)
                .max_epochs(8.0)
                .max_secs(60.0)
                .seed(10)
                .run_config()
                .unwrap()
        };
        let a = coord::run(&p, &mk(Engine::asynchronous(4)));
        let s = sync::run(&p, &mk(Engine::synchronous(4)));
        (a.secs_per_pass, s.secs_per_pass)
    };
    let (a_fast, s_fast) = run_pair(StragglerSpec::None);
    let (a_slow, s_slow) = run_pair(StragglerSpec::Single { p: 0.15 });
    let a_ratio = a_slow / a_fast;
    let s_ratio = s_slow / s_fast;
    // On this container (1 core) the effect is attenuated by timeslicing —
    // async's dropped solves also burn shared CPU — but sync must still
    // degrade substantially more than async (paper Fig 3a shape).
    assert!(
        s_ratio > 1.35,
        "sync should slow substantially: ratio {s_ratio}"
    );
    assert!(
        a_ratio < s_ratio,
        "async ratio {a_ratio} should beat sync ratio {s_ratio}"
    );
}

#[test]
fn counters_are_consistent_async() {
    let p = gfl_instance(11);
    let cfg = RunSpec::new(
        Engine::asynchronous(3)
            .with_straggler(StragglerSpec::Single { p: 0.5 }),
    )
    .tau(5)
    .sample_every(32)
    .max_epochs(50.0)
    .max_secs(30.0)
    .seed(12)
    .run_config()
    .unwrap();
    let r = coord::run(&p, &cfg);
    let c = r.counters;
    // every applied update corresponds to a successful oracle call
    assert!(c.updates_applied <= c.oracle_calls);
    // server applies exactly tau per iteration
    assert_eq!(c.updates_applied, c.iterations * 5);
    // stragglers must have dropped something
    assert!(c.dropped > 0);
    // what was produced is either applied, dropped, collided, or in flight
    assert!(
        c.updates_applied + c.dropped + c.collisions <= c.oracle_calls + 5
    );
}

#[test]
fn delayed_solver_matches_paper_drop_rule_accounting() {
    let p = gfl_instance(13);
    let opts = RunSpec::new(Engine::delayed(DelayModel::None))
        .tau(2)
        .sample_every(64)
        .max_epochs(30.0)
        .max_secs(30.0)
        .seed(14)
        .solve_options();
    let r = delayed::solve(
        &p,
        &opts,
        &DelayOptions {
            model: DelayModel::Poisson { kappa: 4.0 },
            history: 1024,
            ..Default::default()
        },
    );
    // oracle calls = applied + dropped
    assert_eq!(
        r.oracle_calls,
        (r.iterations * 2 - r.oracle_calls) + r.oracle_calls,
    );
    assert!(r.dropped > 0, "kappa=4 must drop early updates");
    assert!(r.trace.last().unwrap().objective < 0.0);
}

#[test]
fn qp_async_with_heterogeneous_workers() {
    let qp = SimplexQp::random(30, 4, 1.0, 0.2, 3, 15);
    let f0 = qp.objective(&(), &qp.init_param());
    let cfg = RunSpec::new(
        Engine::asynchronous(4)
            .with_straggler(StragglerSpec::Heterogeneous { theta: 0.3 }),
    )
    .tau(6)
    .line_search(true)
    .sample_every(16)
    .exact_gap(true)
    .eps_gap(0.02)
    .max_epochs(10_000.0)
    .max_secs(30.0)
    .seed(16)
    .run_config()
    .unwrap();
    let r = coord::run(&qp, &cfg);
    let last = r.trace.last().unwrap();
    assert!(last.objective < f0);
    assert!(last.gap <= 0.05, "gap={}", last.gap);
    // feasibility
    for b in 0..qp.n {
        let blk = &r.param[b * qp.m..(b + 1) * qp.m];
        let sum: f64 = blk.iter().map(|&v| v as f64).sum();
        assert!((sum - 1.0).abs() < 1e-4);
    }
}

#[test]
fn deterministic_sequential_solves_given_seed() {
    let p = gfl_instance(17);
    let opts = RunSpec::new(Engine::Seq)
        .tau(3)
        .sample_every(16)
        .max_epochs(20.0)
        .max_secs(30.0)
        .seed(18)
        .solve_options();
    let a = minibatch::solve(&p, &opts);
    let b = minibatch::solve(&p, &opts);
    assert_eq!(a.raw_param, b.raw_param);
    assert_eq!(a.oracle_calls, b.oracle_calls);
}

#[test]
fn lockfree_scales_throughput_with_threads() {
    // More threads -> more oracle calls per second (within budgeted time).
    // Compute-bound oracle so scaling isn't hidden by memory traffic.
    let p = SimplexQp::random(100, 16, 1.0, 0.5, 16, 19);
    let run_with = |workers: usize| {
        let cfg = RunSpec::new(Engine::lockfree(workers))
            .sample_every(1 << 20)
            .max_epochs(f64::INFINITY)
            .max_secs(0.5)
            .seed(20)
            .run_config()
            .unwrap();
        let r = lockfree::run(&p, &cfg);
        r.counters.oracle_calls as f64 / r.elapsed_s
    };
    let t1 = run_with(1);
    let t4 = run_with(4);
    let mut rng = Pcg64::seeded(1);
    let _ = rng.next_u64();
    // The CI container exposes a single core, so linear scaling is not
    // observable here; assert the lock-free path at least does not
    // collapse under contention (on multicore hosts this scales ~T).
    assert!(
        t4 > 0.4 * t1,
        "lockfree throughput collapsed: 1thr={t1:.0}/s 4thr={t4:.0}/s"
    );
}
