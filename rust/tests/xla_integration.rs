//! Integration: AOT-compiled XLA artifacts vs native rust compute.
//!
//! Requires `make artifacts` (skips, loudly, when artifacts/ is missing so
//! plain `cargo test` works in a fresh checkout).

use apbcfw::data::{mixture, ocr_like, signal};
use apbcfw::problems::gfl::{Gfl, GflOracleBackend};
use apbcfw::problems::ssvm::chain::{ChainDecoder, ChainSsvm};
use apbcfw::problems::ssvm::multiclass::{MulticlassDecoder, MulticlassSsvm};
use apbcfw::problems::Problem;
use apbcfw::runtime::service;
use apbcfw::runtime::xla_backends::{
    XlaChainDecoder, XlaGfl, XlaGflPrimal, XlaMulticlassDecoder,
};
use apbcfw::run::{Engine, RunSpec};
use apbcfw::solver::minibatch;
use apbcfw::util::la;
use apbcfw::util::rng::Pcg64;
use std::sync::Arc;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

/// Default artifact shapes (must match python/compile/aot.py defaults).
const GFL_D: usize = 10;
const GFL_N: usize = 100;
const CHAIN_K: usize = 26;
const CHAIN_D: usize = 128;
const CHAIN_L: usize = 9;
const MC_K: usize = 10;
const MC_D: usize = 64;

#[test]
fn gfl_step_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let handle = service::spawn(dir).unwrap();
    let mut rng = Pcg64::seeded(1);
    let lam = 0.01;
    let y = rng.gaussian_vec(GFL_D * GFL_N);
    let gfl = Gfl::new(GFL_D, GFL_N, lam, y);
    let xla = XlaGfl::new(handle, GFL_D, GFL_N, lam, &gfl.b).unwrap();

    // random feasible U
    let mut u = rng.gaussian_vec(GFL_D * (GFL_N - 1));
    for t in 0..GFL_N - 1 {
        la::project_l2_ball(lam, &mut u[t * GFL_D..(t + 1) * GFL_D]);
    }
    let (g, s, gap, f) = xla.step(&u);
    // native comparison
    let mut native_gap_sum = 0.0;
    for t in 0..gfl.m {
        let gn = gfl.grad_col(&u, t);
        for r in 0..GFL_D {
            assert!(
                (g[t * GFL_D + r] - gn[r]).abs() < 1e-4,
                "grad mismatch at ({t},{r})"
            );
        }
        let o = gfl.oracle(&u, t);
        let os = o.s.as_dense().expect("gfl oracle is dense");
        for r in 0..GFL_D {
            assert!(
                (s[t * GFL_D + r] - os[r]).abs() < 1e-4,
                "oracle mismatch at ({t},{r})"
            );
        }
        let bg = gfl.block_gap(&(), &u, &o);
        assert!((gap[t] as f64 - bg).abs() < 1e-3, "gap mismatch at {t}");
        native_gap_sum += bg;
    }
    let _ = native_gap_sum;
    assert!(
        (f - gfl.objective_of(&u)).abs() < 1e-3,
        "objective mismatch: xla {f} native {}",
        gfl.objective_of(&u)
    );
}

#[test]
fn gfl_primal_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let handle = service::spawn(dir).unwrap();
    let mut rng = Pcg64::seeded(2);
    let lam = 0.5;
    let sig = signal::piecewise_constant(GFL_D, GFL_N, 5, 2.0, 0.5, 7);
    let gfl = Gfl::new(GFL_D, GFL_N, lam, sig.noisy.clone());
    let xla =
        XlaGflPrimal::new(handle, GFL_D, GFL_N, lam, &gfl.y).unwrap();
    let mut u = rng.gaussian_vec(GFL_D * (GFL_N - 1));
    for t in 0..GFL_N - 1 {
        la::project_l2_ball(lam, &mut u[t * GFL_D..(t + 1) * GFL_D]);
    }
    let (x, p) = xla.primal(&u);
    let xn = gfl.primal_signal(&u);
    for (a, b) in x.iter().zip(xn.iter()) {
        assert!((a - b).abs() < 1e-4);
    }
    assert!(
        (p - gfl.primal_objective(&u)).abs()
            < 1e-3 * gfl.primal_objective(&u).abs().max(1.0)
    );
}

#[test]
fn chain_decoder_artifact_matches_native_viterbi() {
    let Some(dir) = artifacts_dir() else { return };
    let handle = service::spawn(dir).unwrap();
    let data = Arc::new(ocr_like::generate(
        20, CHAIN_K, CHAIN_D, CHAIN_L, 0.15, 3,
    ));
    let problem = ChainSsvm::new(data.clone(), 1.0);
    let xla = XlaChainDecoder::new(handle, data.clone()).unwrap();
    let mut rng = Pcg64::seeded(4);
    let w: Vec<f32> = rng.gaussian_vec(problem.dim());
    for i in 0..10 {
        for lw in [0.0f32, 1.0] {
            let (ys_n, h_n) = problem.viterbi(&w, i, lw);
            let (ys_x, h_x) = xla.decode(&w, i, lw);
            assert_eq!(ys_n, ys_x, "decode mismatch i={i} lw={lw}");
            assert!(
                (h_n - h_x).abs() < 1e-2 * h_n.abs().max(1.0),
                "H mismatch i={i}: native {h_n} xla {h_x}"
            );
        }
    }
}

#[test]
fn multiclass_decoder_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let handle = service::spawn(dir).unwrap();
    let data = Arc::new(mixture::generate(40, MC_K, MC_D, 0.2, 5));
    let problem = MulticlassSsvm::new(data.clone(), 0.1);
    let xla = XlaMulticlassDecoder::new(handle, data.clone()).unwrap();
    let mut rng = Pcg64::seeded(6);
    let w: Vec<f32> = rng.gaussian_vec(problem.dim());
    for i in 0..40 {
        for lw in [0.0f32, 1.0] {
            let (y_n, h_n) = problem.argmax(&w, i, lw);
            let (y_x, h_x) = xla.decode(&w, i, lw);
            assert_eq!(y_n, y_x, "argmax mismatch i={i} lw={lw}");
            assert!((h_n - h_x).abs() < 1e-3 * h_n.abs().max(1.0));
        }
    }
}

#[test]
fn solve_with_xla_backend_converges_like_native() {
    let Some(dir) = artifacts_dir() else { return };
    let handle = service::spawn(dir).unwrap();
    let mut rng = Pcg64::seeded(8);
    let lam = 0.05;
    let y = rng.gaussian_vec(GFL_D * GFL_N);
    let native = Gfl::new(GFL_D, GFL_N, lam, y.clone());
    let backend =
        Arc::new(XlaGfl::new(handle, GFL_D, GFL_N, lam, &native.b).unwrap());
    let xla_problem =
        Gfl::new(GFL_D, GFL_N, lam, y).with_backend(backend);

    let opts = RunSpec::new(Engine::Seq)
        .tau(4)
        .line_search(true)
        .sample_every(16)
        .max_epochs(30.0)
        .max_secs(120.0)
        .seed(9)
        .solve_options();
    let r_native = minibatch::solve(&native, &opts);
    let r_xla = minibatch::solve(&xla_problem, &opts);
    let f_native = r_native.trace.last().unwrap().objective;
    let f_xla = r_xla.trace.last().unwrap().objective;
    // Same seeds, same oracle answers -> same trajectory (f32 tolerance).
    assert!(
        (f_native - f_xla).abs() < 1e-3 * f_native.abs().max(1.0),
        "native {f_native} vs xla {f_xla}"
    );
}

#[test]
fn xla_backed_async_coordinator_run() {
    // The XLA service handle must be usable from multiple worker threads.
    let Some(dir) = artifacts_dir() else { return };
    let handle = service::spawn(dir).unwrap();
    let mut rng = Pcg64::seeded(10);
    let lam = 0.05;
    let y = rng.gaussian_vec(GFL_D * GFL_N);
    let native = Gfl::new(GFL_D, GFL_N, lam, y.clone());
    let backend =
        Arc::new(XlaGfl::new(handle, GFL_D, GFL_N, lam, &native.b).unwrap());
    let problem = Gfl::new(GFL_D, GFL_N, lam, y).with_backend(backend);

    let cfg = RunSpec::new(Engine::asynchronous(3))
        .tau(4)
        .line_search(true)
        .sample_every(8)
        .max_epochs(20.0)
        .max_secs(60.0)
        .seed(11)
        .run_config()
        .unwrap();
    let r = apbcfw::coordinator::apbcfw::run(&problem, &cfg);
    assert!(r.counters.updates_applied > 0);
    let f_end = r.trace.last().unwrap().objective;
    assert!(f_end < -1e-3, "async+xla should make progress: {f_end}");
}
