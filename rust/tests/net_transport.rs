//! Distributed transport equivalence and codec property tests.
//!
//! The load-bearing claim: a `serve`+`worker` solve over 127.0.0.1 is the
//! same algorithm as the in-process delayed-update framework — at one
//! worker (`tau = batch = 1`, lockstep pull/solve/push) it replays the
//! sequential delayed engine (`solver::delayed`, `DelayModel::None`)
//! draw-for-draw and must be **bit-identical**: the worker samples blocks
//! from rng stream `2 + id` (worker 0 = the delayed engine's stream), the
//! snapshot wire roundtrip preserves f32 bits exactly, and the server
//! applies with the same `schedule_gamma`. Beyond one worker the schedule
//! is interleaving-dependent, so the guarantee weakens to
//! tolerance-bounded: both sides converge to the same gap target.
//!
//! The codec side pins that sparse payloads are never densified on the
//! wire (randomized round-trips) — the bytes axis the whole subsystem
//! exists to shrink.

use apbcfw::net::wire::{self, Msg};
use apbcfw::net::{solve_loopback, BoundServer};
use apbcfw::problems::{BlockOracle, OraclePayload, PayloadMode};
use apbcfw::run::{Engine, LiveEvent, ProblemInstance, Runner, RunSpec};
use apbcfw::sim::delay::DelayModel;
use apbcfw::util::config::Config;
use apbcfw::util::rng::Pcg64;

/// GFL instance with 40 blocks (d=6, n=41): 8 epochs = 320 oracle calls,
/// divisible by the sample cadence so the delayed engine and the net
/// server stop on exactly the same iteration.
fn gfl_cfg() -> Config {
    Config::parse(
        "[run]\nseed = 5\n\
         [gfl]\nd = 6\nn = 41\nlambda = 0.2\nsegments = 4\nnoise = 0.5\n",
    )
    .unwrap()
}

/// QP with 24 blocks of dim 5: 6 epochs = 144 calls, divisible by 8.
fn qp_cfg() -> Config {
    Config::parse("[run]\nseed = 5\n[qp]\nn = 24\nm = 5\nmu = 0.2\n").unwrap()
}

fn shared_knobs(spec: RunSpec, epochs: f64) -> RunSpec {
    spec.tau(1)
        .sample_every(8)
        .max_epochs(epochs)
        .max_secs(60.0)
        .seed(5)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// One-worker loopback vs the sequential delayed engine, bit for bit.
fn assert_loopback_matches_delayed(
    problem: &str,
    cfg: &Config,
    epochs: f64,
    payload: PayloadMode,
) {
    let net_spec =
        shared_knobs(RunSpec::new(Engine::asynchronous(1)), epochs)
            .payload(payload);
    let net = solve_loopback(net_spec, problem, cfg, "127.0.0.1:0")
        .unwrap_or_else(|e| panic!("{problem}: loopback solve failed: {e:#}"));

    let instance = ProblemInstance::from_config(problem, cfg).unwrap();
    let ref_spec =
        shared_knobs(RunSpec::new(Engine::delayed(DelayModel::None)), epochs)
            .payload(payload);
    let reference = Runner::new(ref_spec).unwrap().solve(&instance).unwrap();

    assert_eq!(
        net.counters.oracle_calls, reference.counters.oracle_calls,
        "{problem}: oracle budgets diverged"
    );
    assert_eq!(
        net.counters.updates_applied, reference.counters.updates_applied,
        "{problem}: applied counts diverged"
    );
    assert_eq!(net.counters.dropped, 0, "{problem}: lockstep never drops");
    assert_eq!(net.counters.delay_sum, 0, "{problem}: lockstep delay is 0");
    assert_eq!(
        bits(&net.raw_param),
        bits(&reference.raw_param),
        "{problem}: final parameter bits diverged"
    );
    // The trace streams agree sample-for-sample (the net report appends
    // one extra final sample, exactly like the in-process async engine).
    assert_eq!(net.trace.samples.len(), reference.trace.samples.len() + 1);
    for (a, b) in net
        .trace
        .samples
        .iter()
        .zip(reference.trace.samples.iter())
    {
        assert_eq!(a.iter, b.iter, "{problem}: sample iteration");
        assert_eq!(a.oracle_calls, b.oracle_calls, "{problem}: sample calls");
        assert_eq!(
            a.objective.to_bits(),
            b.objective.to_bits(),
            "{problem}: objective bits at iter {}",
            a.iter
        );
        assert_eq!(
            a.gap.to_bits(),
            b.gap.to_bits(),
            "{problem}: gap-estimate bits at iter {}",
            a.iter
        );
    }
    // And the whole solve really crossed the wire.
    assert!(net.counters.wire_rx_bytes > 0, "{problem}: nothing received");
    assert!(net.counters.wire_tx_bytes > 0, "{problem}: nothing sent");
}

#[test]
fn loopback_one_worker_bit_identical_to_delayed_engine_gfl() {
    assert_loopback_matches_delayed("gfl", &gfl_cfg(), 8.0, PayloadMode::Auto);
}

#[test]
fn loopback_one_worker_bit_identical_to_delayed_engine_qp_sparse() {
    assert_loopback_matches_delayed(
        "qp",
        &qp_cfg(),
        6.0,
        PayloadMode::Sparse,
    );
}

#[test]
fn sparse_wire_payloads_match_dense_bits_and_ship_fewer_bytes() {
    // The payload representation contract holds across the wire: forced
    // sparse and forced dense loopback runs of the same spec produce
    // bit-identical parameters, and the sparse one ships fewer payload
    // bytes per oracle (QP's vertex is 1-hot).
    let cfg = qp_cfg();
    let mut runs = Vec::new();
    for payload in [PayloadMode::Dense, PayloadMode::Sparse] {
        let spec = shared_knobs(RunSpec::new(Engine::asynchronous(1)), 6.0)
            .payload(payload);
        runs.push(solve_loopback(spec, "qp", &cfg, "127.0.0.1:0").unwrap());
    }
    let (dense, sparse) = (&runs[0], &runs[1]);
    assert_eq!(bits(&dense.raw_param), bits(&sparse.raw_param));
    assert!(sparse.counters.payload_bytes < dense.counters.payload_bytes);
    assert!(
        sparse.counters.wire_rx_bytes < dense.counters.wire_rx_bytes,
        "sparse {} !< dense {} frame bytes",
        sparse.counters.wire_rx_bytes,
        dense.counters.wire_rx_bytes
    );
    assert!(sparse.counters.payload_nnz < dense.counters.payload_nnz);
}

#[test]
fn wire_exact_knob_stays_bit_identical_to_the_delayed_engine() {
    // `run.wire = exact` (the pinned default, spelled out) must keep the
    // one-worker loopback on the bit-identical path — the v4 knob only
    // changes bytes when asked to.
    let mut cfg = gfl_cfg();
    cfg.set("run.wire", "exact");
    assert_loopback_matches_delayed("gfl", &cfg, 8.0, PayloadMode::Auto);
}

#[test]
fn quantized_wire_modes_converge_within_tolerance_and_ship_fewer_bytes() {
    // `run.wire = f16 | q8` quantizes sparse update values on the wire,
    // trading bit-identity for bytes. Multiclass sparse payloads carry a
    // full feature vector per oracle (nnz = d), so the quantized
    // encodings must measurably shrink the shipped update-frame bytes
    // while the solve still lands on the exact run's objective to the
    // documented tolerance (EXPERIMENTS.md §Wire-efficiency: 1e-2
    // relative for f16, 5e-2 for q8).
    let cfg_text = "[run]\nseed = 5\n\
                    [multiclass]\nn = 24\nk = 4\nd = 16\nnoise = 0.15\n\
                    lambda = 0.05\n";
    let mut runs = Vec::new();
    for mode in ["exact", "f16", "q8"] {
        let mut cfg = Config::parse(cfg_text).unwrap();
        cfg.set("run.wire", mode);
        let spec = shared_knobs(RunSpec::new(Engine::asynchronous(1)), 6.0)
            .payload(PayloadMode::Sparse);
        let r = solve_loopback(spec, "multiclass", &cfg, "127.0.0.1:0")
            .unwrap_or_else(|e| panic!("wire={mode} loopback failed: {e:#}"));
        assert!(r.counters.updates_applied > 0, "wire={mode}: nothing ran");
        assert!(
            r.counters.shipped_payload_bytes > 0,
            "wire={mode}: shipped-bytes telemetry missing"
        );
        assert!(
            r.last().unwrap().objective.is_finite(),
            "wire={mode}: diverged"
        );
        runs.push(r);
    }
    let (exact, f16, q8) = (&runs[0], &runs[1], &runs[2]);
    let ref_obj = exact.last().unwrap().objective;
    let scale = ref_obj.abs().max(1.0);
    assert!(
        (f16.last().unwrap().objective - ref_obj).abs() <= 1e-2 * scale,
        "f16 objective {} vs exact {ref_obj}",
        f16.last().unwrap().objective
    );
    assert!(
        (q8.last().unwrap().objective - ref_obj).abs() <= 5e-2 * scale,
        "q8 objective {} vs exact {ref_obj}",
        q8.last().unwrap().objective
    );
    // The logical payload cost is mode-independent (same oracles), so
    // the saving must show up in the shipped bytes: q8 < f16 < exact.
    assert_eq!(exact.counters.payload_bytes, f16.counters.payload_bytes);
    assert!(
        q8.counters.shipped_payload_bytes
            < f16.counters.shipped_payload_bytes
            && f16.counters.shipped_payload_bytes
                < exact.counters.shipped_payload_bytes,
        "shipped bytes not ordered: exact {} f16 {} q8 {}",
        exact.counters.shipped_payload_bytes,
        f16.counters.shipped_payload_bytes,
        q8.counters.shipped_payload_bytes
    );
}

#[test]
fn loopback_two_workers_converge_to_the_async_tolerance() {
    // Beyond one worker the interleaving is scheduling-dependent, so the
    // equivalence is tolerance-bounded: the distributed solve reaches the
    // same gap target the in-process async engine does.
    let cfg = gfl_cfg();
    let spec = RunSpec::new(Engine::asynchronous(2))
        .tau(2)
        .sample_every(16)
        .exact_gap(true)
        .eps_gap(0.05)
        .max_epochs(5000.0)
        .max_secs(30.0)
        .seed(5);
    let net = solve_loopback(spec.clone(), "gfl", &cfg, "127.0.0.1:0").unwrap();
    let last = net.trace.last().unwrap();
    assert!(last.gap <= 0.05, "net gap {}", last.gap);

    let instance = ProblemInstance::from_config("gfl", &cfg).unwrap();
    let inproc = Runner::new(spec).unwrap().solve(&instance).unwrap();
    assert!(inproc.trace.last().unwrap().gap <= 0.05);
    // Both are eps-optimal, so the objectives agree to the tolerance.
    assert!(
        (last.objective - inproc.trace.last().unwrap().objective).abs()
            <= 0.1,
        "net {} vs in-process {}",
        last.objective,
        inproc.trace.last().unwrap().objective
    );
}

#[test]
fn loopback_batched_fanout_and_staleness_delay_counters() {
    // batch = 4 blocks per snapshot pull, one worker: completes, applies
    // everything, and the delay counters stay sane (lockstep: delay 0).
    let cfg = qp_cfg();
    let spec = RunSpec::new(Engine::asynchronous(1))
        .tau(4)
        .batch(4)
        .sample_every(4)
        .max_epochs(6.0)
        .max_secs(30.0)
        .seed(7)
        .payload(PayloadMode::Sparse);
    let r = solve_loopback(spec, "qp", &cfg, "127.0.0.1:0").unwrap();
    assert!(r.counters.updates_applied > 0);
    assert_eq!(r.counters.delay_sum, 0);
    assert_eq!(r.counters.delay_max, 0);
    // Sparse QP oracles are 1-hot: nnz per oracle must be exactly 1.
    assert_eq!(r.counters.payload_nnz, r.counters.oracle_calls);
}

#[test]
fn loopback_ssvm_uses_full_snapshots_and_completes() {
    // Chain SSVM updates w densely (`touched_ranges` = None), so every
    // refresh is a full snapshot — the delta fallback path.
    let cfg = Config::parse(
        "[run]\nseed = 3\n\
         [ssvm]\nn = 12\nk = 3\nd = 6\nell = 4\nlambda = 1.0\n",
    )
    .unwrap();
    let spec = RunSpec::new(Engine::asynchronous(2))
        .tau(2)
        .sample_every(8)
        .max_epochs(3.0)
        .max_secs(30.0)
        .seed(3);
    let r = solve_loopback(spec, "ssvm", &cfg, "127.0.0.1:0").unwrap();
    assert!(r.counters.updates_applied > 0);
    assert!(r.counters.wire_tx_bytes > 0);
    assert!(r.last().unwrap().objective.is_finite());
}

#[test]
fn spawn_serve_streams_events_and_reports() {
    // The service surface: bind synchronously (address known first),
    // connect a worker, and watch live events while the solve runs.
    let cfg = qp_cfg();
    let spec = RunSpec::new(Engine::asynchronous(1))
        .tau(1)
        .sample_every(8)
        .max_epochs(2.0)
        .max_secs(30.0)
        .seed(5);
    let session =
        apbcfw::runtime::service::spawn_serve(spec, "qp", &cfg, "127.0.0.1:0")
            .unwrap();
    let addr = session.addr.to_string();
    let worker = std::thread::spawn(move || apbcfw::net::worker::run(&addr));
    let events: Vec<_> = session.events.iter().collect();
    let report = session.join().unwrap();
    let summary = worker.join().unwrap().unwrap();
    assert!(!events.is_empty());
    assert_eq!(summary.worker_id, 0);
    assert_eq!(summary.oracle_calls, report.counters.oracle_calls);
    assert!(summary.tx_bytes > 0 && summary.rx_bytes > 0);
}

#[test]
fn server_drops_connections_sending_unappliable_oracles() {
    // The codec only checks a frame's self-consistency; the server must
    // additionally validate decoded oracles against the instance (block
    // in range, payload of the problem's dimension) and drop violators
    // instead of panicking in `apply`.
    for bad in [
        // Block index far out of range (payload dim correct: qp m = 5).
        BlockOracle::dense(1_000_000, vec![0.0; 5], 0.0),
        // Valid block, wrong payload dimension.
        BlockOracle::dense(0, vec![0.0; 64], 0.0),
        // Sparse payload whose self-declared dim disagrees with the
        // instance (its idx is valid against its own dim).
        BlockOracle {
            block: 0,
            s: OraclePayload::Sparse {
                idx: vec![63],
                val: vec![1.0],
                dim: 64,
            },
            ls: 0.0,
        },
    ] {
        let mut cfg = qp_cfg();
        // Dropping the violator empties the fleet; without a short grace
        // window the server would wait out the 30 s default for a
        // replacement worker before concluding the run.
        cfg.set("run.accept_timeout_secs", "0.5");
        let spec = RunSpec::new(Engine::asynchronous(1))
            .tau(1)
            .max_epochs(50.0)
            .max_secs(20.0)
            .seed(5);
        let session = apbcfw::runtime::service::spawn_serve(
            spec,
            "qp",
            &cfg,
            "127.0.0.1:0",
        )
        .unwrap();
        let mut stream = std::net::TcpStream::connect(session.addr).unwrap();
        let worker = match wire::read_frame(&mut stream).unwrap().unwrap() {
            (Msg::Hello(h), _) => h.worker_id,
            (other, _) => panic!("expected Hello, got {other:?}"),
        };
        let mut buf = Vec::new();
        let msg = Msg::Update {
            k_read: 0,
            worker,
            generation: 0,
            oracles: vec![bad],
        };
        wire::write_frame(&mut stream, &msg, &mut buf).unwrap();
        // The server drops the connection (sole worker -> solve ends)
        // without applying anything and without panicking.
        let report = session.join().unwrap();
        assert_eq!(report.counters.updates_applied, 0);
    }
}

#[test]
fn dead_worker_is_reaped_by_liveness_and_its_blocks_requeued() {
    // A fleet of two where one member goes silent mid-run: the liveness
    // scan must declare it dead (the socket stays open, so only the
    // last-seen clock can), requeue its in-flight fan-out round, and let
    // the survivor finish the solve.
    let mut cfg = qp_cfg();
    cfg.set("run.liveness_ms", "250");
    let spec = RunSpec::new(Engine::asynchronous(2))
        .tau(2)
        .sample_every(16)
        .max_epochs(1e6)
        .max_secs(1.5)
        .seed(5);
    let session = apbcfw::runtime::service::spawn_serve(
        spec,
        "qp",
        &cfg,
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = session.addr.to_string();
    let survivor = std::thread::spawn(move || apbcfw::net::worker::run(&addr));
    // The victim: handshakes, pulls one snapshot, then goes silent while
    // holding its connection open.
    let mut victim = std::net::TcpStream::connect(session.addr).unwrap();
    match wire::read_frame(&mut victim).unwrap().unwrap() {
        (Msg::Hello(_), _) => {}
        (other, _) => panic!("expected Hello, got {other:?}"),
    }
    let mut buf = Vec::new();
    wire::write_frame(
        &mut victim,
        &Msg::SnapshotRequest { have_version: 0 },
        &mut buf,
    )
    .unwrap();
    match wire::read_frame(&mut victim).unwrap().unwrap() {
        (Msg::Snapshot { .. }, _) => {}
        (other, _) => panic!("expected Snapshot, got {other:?}"),
    }
    drop(session.events);
    let report = session.join().unwrap();
    let summary = survivor.join().unwrap().unwrap();
    drop(victim);
    assert!(summary.clean, "survivor should be shut down cleanly");
    assert!(report.counters.updates_applied > 0);
    assert_eq!(report.counters.workers_lost, 1, "{:?}", report.counters);
    assert!(
        report.counters.blocks_requeued >= 1,
        "the victim's answered fan-out round must be requeued: {:?}",
        report.counters
    );
}

#[test]
fn late_worker_joins_mid_run_and_contributes() {
    // Elastic membership: a worker connecting after the run started gets
    // a fresh snapshot and a fresh worker id (hence rng stream) and pulls
    // its share of the remaining work.
    let cfg = gfl_cfg();
    let spec = RunSpec::new(Engine::asynchronous(1))
        .tau(2)
        .sample_every(16)
        .max_epochs(1e6)
        .max_secs(1.0)
        .seed(5);
    let session = apbcfw::runtime::service::spawn_serve(
        spec,
        "gfl",
        &cfg,
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = session.addr.to_string();
    let first = std::thread::spawn(move || apbcfw::net::worker::run(&addr));
    // Wait until the run is demonstrably in flight before joining.
    let mut applies = 0usize;
    for event in session.events.iter() {
        if matches!(event, LiveEvent::Apply { .. }) {
            applies += 1;
            if applies >= 20 {
                break;
            }
        }
    }
    let addr = session.addr.to_string();
    let second = std::thread::spawn(move || apbcfw::net::worker::run(&addr));
    drop(session.events);
    let report = session.join().unwrap();
    let s1 = first.join().unwrap().unwrap();
    let s2 = second.join().unwrap().unwrap();
    assert_eq!(report.counters.workers_joined, 1, "{:?}", report.counters);
    assert_eq!(s1.worker_id, 0);
    assert_eq!(s2.worker_id, 1, "joiner must get a fresh id");
    assert!(s2.oracle_calls > 0, "joiner never contributed");
    assert!(s1.clean && s2.clean, "both workers should see the shutdown");
}

#[test]
fn chaos_dropped_updates_cost_extra_rounds_but_the_solve_completes() {
    // `run.chaos = drop:P` swallows update frames on the worker's tx
    // path. Drops are invisible to the server except as extra worker
    // rounds, so the crisp observable is worker-side oracle calls
    // exceeding what the server received.
    let mut cfg = qp_cfg();
    cfg.set("run.chaos", "drop:0.3");
    let spec = RunSpec::new(Engine::asynchronous(1))
        .tau(1)
        .sample_every(8)
        .max_epochs(2.0)
        .max_secs(30.0)
        .seed(5);
    let session = apbcfw::runtime::service::spawn_serve(
        spec,
        "qp",
        &cfg,
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = session.addr.to_string();
    let worker = std::thread::spawn(move || apbcfw::net::worker::run(&addr));
    drop(session.events);
    let report = session.join().unwrap();
    let summary = worker.join().unwrap().unwrap();
    assert!(summary.clean);
    assert!(report.counters.updates_applied > 0);
    assert!(
        summary.oracle_calls > report.counters.oracle_calls,
        "no update was dropped: worker {} vs server {}",
        summary.oracle_calls,
        report.counters.oracle_calls
    );
}

#[test]
fn chaos_delay_surfaces_in_the_staleness_telemetry() {
    // A 5 ms stall injected on half of one worker's update frames lets
    // the other worker run ahead, so the observed staleness — applied
    // delay or staleness-rule drops — must be nonzero, exactly the
    // quantity the Fig 3 straggler replay plots.
    let mut cfg = gfl_cfg();
    cfg.set("run.chaos", "delay:fixed:5:0.5");
    let spec = RunSpec::new(Engine::asynchronous(2))
        .tau(2)
        .sample_every(16)
        .max_epochs(6.0)
        .max_secs(30.0)
        .seed(5);
    let net = solve_loopback(spec, "gfl", &cfg, "127.0.0.1:0").unwrap();
    assert!(net.counters.updates_applied > 0);
    assert!(net.last().unwrap().objective.is_finite());
    assert!(
        net.counters.delay_sum + net.counters.dropped > 0,
        "injected stalls produced no observable staleness: {:?}",
        net.counters
    );
}

#[test]
fn v2_control_frames_roundtrip_and_bad_frames_are_rejected() {
    let mut buf = Vec::new();
    for msg in [
        Msg::Heartbeat,
        Msg::Join { resumed: false },
        Msg::Join { resumed: true },
    ] {
        let n = wire::encode_frame(&msg, &mut buf);
        let (decoded, consumed) =
            wire::read_frame(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!(consumed, n);
        assert_eq!(decoded, msg);
    }
    // Every strict non-empty prefix of a frame is a truncation error
    // (empty input is the clean-EOF `None`).
    let n = wire::encode_frame(&Msg::Join { resumed: true }, &mut buf);
    for cut in 1..n {
        assert!(wire::read_frame(&mut &buf[..cut]).is_err(), "cut {cut}");
    }
    // A v1 header is refused with a version error, not misparsed.
    let n = wire::encode_frame(&Msg::Heartbeat, &mut buf);
    let mut bad = buf[..n].to_vec();
    bad[4] = 1; // LE u16 version at bytes 4..6
    bad[5] = 0;
    let err = wire::read_frame(&mut bad.as_slice())
        .unwrap_err()
        .to_string();
    assert!(err.contains("version"), "{err}");
}

#[test]
fn bind_rejects_bad_specs_synchronously() {
    let cfg = qp_cfg();
    // Non-async engine.
    let err = BoundServer::bind(
        RunSpec::new(Engine::synchronous(2)),
        "qp",
        &cfg,
        "127.0.0.1:0",
    )
    .map(|_| ())
    .unwrap_err()
    .to_string();
    assert!(err.contains("async"), "{err}");
    // Unknown problem.
    assert!(BoundServer::bind(
        RunSpec::new(Engine::asynchronous(1)),
        "nosuch",
        &cfg,
        "127.0.0.1:0",
    )
    .map(|_| ())
    .is_err());
}

#[test]
fn loopback_one_shard_knob_stays_bit_identical() {
    // `run.shards = 1` must be the historical v2 server, bit for bit:
    // the degenerate plan takes the single-loop path, so the exact pins
    // the unsharded loopback satisfies must hold with the knob set.
    let mut cfg = gfl_cfg();
    cfg.set("run.shards", "1");
    assert_loopback_matches_delayed("gfl", &cfg, 8.0, PayloadMode::Auto);
}

#[test]
fn loopback_two_shards_one_worker_matches_delayed_within_tolerance() {
    // The sharded plane at one worker: each round the worker fans its
    // snapshot pull to both shards, solves globally sampled blocks, and
    // routes every update to its block's owner. Per shard that is
    // lockstep — nothing is ever stale — but the block stream splits
    // across two independent apply clocks, so the equivalence to the
    // sequential delayed engine is tolerance-bounded, not bit-exact.
    let epochs = 120.0;
    let mut cfg = gfl_cfg();
    cfg.set("run.shards", "2");
    let spec = shared_knobs(RunSpec::new(Engine::asynchronous(1)), epochs);
    let net = solve_loopback(spec, "gfl", &cfg, "127.0.0.1:0")
        .unwrap_or_else(|e| panic!("2-shard loopback failed: {e:#}"));

    let instance = ProblemInstance::from_config("gfl", &gfl_cfg()).unwrap();
    let ref_spec =
        shared_knobs(RunSpec::new(Engine::delayed(DelayModel::None)), epochs);
    let reference = Runner::new(ref_spec).unwrap().solve(&instance).unwrap();

    // Deterministic aggregated telemetry: the lockstep worker is never
    // stale on either shard, every oracle the plane counted was
    // applied, and the global-stop rendezvous ends the run without
    // booking phantom worker deaths.
    assert_eq!(net.counters.workers_lost, 0, "{:?}", net.counters);
    assert_eq!(net.counters.dropped, 0, "{:?}", net.counters);
    assert_eq!(net.counters.delay_sum, 0, "{:?}", net.counters);
    assert_eq!(
        net.counters.updates_applied, net.counters.oracle_calls,
        "{:?}",
        net.counters
    );
    // The per-shard epoch budgets split the spec's global budget; the
    // first shard to spend its half stops the plane, so the aggregate
    // lands between half of the sequential budget and all of it (plus
    // a turn of in-flight slack).
    let budget = reference.counters.oracle_calls;
    assert!(
        net.counters.oracle_calls > budget / 2
            && net.counters.oracle_calls <= budget + 8,
        "aggregated oracle calls {} vs sequential budget {budget}",
        net.counters.oracle_calls
    );
    assert!(net.counters.snapshot_reads > 0, "{:?}", net.counters);
    assert!(net.counters.wire_rx_bytes > 0 && net.counters.wire_tx_bytes > 0);
    // The rendezvous evaluates the assembled iterate exactly (final
    // appended sample); both solves are deep into convergence by now,
    // so the objectives agree to a loose tolerance.
    let last = net.last().unwrap();
    let ref_obj = reference.trace.last().unwrap().objective;
    assert!(last.gap.is_finite() && last.gap >= -1e-6, "gap {}", last.gap);
    assert!(
        (last.objective - ref_obj).abs() <= 0.1 * ref_obj.abs().max(1.0),
        "2-shard objective {} vs sequential {}",
        last.objective,
        ref_obj
    );
}

#[test]
fn loopback_two_shards_two_workers_solve_sparse_qp() {
    // Two shards x two workers over the sparse wire path: updates are
    // owner-routed, snapshot pulls fan out under the per-shard version
    // vector, and the run still ends in an orderly global shutdown.
    let mut cfg = qp_cfg();
    cfg.set("run.shards", "2");
    let spec = RunSpec::new(Engine::asynchronous(2))
        .tau(2)
        .sample_every(8)
        .max_epochs(6.0)
        .max_secs(30.0)
        .seed(5)
        .payload(PayloadMode::Sparse);
    let net = solve_loopback(spec, "qp", &cfg, "127.0.0.1:0")
        .unwrap_or_else(|e| panic!("sharded qp loopback failed: {e:#}"));
    assert!(net.counters.updates_applied > 0);
    assert_eq!(net.counters.workers_lost, 0, "{:?}", net.counters);
    // Sparse QP oracles stay 1-hot when routed across shards.
    assert_eq!(net.counters.payload_nnz, net.counters.oracle_calls);
    assert!(net.counters.wire_rx_bytes > 0 && net.counters.wire_tx_bytes > 0);
    assert!(net.last().unwrap().objective.is_finite());
}

#[test]
fn adaptive_step_and_batch_survive_the_wire_and_stamp_telemetry() {
    // run.adapt over the net path: the server damps its schedule from
    // the observed-delay EMA (adapt.step = kappa threads through the
    // serve-side ApplyKnobs) and the workers retune their fan-out from
    // snapshot-pull latency (adapt.batch = auto). Loopback pulls are
    // cheap and uniform, so the controller must grow the batch off its
    // floor — every growth step is a server-visible payload-width change
    // counted in batch_resizes.
    let mut cfg = gfl_cfg();
    cfg.set("run.adapt.step", "kappa");
    cfg.set("run.adapt.batch", "auto:1:4");
    cfg.set("run.chaos", "delay:fixed:5:0.5");
    let spec = RunSpec::new(Engine::asynchronous(2))
        .tau(2)
        .sample_every(16)
        .max_epochs(6.0)
        .max_secs(30.0)
        .seed(5)
        .adapt(apbcfw::sim::adapt::AdaptSpec {
            step: apbcfw::sim::adapt::StepPolicy::Kappa,
            batch: apbcfw::sim::adapt::BatchPolicy::Auto { min: 1, max: 4 },
            ..Default::default()
        });
    let net = solve_loopback(spec, "gfl", &cfg, "127.0.0.1:0")
        .unwrap_or_else(|e| panic!("adaptive loopback failed: {e:#}"));
    assert!(net.counters.updates_applied > 0);
    assert!(net.last().unwrap().objective.is_finite());
    assert!(
        net.counters.batch_resizes > 0,
        "cheap uniform loopback pulls must grow the adaptive batch: {:?}",
        net.counters
    );
    // Injected stalls make some applied update demonstrably stale; the
    // kappa EMA sees it before that apply's gamma, so any nonzero
    // applied delay forces a nonzero damping deficit.
    if net.counters.delay_sum > 0 {
        assert!(
            net.counters.gamma_damped_sum > 0,
            "observed delay left the step schedule undamped: {:?}",
            net.counters
        );
    }
}

// ---------------------------------------------------------------------
// Crash recovery (wire v5): generation fencing, checkpoint/restore
// ---------------------------------------------------------------------

#[test]
fn stale_generation_update_is_fenced_and_leaves_the_param_untouched() {
    // Wire v5's generation fence: an Update stamped with a generation
    // other than the apply core's current one must be counted and
    // dropped — never applied. The probe run receives one valid-looking
    // oracle under a bogus generation; the control run receives nothing
    // at all. Both must end with the same (initial) parameter bits.
    let mut params: Vec<Vec<u32>> = Vec::new();
    for send_stale in [true, false] {
        let mut cfg = qp_cfg();
        // The lone client drops its connection, emptying the fleet; a
        // short grace window ends the run promptly.
        cfg.set("run.accept_timeout_secs", "0.5");
        let spec = RunSpec::new(Engine::asynchronous(1))
            .tau(1)
            .max_epochs(50.0)
            .max_secs(20.0)
            .seed(5);
        let session = apbcfw::runtime::service::spawn_serve(
            spec,
            "qp",
            &cfg,
            "127.0.0.1:0",
        )
        .unwrap();
        let mut stream = std::net::TcpStream::connect(session.addr).unwrap();
        let hello = match wire::read_frame(&mut stream).unwrap().unwrap() {
            (Msg::Hello(h), _) => h,
            (other, _) => panic!("expected Hello, got {other:?}"),
        };
        assert_eq!(hello.generation, 0, "fresh serve must start at gen 0");
        assert_eq!(hello.resume_draws, 0, "fresh serve never fast-forwards");
        if send_stale {
            // Valid in every other respect (block in range, payload of
            // the instance's dimension, k_read current), so the fence is
            // the only thing that can drop it.
            let mut buf = Vec::new();
            let msg = Msg::Update {
                k_read: 0,
                worker: hello.worker_id,
                generation: hello.generation + 7,
                oracles: vec![BlockOracle::dense(0, vec![0.5; 5], 1.0)],
            };
            wire::write_frame(&mut stream, &msg, &mut buf).unwrap();
        }
        drop(stream);
        let report = session.join().unwrap();
        assert_eq!(
            report.counters.updates_applied, 0,
            "a fenced update must never be applied"
        );
        assert_eq!(
            report.counters.stale_fenced,
            u64::from(send_stale),
            "{:?}",
            report.counters
        );
        params.push(bits(&report.raw_param));
    }
    assert_eq!(
        params[0], params[1],
        "the fenced update must leave the parameter untouched"
    );
}

#[test]
fn crash_restore_loopback_bit_identical_to_uninterrupted_run() {
    // The tentpole end-to-end pin: a one-worker loopback solve killed by
    // deterministic crash injection after 50 applied updates and
    // auto-restored from its durable checkpoint must finish with exactly
    // the bits of the same solve run without the crash — final parameter
    // and every trace sample. The checkpoint carries the master iterate,
    // gamma/sampler clock (k), counters, and problem server state; the
    // reconnecting worker fast-forwards its draw stream by the announced
    // `resume_draws` and re-enters at generation 1, so the replayed tail
    // is draw-for-draw the uninterrupted schedule.
    let dir = std::env::temp_dir()
        .join(format!("apbcfw-crash-restore-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = gfl_cfg();
    cfg.set("run.checkpoint_dir", dir.to_str().unwrap());
    cfg.set("run.checkpoint_every", "20");
    cfg.set("run.chaos", "crash:50");
    let spec = shared_knobs(RunSpec::new(Engine::asynchronous(1)), 8.0);
    let crashed = solve_loopback(spec, "gfl", &cfg, "127.0.0.1:0")
        .unwrap_or_else(|e| panic!("crash+restore loopback failed: {e:#}"));
    std::fs::remove_dir_all(&dir).ok();

    let ref_spec = shared_knobs(RunSpec::new(Engine::asynchronous(1)), 8.0);
    let clean =
        solve_loopback(ref_spec, "gfl", &gfl_cfg(), "127.0.0.1:0").unwrap();

    assert!(
        crashed.counters.checkpoints_written >= 1,
        "{:?}",
        crashed.counters
    );
    assert!(crashed.counters.restores >= 1, "{:?}", crashed.counters);
    // The restored counters keep the epoch budget global across the
    // crash: re-executed post-checkpoint work replaces (not adds to) the
    // lost session's tail, so the budgets land identically.
    assert_eq!(
        crashed.counters.oracle_calls, clean.counters.oracle_calls,
        "oracle budgets diverged across the crash"
    );
    assert_eq!(
        bits(&crashed.raw_param),
        bits(&clean.raw_param),
        "crash+restore diverged from the uninterrupted solve"
    );
    assert_eq!(crashed.trace.samples.len(), clean.trace.samples.len());
    for (a, b) in
        crashed.trace.samples.iter().zip(clean.trace.samples.iter())
    {
        assert_eq!(a.iter, b.iter, "sample iteration");
        assert_eq!(a.oracle_calls, b.oracle_calls, "sample oracle calls");
        assert_eq!(
            a.objective.to_bits(),
            b.objective.to_bits(),
            "objective bits at iter {}",
            a.iter
        );
        assert_eq!(
            a.gap.to_bits(),
            b.gap.to_bits(),
            "gap-estimate bits at iter {}",
            a.iter
        );
    }
}

#[test]
fn two_shard_crash_restore_resumes_both_shards_and_matches_clean_twin() {
    // Coordinated recovery across the sharded plane: with crash:30 every
    // shard aborts its first generation after 30 applied updates, so BOTH
    // shards crash, restore from their own durable checkpoints, and
    // resume under the bumped generation. `--restore` (run.restore) is
    // stated explicitly, matching the operator drill. The pins: each
    // shard wrote checkpoints and restored (counters aggregate across
    // the plane, so restores >= 2 means neither shard fell back to a
    // fresh start), the per-shard epoch budgets stay global across the
    // crash, and the finished solve lands on the uninterrupted twin's
    // objective to the sharded tolerance (the apply interleaving across
    // two clocks is not bit-reproducible, the telemetry is).
    let dir = std::env::temp_dir()
        .join(format!("apbcfw-2shard-restore-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let epochs = 120.0;
    let mut cfg = gfl_cfg();
    cfg.set("run.shards", "2");
    cfg.set("run.checkpoint_dir", dir.to_str().unwrap());
    cfg.set("run.checkpoint_every", "10");
    cfg.set("run.restore", "true");
    cfg.set("run.chaos", "crash:30");
    let spec = shared_knobs(RunSpec::new(Engine::asynchronous(1)), epochs);
    let crashed = solve_loopback(spec, "gfl", &cfg, "127.0.0.1:0")
        .unwrap_or_else(|e| panic!("2-shard crash+restore failed: {e:#}"));
    std::fs::remove_dir_all(&dir).ok();

    let mut clean_cfg = gfl_cfg();
    clean_cfg.set("run.shards", "2");
    let clean = solve_loopback(
        shared_knobs(RunSpec::new(Engine::asynchronous(1)), epochs),
        "gfl",
        &clean_cfg,
        "127.0.0.1:0",
    )
    .unwrap();

    assert!(
        crashed.counters.checkpoints_written >= 2,
        "both shards must persist checkpoints: {:?}",
        crashed.counters
    );
    assert!(
        crashed.counters.restores >= 2,
        "both shards must resume from their checkpoints (a fresh start \
         would under-count): {:?}",
        crashed.counters
    );
    // The lockstep worker is never stale on either shard, crash or not.
    assert_eq!(crashed.counters.dropped, 0, "{:?}", crashed.counters);
    assert_eq!(crashed.counters.delay_sum, 0, "{:?}", crashed.counters);
    assert!(crashed.counters.updates_applied > 60, "{:?}", crashed.counters);
    // Budget telemetry matches the twin's shape: the restored shards
    // replace (not replay-on-top-of) the lost tails, so the aggregate
    // lands in the same band the clean sharded run does.
    let (a, b) = (crashed.counters.oracle_calls, clean.counters.oracle_calls);
    assert!(
        a > b / 2 && a <= b + b / 2,
        "post-restore oracle budget {a} out of band vs clean twin {b}"
    );
    let obj = crashed.last().unwrap().objective;
    let ref_obj = clean.last().unwrap().objective;
    assert!(
        (obj - ref_obj).abs() <= 0.1 * ref_obj.abs().max(1.0),
        "2-shard crash+restore objective {obj} vs clean twin {ref_obj}"
    );
}

#[test]
fn checkpoint_every_zero_default_stays_bit_identical() {
    // `run.checkpoint_every = 0` (the documented default, spelled out)
    // must keep the serve plane behavior-identical to the pre-v5 fleet:
    // no checkpoint writes, no restore probing, and the one-worker
    // bit-identity pin still holds.
    let mut cfg = gfl_cfg();
    cfg.set("run.checkpoint_every", "0");
    assert_loopback_matches_delayed("gfl", &cfg, 8.0, PayloadMode::Auto);
}

// ---------------------------------------------------------------------
// Codec round-trip property tests
// ---------------------------------------------------------------------

fn random_payload(rng: &mut Pcg64, dim: usize) -> OraclePayload {
    match rng.below(3) {
        0 => OraclePayload::Dense(rng.gaussian_vec(dim)),
        1 => {
            // Random strictly-ascending support (possibly empty).
            let mut idx: Vec<u32> = Vec::new();
            for i in 0..dim {
                if rng.below(3) == 0 {
                    idx.push(i as u32);
                }
            }
            let val = rng.gaussian_vec(idx.len());
            OraclePayload::Sparse {
                idx,
                val,
                dim: dim as u32,
            }
        }
        _ => OraclePayload::Sparse {
            idx: Vec::new(),
            val: Vec::new(),
            dim: dim as u32,
        },
    }
}

#[test]
fn randomized_update_frames_roundtrip_bit_exactly() {
    let mut rng = Pcg64::seeded(42);
    let mut buf = Vec::new();
    for trial in 0..200 {
        let nor = 1 + rng.below(5);
        let dim = 1 + rng.below(33);
        let oracles: Vec<BlockOracle> = (0..nor)
            .map(|_| BlockOracle {
                block: rng.below(1000),
                s: random_payload(&mut rng, dim),
                ls: rng.gaussian(),
            })
            .collect();
        let msg = Msg::Update {
            k_read: rng.below(1 << 30) as u64,
            worker: rng.below(64) as u32,
            generation: rng.below(1 << 16) as u64,
            oracles,
        };
        let n = wire::encode_frame(&msg, &mut buf);
        let mut cursor: &[u8] = &buf;
        let (decoded, consumed) =
            wire::read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(consumed, n, "trial {trial}");
        // PartialEq on Msg covers block/ls/payload, representation
        // included: a sparse payload must come back Sparse.
        assert_eq!(decoded, msg, "trial {trial}");
    }
}

#[test]
fn randomized_snapshot_frames_roundtrip_bit_exactly() {
    let mut rng = Pcg64::seeded(7);
    let mut buf = Vec::new();
    for _ in 0..100 {
        let dim = rng.below(64);
        let body = if rng.below(2) == 0 {
            wire::SnapshotBody::Full(rng.gaussian_vec(dim))
        } else {
            let nruns = rng.below(4);
            wire::SnapshotBody::Delta(
                (0..nruns)
                    .map(|_| {
                        (rng.below(1000) as u32,
                         rng.gaussian_vec(1 + rng.below(8)))
                    })
                    .collect(),
            )
        };
        let msg = Msg::Snapshot {
            version: rng.below(1 << 20) as u64,
            body,
        };
        let n = wire::encode_frame(&msg, &mut buf);
        let (decoded, consumed) =
            wire::read_frame(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!(consumed, n);
        assert_eq!(decoded, msg);
    }
}
