//! Concurrency stress for the wide-word [`SharedParam`], run over BOTH
//! storage layouts (packed, and the cacheline-padded NUMA-study layout —
//! same semantics, different false-sharing profile):
//!
//! - Torn mode, odd (non-u64-aligned) length: concurrent whole-vector
//!   publishers + readers must never produce a value that was not written
//!   by *some* publisher — lanes may mix iterations (paper §2.3) but a
//!   word-packed store must never corrupt a lane.
//! - Concurrent `publish_range` writers over adjacent ranges sharing a
//!   boundary word must not clobber each other's lanes.
//! - Consistent mode: readers must NEVER observe a torn snapshot (every
//!   element from the same publish).

use apbcfw::coordinator::shared::{ParamLayout, SharedParam, SnapshotMode};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const LAYOUTS: [ParamLayout; 2] = [ParamLayout::Packed, ParamLayout::Padded];

#[test]
fn torn_mode_odd_length_values_never_corrupt() {
    for layout in LAYOUTS {
        torn_mode_odd_length_values_never_corrupt_in(layout);
    }
}

fn torn_mode_odd_length_values_never_corrupt_in(layout: ParamLayout) {
    // Publishers write constant vectors (value = publisher id + 1); any
    // element a reader sees must be 0 (init) or one of those constants.
    let len = 33; // odd: exercises the half-used tail word
    let init = vec![0.0f32; len];
    let sp =
        Arc::new(SharedParam::with_layout(&init, SnapshotMode::Torn, layout));
    let stop = Arc::new(AtomicBool::new(false));
    let mut writer_handles = Vec::new();
    for wid in 0..3u32 {
        let sp = Arc::clone(&sp);
        let stop = Arc::clone(&stop);
        writer_handles.push(std::thread::spawn(move || {
            let vals = vec![(wid + 1) as f32; len];
            let mut v = 0u64;
            while !stop.load(Ordering::Relaxed) {
                v += 1;
                sp.publish(&vals, v);
            }
        }));
    }
    let mut reader_handles = Vec::new();
    for _ in 0..4 {
        let sp = Arc::clone(&sp);
        reader_handles.push(std::thread::spawn(move || {
            let mut buf = Vec::new();
            for _ in 0..20_000 {
                sp.read(&mut buf);
                assert_eq!(buf.len(), len);
                for (i, &x) in buf.iter().enumerate() {
                    assert!(
                        x == 0.0 || x == 1.0 || x == 2.0 || x == 3.0,
                        "corrupt lane value {x} at {i}"
                    );
                }
            }
        }));
    }
    for r in reader_handles {
        r.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    for w in writer_handles {
        w.join().unwrap();
    }
}

#[test]
fn concurrent_range_publishers_do_not_clobber_neighbor_lanes() {
    for layout in LAYOUTS {
        // Two writers own adjacent odd-length ranges [0, 5) and [5, 9):
        // the boundary element pair (4, 5) shares one u64 word in either
        // layout. After any number of concurrent publishes, each element
        // must hold its own writer's value exactly.
        let len = 9;
        let init = vec![0.0f32; len];
        let sp = Arc::new(SharedParam::with_layout(
            &init,
            SnapshotMode::Torn,
            layout,
        ));
        let mut handles = Vec::new();
        for (lo, hi, base) in [(0usize, 5usize, 100.0f32), (5, 9, 200.0)] {
            let sp = Arc::clone(&sp);
            handles.push(std::thread::spawn(move || {
                let vals: Vec<f32> =
                    (lo..hi).map(|i| base + i as f32).collect();
                for _ in 0..50_000 {
                    sp.publish_range(lo, &vals);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let v = sp.read_vec();
        for (i, &x) in v.iter().enumerate() {
            let expect =
                if i < 5 { 100.0 + i as f32 } else { 200.0 + i as f32 };
            assert_eq!(x, expect, "element {i} ({layout:?})");
        }
    }
}

#[test]
fn concurrent_fetch_add_across_lane_pairs_is_exact() {
    for layout in LAYOUTS {
        // Hogwild updates on an odd-length vector: every lane (both
        // halves of interior words and the lone tail lane) must sum
        // exactly.
        let len = 5;
        let init = vec![0.0f32; len];
        let sp = Arc::new(SharedParam::with_layout(
            &init,
            SnapshotMode::Torn,
            layout,
        ));
        let mut handles = Vec::new();
        for t in 0..10usize {
            let sp = Arc::clone(&sp);
            handles.push(std::thread::spawn(move || {
                for _ in 0..8_000 {
                    sp.fetch_add_f32(t % len, 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let v = sp.read_vec();
        // 10 threads round-robin over 5 indices: 2 threads per index.
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, 16_000.0, "element {i} ({layout:?})");
        }
    }
}

#[test]
fn consistent_mode_never_observes_torn_snapshot() {
    for layout in LAYOUTS {
        consistent_mode_never_observes_torn_snapshot_in(layout);
    }
}

fn consistent_mode_never_observes_torn_snapshot_in(layout: ParamLayout) {
    // Publishers write uniform vectors; under Consistent mode every
    // snapshot must be uniform (all elements from one publish).
    let len = 33; // odd again
    let init = vec![0.0f32; len];
    let sp = Arc::new(SharedParam::with_layout(
        &init,
        SnapshotMode::Consistent,
        layout,
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let mut writer_handles = Vec::new();
    for wid in 0..2u32 {
        let sp = Arc::clone(&sp);
        let stop = Arc::clone(&stop);
        writer_handles.push(std::thread::spawn(move || {
            let mut v = 0u64;
            while !stop.load(Ordering::Relaxed) {
                v += 1;
                let val = (wid * 1_000_000 + (v % 1_000) as u32) as f32;
                let vals = vec![val; len];
                sp.publish(&vals, v);
            }
        }));
    }
    let mut reader_handles = Vec::new();
    for _ in 0..4 {
        let sp = Arc::clone(&sp);
        reader_handles.push(std::thread::spawn(move || {
            let mut buf = Vec::new();
            for _ in 0..10_000 {
                sp.read(&mut buf);
                assert_eq!(buf.len(), len);
                let first = buf[0];
                assert!(
                    buf.iter().all(|&x| x == first),
                    "torn consistent snapshot: {buf:?}"
                );
            }
        }));
    }
    for r in reader_handles {
        r.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    for w in writer_handles {
        w.join().unwrap();
    }
}

#[test]
fn torn_mode_version_gated_snapshot_reuse_pattern() {
    // The worker pattern: re-read only on version change. Interleave
    // publishes and reads and verify the version counter orders them.
    let sp = SharedParam::new(&[1.0, 2.0, 3.0]);
    let mut snap = Vec::new();
    let mut seen = sp.version();
    sp.read(&mut snap);
    assert_eq!(snap, vec![1.0, 2.0, 3.0]);
    sp.publish(&[4.0, 5.0, 6.0], seen + 1);
    assert!(sp.version() > seen);
    seen = sp.version();
    sp.read(&mut snap);
    assert_eq!(snap, vec![4.0, 5.0, 6.0]);
    assert_eq!(sp.version(), seen);
}
