//! Hot-path equivalence properties (§Perf acceptance):
//!
//! 1. `Problem::oracle_into` must be BIT-IDENTICAL to `Problem::oracle`
//!    for all four problems, including when the output slot AND the
//!    caller-owned scratch are dirty from a previous (different-block,
//!    even different-instance) solve — buffer reuse must not leak.
//! 2. The caller-owned scratch must be REENTRANT: two differently-shaped
//!    instances of the same problem type alternating `oracle_into` calls
//!    on one thread (each with its own scratch) must produce exactly what
//!    fresh-scratch calls produce — the RefCell resize-thrash case the
//!    historical thread-local scratch could not express safely. The
//!    scratch is also `Send`, so it can move with its worker.
//! 3. The SIMD-dispatched kernels must match the scalar references within
//!    ULP-scale tolerance across sizes 0..64 and large random vectors
//!    (reductions re-associate; elementwise ops differ only by FMA).

use apbcfw::data::{mixture, ocr_like, signal};
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::simplex_qp::SimplexQp;
use apbcfw::problems::ssvm::chain::ChainSsvm;
use apbcfw::problems::ssvm::multiclass::MulticlassSsvm;
use apbcfw::problems::{
    ApplyOptions, BlockOracle, OracleScratch, PayloadKind, Problem,
};
use apbcfw::util::la;
use apbcfw::util::proptest::check;
use apbcfw::util::simd;
use std::sync::Arc;

/// Assert two oracles are identical to the bit, comparing payloads through
/// their DENSIFIED form (the payload representation contract: a sparse
/// payload must densify to exactly the dense emission's bits).
fn assert_oracle_bits_eq(a: &BlockOracle, b: &BlockOracle, ctx: &str) {
    assert_eq!(a.block, b.block, "{ctx}: block");
    assert_eq!(a.ls.to_bits(), b.ls.to_bits(), "{ctx}: ls");
    a.s.debug_check_invariants();
    b.s.debug_check_invariants();
    let da = a.s.to_dense_vec();
    let db = b.s.to_dense_vec();
    assert_eq!(da.len(), db.len(), "{ctx}: payload length");
    for (j, (x, y)) in da.iter().zip(db.iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: payload[{j}] {x} vs {y}"
        );
    }
}

/// Drive `oracle` vs `oracle_into` over random params/blocks, reusing one
/// dirty slot (per requested representation) AND one dirty caller-owned
/// scratch throughout to exercise buffer reuse.
fn check_problem_equivalence<P: Problem>(p: &P, cases: usize, seed: u64) {
    for kind in [PayloadKind::Dense, PayloadKind::Sparse] {
        let mut slot = BlockOracle::empty_with(kind);
        let mut scratch = OracleScratch::<P>::default();
        check(cases, seed, |g| {
            let dim = p.param_dim();
            let param = g.gaussian_vec(dim);
            let block = g.usize_in(0, p.num_blocks() - 1);
            let reference = p.oracle(&param, block);
            p.oracle_into(&param, block, &mut scratch, &mut slot);
            assert_oracle_bits_eq(&slot, &reference, p.name());
        });
    }
}

#[test]
fn gfl_oracle_into_is_bit_identical() {
    let sig = signal::piecewise_constant(7, 41, 5, 2.0, 0.5, 11);
    let gfl = Gfl::new(7, 41, 0.25, sig.noisy.clone());
    check_problem_equivalence(&gfl, 100, 301);
}

#[test]
fn gfl_oracle_into_handles_zero_gradient() {
    // All-zero observations give a zero gradient column at u = 0: the
    // zero-norm branch must also match bit-for-bit.
    let gfl = Gfl::new(3, 5, 0.5, vec![0.0; 15]);
    let u = gfl.init_param();
    let mut slot = BlockOracle::empty();
    for t in 0..gfl.m {
        let reference = gfl.oracle(&u, t);
        gfl.oracle_into(&u, t, &mut (), &mut slot);
        assert_oracle_bits_eq(&slot, &reference, "gfl-zero");
    }
}

#[test]
fn simplex_qp_oracle_into_is_bit_identical() {
    let qp = SimplexQp::random(12, 5, 1.0, 0.4, 3, 17);
    check_problem_equivalence(&qp, 100, 302);
}

#[test]
fn chain_ssvm_oracle_into_is_bit_identical() {
    let data = Arc::new(ocr_like::generate(20, 5, 9, 6, 0.15, 23));
    let chain = ChainSsvm::new(data, 0.1);
    check_problem_equivalence(&chain, 60, 303);
}

#[test]
fn multiclass_ssvm_oracle_into_is_bit_identical() {
    let data = Arc::new(mixture::generate(40, 6, 11, 0.2, 29));
    let mc = MulticlassSsvm::new(data, 0.05);
    check_problem_equivalence(&mc, 100, 304);
}

#[test]
fn oracle_into_slot_reuse_is_stateless() {
    // Filling the same slot with different blocks in sequence must give
    // the same answers as fresh slots (no state bleeds through the buffer).
    let sig = signal::piecewise_constant(6, 30, 4, 2.0, 0.5, 31);
    let gfl = Gfl::new(6, 30, 0.2, sig.noisy.clone());
    let u = gfl.init_param();
    let mut reused = BlockOracle::empty();
    for pass in 0..3 {
        for t in 0..gfl.m {
            gfl.oracle_into(&u, t, &mut (), &mut reused);
            let fresh = gfl.oracle(&u, t);
            assert_oracle_bits_eq(&reused, &fresh, "reuse");
        }
        let _ = pass;
    }
}

// ---------------------------------------------------------------------------
// Payload representation equivalence: sparse == dense, bit for bit
// ---------------------------------------------------------------------------

/// Run the same scripted solve twice — dense-slot oracles vs sparse-slot
/// oracles — and pin param, ApplyInfo, per-oracle block_gap, and objective
/// bit-identical every iteration (the `run.payload` contract).
fn check_payload_representation_equivalence<P: Problem>(
    p: &P,
    iters: usize,
    seed: u64,
) {
    use apbcfw::solver::schedule_gamma;
    use apbcfw::util::rng::Pcg64;
    let n = p.num_blocks();
    let tau = 3.min(n);
    let mut param_d = p.init_param();
    let mut state_d = p.init_server();
    let mut param_s = p.init_param();
    let mut state_s = p.init_server();
    let mut sc_d = OracleScratch::<P>::default();
    let mut sc_s = OracleScratch::<P>::default();
    let mut slots_d: Vec<BlockOracle> = (0..tau)
        .map(|_| BlockOracle::empty_with(PayloadKind::Dense))
        .collect();
    let mut slots_s: Vec<BlockOracle> = (0..tau)
        .map(|_| BlockOracle::empty_with(PayloadKind::Sparse))
        .collect();
    let mut rng = Pcg64::seeded(seed);
    for k in 0..iters {
        let blocks = rng.subset(n, tau);
        for ((sd, ss), &i) in
            slots_d.iter_mut().zip(slots_s.iter_mut()).zip(blocks.iter())
        {
            p.oracle_into(&param_d, i, &mut sc_d, sd);
            p.oracle_into(&param_s, i, &mut sc_s, ss);
            assert_oracle_bits_eq(sd, ss, p.name());
            let gd = p.block_gap(&state_d, &param_d, sd);
            let gs = p.block_gap(&state_s, &param_s, ss);
            // block_gap is bit-pinned for the problems whose apply
            // consumes it (parameter-space); the SSVM gather-dot arm is
            // monitoring-only and tolerance-grade.
            assert!(
                gd.to_bits() == gs.to_bits()
                    || (gd - gs).abs() <= 1e-10 * (1.0 + gd.abs()),
                "{}: block_gap {gd} vs {gs}",
                p.name()
            );
        }
        // k = 0 exercises the clamped gamma = 1 step; alternate exact
        // line search to cover both step paths.
        let opts = ApplyOptions {
            gamma: schedule_gamma(n, tau, k as u64),
            line_search: k % 2 == 1,
        };
        let info_d = p.apply(&mut state_d, &mut param_d, &slots_d, opts);
        let info_s = p.apply(&mut state_s, &mut param_s, &slots_s, opts);
        assert_eq!(
            info_d.gamma.to_bits(),
            info_s.gamma.to_bits(),
            "{} k={k}: gamma {} vs {}",
            p.name(),
            info_d.gamma,
            info_s.gamma
        );
        assert_eq!(
            info_d.batch_gap.to_bits(),
            info_s.batch_gap.to_bits(),
            "{} k={k}: batch_gap {} vs {}",
            p.name(),
            info_d.batch_gap,
            info_s.batch_gap
        );
        for (j, (a, b)) in param_d.iter().zip(param_s.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{} k={k}: param[{j}] {a} vs {b}",
                p.name()
            );
        }
        let od = p.objective(&state_d, &param_d);
        let os = p.objective(&state_s, &param_s);
        assert_eq!(
            od.to_bits(),
            os.to_bits(),
            "{} k={k}: objective {od} vs {os}",
            p.name()
        );
    }
}

#[test]
fn payload_sparse_equals_dense_gfl() {
    // GFL is the dense-fallback proof: sparse-requested slots come back
    // dense and the run is trivially identical.
    let sig = signal::piecewise_constant(6, 30, 4, 2.0, 0.5, 71);
    let gfl = Gfl::new(6, 30, 0.25, sig.noisy.clone());
    check_payload_representation_equivalence(&gfl, 40, 601);
}

#[test]
fn payload_sparse_equals_dense_simplex_qp() {
    let qp = SimplexQp::random(14, 5, 1.0, 0.4, 3, 73);
    check_payload_representation_equivalence(&qp, 40, 602);
}

#[test]
fn payload_sparse_equals_dense_chain_ssvm() {
    let data = Arc::new(ocr_like::generate(18, 5, 9, 6, 0.15, 79));
    let chain = ChainSsvm::new(data, 0.1);
    check_payload_representation_equivalence(&chain, 30, 603);
}

#[test]
fn payload_sparse_equals_dense_multiclass_ssvm() {
    let data = Arc::new(mixture::generate(30, 6, 11, 0.2, 83));
    let mc = MulticlassSsvm::new(data, 0.05);
    check_payload_representation_equivalence(&mc, 40, 604);
}

#[test]
fn sparse_slot_reuse_across_blocks_is_stateless() {
    // One sparse slot cycled through every block repeatedly must keep
    // densifying to the fresh dense oracle — stale idx/val content from a
    // previous (larger-support) fill must not leak.
    let data = Arc::new(ocr_like::generate(12, 4, 7, 5, 0.15, 89));
    let chain = ChainSsvm::new(data, 0.1);
    let mut rng = apbcfw::util::rng::Pcg64::seeded(90);
    let w: Vec<f32> = rng.gaussian_vec(chain.dim());
    let mut sc = OracleScratch::<ChainSsvm>::default();
    let mut slot = BlockOracle::empty_with(PayloadKind::Sparse);
    for _pass in 0..3 {
        for i in 0..chain.num_blocks() {
            chain.oracle_into(&w, i, &mut sc, &mut slot);
            assert_oracle_bits_eq(&slot, &chain.oracle(&w, i), "sparse-reuse");
        }
    }
}

// ---------------------------------------------------------------------------
// Caller-owned scratch: reentrancy across differently-shaped instances
// ---------------------------------------------------------------------------

/// Alternate `oracle_into` between two differently-shaped instances of one
/// problem type on a single thread, each with its OWN caller-owned scratch
/// reused across the whole interleaving, and pin every output against a
/// fresh-scratch `oracle` call. Under the historical `thread_local!`
/// scratch this access pattern resized the shared buffers on every single
/// call (the ROADMAP's "resize-thrash" case) and the `RefCell` made any
/// reentrant use a runtime panic; with caller-owned scratch it is
/// allocation-free after warm-up and trivially correct.
fn check_interleaved_reentrancy<P: Problem>(a: &P, b: &P, seed: u64) {
    let mut sc_a = OracleScratch::<P>::default();
    let mut sc_b = OracleScratch::<P>::default();
    let mut slot_a = BlockOracle::empty();
    let mut slot_b = BlockOracle::empty();
    check(40, seed, |g| {
        let pa = g.gaussian_vec(a.param_dim());
        let pb = g.gaussian_vec(b.param_dim());
        let ba = g.usize_in(0, a.num_blocks() - 1);
        let bb = g.usize_in(0, b.num_blocks() - 1);
        // a then b then a again: the second a-call sees a scratch whose
        // sibling instance ran in between.
        a.oracle_into(&pa, ba, &mut sc_a, &mut slot_a);
        assert_oracle_bits_eq(&slot_a, &a.oracle(&pa, ba), "interleave-a1");
        b.oracle_into(&pb, bb, &mut sc_b, &mut slot_b);
        assert_oracle_bits_eq(&slot_b, &b.oracle(&pb, bb), "interleave-b");
        a.oracle_into(&pa, ba, &mut sc_a, &mut slot_a);
        assert_oracle_bits_eq(&slot_a, &a.oracle(&pa, ba), "interleave-a2");
    });
}

#[test]
fn chain_scratch_reentrant_across_shapes() {
    // Different K, d, AND ell: every Viterbi buffer (theta, alpha, ptr,
    // ys) would need a different size in each instance.
    let small = ChainSsvm::new(
        Arc::new(ocr_like::generate(8, 3, 5, 4, 0.1, 41)),
        0.1,
    );
    let large = ChainSsvm::new(
        Arc::new(ocr_like::generate(6, 6, 11, 7, 0.1, 43)),
        0.2,
    );
    check_interleaved_reentrancy(&small, &large, 501);
}

#[test]
fn qp_scratch_reentrant_across_shapes() {
    // Different m AND p: both the z = A^T x buffer and the gradient
    // buffer change shape between instances.
    let small = SimplexQp::random(6, 3, 1.0, 0.3, 2, 47);
    let large = SimplexQp::random(9, 7, 1.0, 0.5, 5, 53);
    check_interleaved_reentrancy(&small, &large, 502);
}

#[test]
fn scratch_is_send_and_moves_with_its_worker() {
    fn assert_send<T: Send + Default>() -> T {
        T::default()
    }
    // Compile-time: every problem's scratch satisfies `Send + Default`.
    let chain_sc = assert_send::<OracleScratch<ChainSsvm>>();
    let qp_sc = assert_send::<OracleScratch<SimplexQp>>();
    assert_send::<OracleScratch<Gfl>>();
    assert_send::<OracleScratch<MulticlassSsvm>>();
    // Runtime: a warm scratch can move to another thread and keep
    // producing bit-identical oracles there.
    let data = Arc::new(ocr_like::generate(10, 4, 6, 5, 0.1, 59));
    let chain = ChainSsvm::new(data, 0.1);
    let qp = SimplexQp::random(8, 4, 1.0, 0.2, 3, 61);
    let mut chain_sc = chain_sc;
    let mut qp_sc = qp_sc;
    let mut slot = BlockOracle::empty();
    let wc = {
        let mut rng = apbcfw::util::rng::Pcg64::seeded(63);
        rng.gaussian_vec(chain.dim())
    };
    let wq = qp.init_param();
    chain.oracle_into(&wc, 1, &mut chain_sc, &mut slot); // warm it up
    qp.oracle_into(&wq, 2, &mut qp_sc, &mut slot);
    std::thread::scope(|scope| {
        scope.spawn(move || {
            let mut slot = BlockOracle::empty();
            chain.oracle_into(&wc, 3, &mut chain_sc, &mut slot);
            assert_oracle_bits_eq(&slot, &chain.oracle(&wc, 3), "send-chain");
            qp.oracle_into(&wq, 5, &mut qp_sc, &mut slot);
            assert_oracle_bits_eq(&slot, &qp.oracle(&wq, 5), "send-qp");
        });
    });
}

// ---------------------------------------------------------------------------
// SIMD kernel vs scalar reference
// ---------------------------------------------------------------------------

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn simd_reductions_match_scalar_small_sizes() {
    check(200, 401, |g| {
        let n = g.usize_in(0, 64);
        let x = g.gaussian_vec(n);
        let y = g.gaussian_vec(n);
        assert!(
            rel_close(la::dot(&x, &y), simd::dot_scalar(&x, &y), 1e-12),
            "dot n={n}"
        );
        assert!(
            rel_close(la::norm2_sq(&x), simd::norm2_sq_scalar(&x), 1e-12),
            "norm2_sq n={n}"
        );
    });
}

#[test]
fn simd_reductions_match_scalar_large_vectors() {
    check(20, 402, |g| {
        let n = g.usize_in(1000, 8192);
        let x = g.gaussian_vec(n);
        let y = g.gaussian_vec(n);
        // Pairwise vs sequential summation: difference is bounded by the
        // summation error, far below 1e-10 relative at these sizes.
        assert!(
            rel_close(la::dot(&x, &y), simd::dot_scalar(&x, &y), 1e-10),
            "dot n={n}"
        );
        assert!(
            rel_close(la::norm2_sq(&x), simd::norm2_sq_scalar(&x), 1e-10),
            "norm2_sq n={n}"
        );
    });
}

#[test]
fn simd_elementwise_match_scalar_within_fma_ulp() {
    check(100, 403, |g| {
        let n = g.usize_in(0, 64);
        let a = g.f32_in(-2.0, 2.0);
        let x = g.gaussian_vec(n);
        let y0 = g.gaussian_vec(n);

        let mut ys = y0.clone();
        let mut yv = y0.clone();
        simd::axpy_scalar(a, &x, &mut ys);
        la::axpy(a, &x, &mut yv);
        for (j, (s, v)) in ys.iter().zip(yv.iter()).enumerate() {
            let d = (*s as f64 - *v as f64).abs();
            assert!(
                d <= 1e-6 * (1.0 + (*s as f64).abs()),
                "axpy n={n} j={j}: {s} vs {v}"
            );
        }

        let mut ls = y0.clone();
        let mut lv = y0.clone();
        let t = g.f32_in(0.0, 1.0);
        simd::lerp_into_scalar(t, &x, &mut ls);
        la::lerp_into(t, &x, &mut lv);
        for (j, (s, v)) in ls.iter().zip(lv.iter()).enumerate() {
            let d = (*s as f64 - *v as f64).abs();
            assert!(
                d <= 1e-6 * (1.0 + (*s as f64).abs()),
                "lerp n={n} j={j}: {s} vs {v}"
            );
        }

        let mut ss = y0.clone();
        let mut sv = y0;
        simd::scale_scalar(a, &mut ss);
        la::scale(a, &mut sv);
        assert_eq!(ss, sv, "scale n={n} (single multiply is exact)");
    });
}

#[test]
fn chunked_fallback_matches_scalar() {
    // The portable path is the production kernel on non-x86 targets; pin
    // it against the scalar reference independently of dispatch.
    check(100, 404, |g| {
        let n = g.usize_in(0, 200);
        let x = g.gaussian_vec(n);
        let y = g.gaussian_vec(n);
        assert!(rel_close(
            simd::dot_chunked(&x, &y),
            simd::dot_scalar(&x, &y),
            1e-12
        ));
        assert!(rel_close(
            simd::norm2_sq_chunked(&x),
            simd::norm2_sq_scalar(&x),
            1e-12
        ));
    });
}
