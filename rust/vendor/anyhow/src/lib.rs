//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored crate
//! implements exactly the subset the workspace uses: an [`Error`] carrying
//! a human-readable context chain, the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros, the [`Context`] extension trait for `Result`/`Option`, and the
//! defaulted [`Result`] alias. Every `std::error::Error` converts into
//! [`Error`] (capturing its source chain), which is what `?` relies on.
//!
//! Matching real `anyhow`, [`Error`] deliberately does NOT implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent.

use std::fmt;

/// Error with a context chain, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (used by `anyhow!`).
    pub fn msg(message: impl fmt::Display) -> Self {
        Self {
            chain: vec![message.to_string()],
        }
    }

    /// Push an outer context layer (used by [`Context`]).
    fn wrap(mut self, context: String) -> Self {
        self.chain.insert(0, context);
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` — plain `Result` with [`Error`] as the default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error with an outer context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e.into())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e = fails_io().context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("bad value {} in {}", 7, "slot");
        assert_eq!(format!("{e}"), "bad value 7 in slot");
        fn inner(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert!(inner(5).is_ok());
        assert!(inner(-1).is_err());
        assert_eq!(format!("{}", inner(11).unwrap_err()), "too big: 11");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }
}
