//! API-compatible stub of the `xla` (PJRT) bindings.
//!
//! The build image does not ship the native XLA extension, so this crate
//! mirrors the handful of types and signatures `apbcfw::runtime` uses and
//! fails fast at the entry point: [`PjRtClient::cpu`] returns an
//! "unavailable" error, which makes `apbcfw::xla_available()` report
//! `false`, the artifact store refuse to open, and every XLA-gated bench,
//! test, and example fall back to the native rust oracles (those paths
//! already handle a missing runtime because artifacts may be absent too).
//!
//! To run the real AOT-artifact path, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual bindings; no source change is needed.

use std::fmt;

/// Error type for every stub operation.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "XLA/PJRT runtime unavailable: built against the offline stub \
         crate (rust/vendor/xla); native rust oracles remain fully \
         functional"
            .to_string(),
    )
}

/// Element types the runtime service can unpack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
    Invalid,
}

/// Marker for element types literals can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Real bindings construct a CPU PJRT client; the stub reports the
    /// runtime as unavailable.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable (stub; unreachable because compile() fails).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Array shape descriptor (stub).
pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }

    pub fn ty(&self) -> ElementType {
        ElementType::Invalid
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub client must not construct"),
        };
        assert!(format!("{err}").contains("unavailable"));
    }

    #[test]
    fn literal_builders_exist_but_ops_fail() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let _ = XlaComputation::from_proto(&HloModuleProto);
    }
}
