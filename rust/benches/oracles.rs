//! Oracle-layer benchmarks: native rust oracles vs the XLA artifact path
//! for every problem (L1/L2 performance surface). Run `make artifacts`
//! first to include the XLA rows.

mod bench_util;

use apbcfw::data::{mixture, ocr_like, signal};
use apbcfw::problems::gfl::{Gfl, GflOracleBackend};
use apbcfw::problems::ssvm::chain::{ChainDecoder, ChainSsvm};
use apbcfw::problems::ssvm::multiclass::{MulticlassDecoder, MulticlassSsvm};
use apbcfw::problems::Problem;
use apbcfw::runtime::service;
use apbcfw::runtime::xla_backends::{
    XlaChainDecoder, XlaGfl, XlaMulticlassDecoder,
};
use apbcfw::util::rng::Pcg64;
use bench_util::bench;
use std::sync::Arc;

fn main() {
    println!("== oracles ==");
    let mut rng = Pcg64::seeded(2);
    let artifacts = std::path::Path::new("artifacts");
    let handle = if artifacts.join("manifest.txt").exists() {
        service::spawn(artifacts).ok()
    } else {
        println!("(artifacts missing — XLA rows skipped)");
        None
    };

    // ---- GFL (paper shape d=10 n=100) ----
    let sig = signal::piecewise_constant(10, 100, 6, 2.0, 0.5, 3);
    let gfl = Gfl::new(10, 100, 0.01, sig.noisy.clone());
    let u = gfl.init_param();
    bench("gfl native oracle (1 block)", 20000, || {
        std::hint::black_box(gfl.oracle(&u, 42));
    });
    let mut slot = apbcfw::problems::BlockOracle::empty();
    bench("gfl native oracle_into (1 block)", 20000, || {
        gfl.oracle_into(&u, 42, &mut (), &mut slot);
        std::hint::black_box(slot.ls);
    });
    bench("gfl native full objective", 5000, || {
        std::hint::black_box(gfl.objective_of(&u));
    });
    if let Some(h) = &handle {
        let be = XlaGfl::new(h.clone(), 10, 100, 0.01, &gfl.b).unwrap();
        bench("gfl XLA full step (all 99 blocks)", 500, || {
            std::hint::black_box(be.step(&u));
        });
    }

    // ---- chain SSVM (paper shape K=26 d=128 L=9) ----
    let data = Arc::new(ocr_like::generate(64, 26, 128, 9, 0.15, 4));
    let chain = ChainSsvm::new(data.clone(), 1.0);
    let w: Vec<f32> = rng.gaussian_vec(chain.dim());
    bench("chain native Viterbi oracle", 2000, || {
        std::hint::black_box(chain.viterbi(&w, 3, 1.0));
    });
    let mut viterbi_sc =
        apbcfw::problems::ssvm::chain::ViterbiScratch::default();
    bench("chain native oracle_into (scratch Viterbi)", 2000, || {
        chain.oracle_into(&w, 3, &mut viterbi_sc, &mut slot);
        std::hint::black_box(slot.ls);
    });
    let mut sparse_slot = apbcfw::problems::BlockOracle::empty_with(
        apbcfw::problems::PayloadKind::Sparse,
    );
    bench("chain native oracle_into (sparse payload)", 2000, || {
        chain.oracle_into(&w, 3, &mut viterbi_sc, &mut sparse_slot);
        std::hint::black_box(sparse_slot.s.nnz());
    });
    bench("chain payload build", 5000, || {
        let ys = chain.viterbi(&w, 3, 1.0).0;
        std::hint::black_box(chain.payload(3, &ys));
    });
    if let Some(h) = &handle {
        let dec = XlaChainDecoder::new(h.clone(), data.clone()).unwrap();
        bench("chain XLA (Pallas) Viterbi oracle", 500, || {
            std::hint::black_box(dec.decode(&w, 3, 1.0));
        });
    }

    // batched chain artifacts: fixed PJRT dispatch amortizes across B
    if let Some(h) = &handle {
        use apbcfw::runtime::service::Tensor;
        for b in [16usize, 64] {
            let name = format!("ssvm_chain_K26_d128_L9_B{b}");
            let wu = w[..26 * 128].to_vec();
            let tr = w[26 * 128..].to_vec();
            let xs = data.features[..b * 9 * 128].to_vec();
            let ys: Vec<i32> =
                data.labels[..b * 9].iter().map(|&v| v as i32).collect();
            let mk_args = || {
                vec![
                    Tensor::F32(wu.clone(), vec![26, 128]),
                    Tensor::F32(tr.clone(), vec![26, 26]),
                    Tensor::F32(xs.clone(), vec![b as i64, 9, 128]),
                    Tensor::I32(ys.clone(), vec![b as i64, 9]),
                    Tensor::F32(vec![1.0], vec![1]),
                ]
            };
            let s = bench(
                &format!("chain XLA Viterbi batched B={b}"),
                200,
                || {
                    std::hint::black_box(h.run(&name, mk_args()).unwrap());
                },
            );
            println!(
                "    -> {:.1} us per sequence (B={b})",
                s.median / 1000.0 / b as f64
            );
        }
    }

    // ---- multiclass SSVM (K=10 d=64) ----
    let mc_data = Arc::new(mixture::generate(64, 10, 64, 0.1, 5));
    let mc = MulticlassSsvm::new(mc_data.clone(), 0.01);
    let wm: Vec<f32> = rng.gaussian_vec(mc.dim());
    bench("multiclass native oracle", 20000, || {
        std::hint::black_box(mc.argmax(&wm, 7, 1.0));
    });
    bench("multiclass native oracle_into", 20000, || {
        mc.oracle_into(&wm, 7, &mut (), &mut slot);
        std::hint::black_box(slot.ls);
    });
    bench("multiclass native oracle_into (sparse payload)", 20000, || {
        mc.oracle_into(&wm, 7, &mut (), &mut sparse_slot);
        std::hint::black_box(sparse_slot.s.nnz());
    });
    if let Some(h) = &handle {
        let dec = XlaMulticlassDecoder::new(h.clone(), mc_data).unwrap();
        bench("multiclass XLA oracle", 1000, || {
            std::hint::black_box(dec.decode(&wm, 7, 1.0));
        });
    }

    // ---- full-gap evaluations (monitoring cost) ----
    bench("gfl full_gap (99 oracles)", 1000, || {
        std::hint::black_box(gfl.full_gap(&(), &u));
    });
}
