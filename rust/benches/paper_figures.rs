//! Paper-figure regeneration bench: runs a scaled-down version of every
//! experiment in DESIGN.md §4 and prints the paper-shaped series. The
//! full-size runs are `apbcfw exp <id> --config config/default.ini`; this
//! bench keeps each figure to a few seconds so `cargo bench` stays usable
//! as a regression harness over ALL tables and figures.

use apbcfw::experiments;
use apbcfw::util::config::Config;

fn main() {
    println!("== paper_figures (scaled-down; full runs via `apbcfw exp`) ==");
    let mut cfg = Config::new();
    // Shrink everything so each figure is seconds, not minutes.
    for (k, v) in [
        ("run.results_dir", "results/bench"),
        // fig1a: small SSVM instance
        ("fig1a.n", "150"),
        ("fig1a.k", "10"),
        ("fig1a.d", "32"),
        ("fig1a.ell", "5"),
        ("fig1a.taus", "1, 4, 16"),
        ("fig1a.thresholds", "0.1, 0.02"),
        ("fig1a.max_epochs", "60"),
        ("fig1a.fstar_epochs", "120"),
        // fig1b: paper-size already small
        ("fig1b.taus", "1, 8, 32"),
        ("fig1b.fstar_epochs", "3000"),
        // fig2: short wall-clock budgets
        ("fig2a.n", "200"),
        ("fig2a.k", "10"),
        ("fig2a.d", "32"),
        ("fig2a.ell", "5"),
        ("fig2a.workers", "4"),
        ("fig2a.tau_multiples", "1, 3"),
        ("fig2a.max_secs", "6"),
        ("fig2a.fstar_epochs", "150"),
        ("fig2b.n", "200"),
        ("fig2b.k", "10"),
        ("fig2b.d", "32"),
        ("fig2b.ell", "5"),
        ("fig2b.workers", "1, 2, 4"),
        ("fig2b.tau_multiples", "1, 2"),
        ("fig2b.max_secs", "6"),
        ("fig2b.fstar_epochs", "150"),
        ("fig2c.n", "200"),
        ("fig2c.k", "10"),
        ("fig2c.d", "32"),
        ("fig2c.ell", "5"),
        ("fig2c.workers", "1, 2, 4"),
        ("fig2c.tau_multiples", "1, 2"),
        ("fig2c.max_secs", "6"),
        ("fig2c.fstar_epochs", "150"),
        ("fig2d.n", "120"),
        ("fig2d.k", "8"),
        ("fig2d.d", "24"),
        ("fig2d.ell", "5"),
        ("fig2d.workers", "1, 2, 4"),
        ("fig2d.tau_multiples", "1, 2"),
        ("fig2d.max_secs", "8"),
        ("fig2d.fstar_epochs", "150"),
        // fig3: fewer passes / workers
        ("fig3a.n", "150"),
        ("fig3a.k", "8"),
        ("fig3a.d", "24"),
        ("fig3a.ell", "5"),
        ("fig3a.workers", "4"),
        ("fig3a.tau", "4"),
        ("fig3a.passes", "4"),
        ("fig3a.probs", "1.0, 0.5, 0.25"),
        ("fig3b.n", "150"),
        ("fig3b.k", "8"),
        ("fig3b.d", "24"),
        ("fig3b.ell", "5"),
        ("fig3b.workers", "4"),
        ("fig3b.tau", "4"),
        ("fig3b.passes", "4"),
        ("fig3b.thetas", "1.0, 0.5, 0.2"),
        // fig4: fewer kappas / reps
        ("fig4.kappas", "0, 5, 15"),
        ("fig4.reps", "2"),
        // fig5 default is fine but shorten
        ("fig5.epochs", "800"),
        // ex1 small
        ("ex1.n", "300"),
        ("ex1.taus", "1, 5, 10, 40"),
        ("ex1.max_epochs", "150"),
        // ex2 small
        ("ex2.taus", "1, 4, 8"),
        ("ex2.subsets", "3"),
        ("ex2.samples", "8"),
        // d4 small
        ("d4.n", "32"),
        ("d4.taus", "1, 4, 8"),
        ("d4.max_epochs", "800"),
        // prop1 small
        ("prop1.reps", "500"),
    ] {
        cfg.set(k, v);
    }
    let t0 = std::time::Instant::now();
    for id in experiments::ALL {
        println!("\n---- {id} ----");
        let t = std::time::Instant::now();
        if let Err(e) = experiments::run(id, &cfg) {
            println!("{id} FAILED: {e:#}");
            std::process::exit(1);
        }
        println!("[{id} done in {:.1}s]", t.elapsed().as_secs_f64());
    }
    println!(
        "\nall {} paper figures regenerated in {:.1}s",
        experiments::ALL.len(),
        t0.elapsed().as_secs_f64()
    );
}
