//! Shared micro-bench harness (criterion is not in the offline vendor set).
//!
//! Usage: `bench("name", iters, || work())` — warms up, measures `iters`
//! batches, prints mean/median/p95 per call in nanoseconds plus throughput.

use apbcfw::util::stats::Summary;
use std::time::Instant;

/// Time `f` `reps` times (after `warmup` calls) and report per-call stats.
pub fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) -> Summary {
    let warmup = (reps / 10).max(3);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e9);
    }
    let s = Summary::of(&samples);
    println!(
        "{name:<44} mean {:>12.1} ns  med {:>12.1} ns  p95 {:>12.1} ns  ({} reps)",
        s.mean, s.median, s.p95, s.n
    );
    s
}

/// Format a rate (ops/sec) from a per-call summary.
#[allow(dead_code)]
pub fn rate(per_call_ns: f64) -> String {
    format!("{:.2} Kops/s", 1e6 / per_call_ns)
}
