//! Micro-benchmarks of the L3 hot-path primitives: vector math, buffer
//! operations, shared-parameter publish/read, and gap accumulation.
//! These are the §Perf targets — see EXPERIMENTS.md §Perf.

mod bench_util;

use apbcfw::coordinator::buffer::BatchAssembler;
use apbcfw::coordinator::shared::SharedParam;
use apbcfw::coordinator::UpdateMsg;
use apbcfw::problems::BlockOracle;
use apbcfw::util::la;
use apbcfw::util::rng::Pcg64;
use bench_util::bench;

fn main() {
    println!("== hot_paths ==");
    let mut rng = Pcg64::seeded(1);

    // axpy / dot at the SSVM parameter dimension (K*d + K*K = 4004)
    let dim = 26 * 128 + 26 * 26;
    let x = rng.gaussian_vec(dim);
    let mut y = rng.gaussian_vec(dim);
    bench("axpy dim=4004", 5000, || {
        la::axpy(0.01, &x, &mut y);
    });
    let mut acc = 0.0;
    bench("dot dim=4004", 5000, || {
        acc += la::dot(&x, &y);
    });
    std::hint::black_box(acc);

    // lerp at the GFL column dimension
    let xc = rng.gaussian_vec(10);
    let mut yc = rng.gaussian_vec(10);
    bench("lerp_into dim=10 (GFL column)", 20000, || {
        la::lerp_into(0.3, &xc, &mut yc);
    });

    // batch assembler: insert + take at tau = 16
    bench("assembler insert+take tau=16 n=1000", 2000, || {
        let mut asm = BatchAssembler::new();
        let mut r = Pcg64::seeded(7);
        while asm.len() < 16 {
            asm.insert(UpdateMsg {
                oracle: BlockOracle {
                    block: r.below(1000),
                    s: vec![0.0; 8],
                    ls: 0.0,
                },
                k_read: 0,
                worker: 0,
            });
        }
        std::hint::black_box(asm.take_batch(16));
    });

    // shared parameter publish + snapshot at SSVM dim
    let sp = SharedParam::new(&x);
    bench("SharedParam publish dim=4004", 5000, || {
        sp.publish(&y, 1);
    });
    let mut buf = Vec::new();
    bench("SharedParam read dim=4004", 5000, || {
        sp.read(&mut buf);
        std::hint::black_box(buf.len());
    });

    // simplex projection (PBCD hot path)
    let mut blk = rng.gaussian_vec(10);
    bench("project_simplex dim=10", 20000, || {
        let mut b = blk.clone();
        la::project_simplex(&mut b);
        std::hint::black_box(&b);
    });
    blk[0] += 1.0;
}
