//! Micro-benchmarks of the L3 hot-path primitives: vector math (scalar vs
//! SIMD-dispatched), shared-parameter publish/read (per-element atomic
//! baseline vs wide-word, packed vs cacheline-padded layout), buffer
//! operations, the allocating vs zero-allocation (`oracle` vs
//! snapshot-reuse + `oracle_into`) worker loops for the GFL and
//! chain-SSVM oracles, the batched fan-out's snapshot-read amortization
//! (reads per applied update at batch 1/4/16, measured on a real async
//! engine run), the sparse-payload pipeline's dense-vs-sparse apply
//! throughput + bytes-per-update rows (fused SSVM apply on dense vs
//! sparse batches; real async runs with `run.payload` forced both ways),
//! and the distributed transport's dense-vs-sparse wire bytes-per-update
//! rows (loopback serve+worker runs through the real TCP codec).
//!
//! These are the §Perf targets — see EXPERIMENTS.md §Perf. Every row is
//! also written to `BENCH_hotpaths.json` at the repo root so the perf
//! trajectory is tracked across PRs (timing rows in ns_per_call; metric
//! rows carry their own `unit`). Run with:
//!
//! ```bash
//! cargo bench --bench hot_paths
//! ```

mod bench_util;

use apbcfw::coordinator::apbcfw as coord;
use apbcfw::coordinator::buffer::BatchAssembler;
use apbcfw::coordinator::shared::{ParamLayout, SharedParam, SnapshotMode};
use apbcfw::coordinator::UpdateMsg;
use apbcfw::data::{mixture, ocr_like, signal};
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::ssvm::chain::{ChainSsvm, ViterbiScratch};
use apbcfw::problems::ssvm::multiclass::MulticlassSsvm;
use apbcfw::problems::{
    ApplyOptions, BlockOracle, PayloadKind, PayloadMode, Problem,
};
use apbcfw::run::{Engine, RunSpec};
use apbcfw::util::rng::Pcg64;
use apbcfw::util::simd;
use bench_util::bench;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// One JSON report row: a timing summary (default ns_per_call) or a plain
/// metric with its own unit.
struct Row {
    name: String,
    mean: f64,
    median: f64,
    p95: f64,
    reps: usize,
    /// Per-row unit override (e.g. "reads_per_update"); None inherits the
    /// report-level ns_per_call.
    unit: Option<&'static str>,
}

/// Collected rows for the JSON report.
struct Report {
    rows: Vec<Row>,
}

impl Report {
    fn new() -> Self {
        Self { rows: Vec::new() }
    }

    fn add<F: FnMut()>(&mut self, name: &str, reps: usize, f: F) {
        let s = bench(name, reps, f);
        self.rows.push(Row {
            name: name.to_string(),
            mean: s.mean,
            median: s.median,
            p95: s.p95,
            reps: s.n,
            unit: None,
        });
    }

    /// Record a single measured metric (mean == median == p95 == value).
    fn add_metric(&mut self, name: &str, unit: &'static str, value: f64) {
        println!("{name:<55} {value:>10.4} {unit}");
        self.rows.push(Row {
            name: name.to_string(),
            mean: value,
            median: value,
            p95: value,
            reps: 1,
            unit: Some(unit),
        });
    }

    fn write_json(&self, path: &str) {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str("  \"bench\": \"hot_paths\",\n");
        out.push_str("  \"unit\": \"ns_per_call\",\n");
        out.push_str("  \"status\": \"measured\",\n");
        out.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            let unit = match r.unit {
                Some(u) => format!(", \"unit\": \"{u}\""),
                None => String::new(),
            };
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"mean\": {:.4}, \"median\": {:.4}, \"p95\": {:.4}, \"reps\": {}{}}}{}\n",
                r.name,
                r.mean,
                r.median,
                r.p95,
                r.reps,
                unit,
                if i + 1 < self.rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n");
        out.push_str("}\n");
        match std::fs::write(path, out) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => println!("\nfailed to write {path}: {e}"),
        }
    }
}

/// Per-element AtomicU32 shared-parameter baseline (the pre-§Perf layout),
/// kept here so publish/read rows compare old vs new storage directly.
struct NarrowParam {
    bits: Vec<AtomicU32>,
}

impl NarrowParam {
    fn new(init: &[f32]) -> Self {
        Self {
            bits: init.iter().map(|v| AtomicU32::new(v.to_bits())).collect(),
        }
    }

    fn publish(&self, values: &[f32]) {
        for (b, v) in self.bits.iter().zip(values.iter()) {
            b.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    fn read(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend(
            self.bits
                .iter()
                .map(|b| f32::from_bits(b.load(Ordering::Relaxed))),
        );
    }
}

fn main() {
    println!("== hot_paths ==");
    let mut rng = Pcg64::seeded(1);
    let mut report = Report::new();

    // ---- L3 kernels: scalar reference vs dispatched SIMD ----
    // axpy / dot at the SSVM parameter dimension (K*d + K*K = 4004)
    let dim = 26 * 128 + 26 * 26;
    let x = rng.gaussian_vec(dim);
    let mut y = rng.gaussian_vec(dim);
    report.add("axpy scalar dim=4004", 5000, || {
        simd::axpy_scalar(0.01, &x, &mut y);
    });
    report.add("axpy simd dim=4004", 5000, || {
        apbcfw::util::la::axpy(0.01, &x, &mut y);
    });
    let mut acc = 0.0;
    report.add("dot scalar dim=4004", 5000, || {
        acc += simd::dot_scalar(&x, &y);
    });
    report.add("dot simd dim=4004", 5000, || {
        acc += apbcfw::util::la::dot(&x, &y);
    });
    report.add("norm2_sq simd dim=4004", 5000, || {
        acc += apbcfw::util::la::norm2_sq(&x);
    });
    std::hint::black_box(acc);

    // lerp at the GFL column dimension
    let xc = rng.gaussian_vec(10);
    let mut yc = rng.gaussian_vec(10);
    report.add("lerp_into scalar dim=10 (GFL column)", 20000, || {
        simd::lerp_into_scalar(0.3, &xc, &mut yc);
    });
    report.add("lerp_into simd dim=10 (GFL column)", 20000, || {
        apbcfw::util::la::lerp_into(0.3, &xc, &mut yc);
    });

    // ---- batch assembler: insert + take at tau = 16 ----
    report.add("assembler insert+take tau=16 n=1000", 2000, || {
        let mut asm = BatchAssembler::new();
        let mut r = Pcg64::seeded(7);
        while asm.len() < 16 {
            asm.insert(UpdateMsg {
                oracles: vec![BlockOracle::dense(
                    r.below(1000),
                    vec![0.0; 8],
                    0.0,
                )],
                k_read: 0,
                worker: 0,
                generation: 0,
            });
        }
        std::hint::black_box(asm.take_batch(16));
    });
    report.add("assembler insert+take tau=16 batched x4", 2000, || {
        let mut asm = BatchAssembler::new();
        let mut r = Pcg64::seeded(7);
        let mut blocks = Vec::new();
        while asm.len() < 16 {
            apbcfw::coordinator::pick_blocks(&mut r, 1000, 4, &mut blocks);
            asm.insert(UpdateMsg {
                oracles: blocks
                    .iter()
                    .map(|&block| BlockOracle::dense(block, vec![0.0; 8], 0.0))
                    .collect(),
                k_read: 0,
                worker: 0,
                generation: 0,
            });
        }
        std::hint::black_box(asm.take_batch(16));
    });

    // ---- shared parameter: per-element atomic baseline vs wide-word ----
    let narrow = NarrowParam::new(&x);
    report.add("SharedParam publish/elem-atomic dim=4004", 5000, || {
        narrow.publish(&y);
    });
    let sp = SharedParam::new(&x);
    report.add("SharedParam publish/wide-word dim=4004", 5000, || {
        sp.publish(&y, 1);
    });
    let mut buf = Vec::new();
    report.add("SharedParam read/elem-atomic dim=4004", 5000, || {
        narrow.read(&mut buf);
        std::hint::black_box(buf.len());
    });
    report.add("SharedParam read/wide-word dim=4004", 5000, || {
        sp.read(&mut buf);
        std::hint::black_box(buf.len());
    });
    let spc = SharedParam::with_mode(&x, SnapshotMode::Consistent);
    report.add("SharedParam read/consistent dim=4004", 5000, || {
        spc.read(&mut buf);
        std::hint::black_box(buf.len());
    });
    // Packed vs cacheline-padded layout (the NUMA/false-sharing study
    // knob): same semantics, 8x footprint, one word per line.
    let spp = SharedParam::with_layout(&x, SnapshotMode::Torn, ParamLayout::Padded);
    report.add("SharedParam publish/padded dim=4004", 5000, || {
        spp.publish(&y, 1);
    });
    report.add("SharedParam read/padded dim=4004", 5000, || {
        spp.read(&mut buf);
        std::hint::black_box(buf.len());
    });

    // ---- worker loop: allocating oracle vs zero-alloc oracle_into ----
    // GFL at the paper shape (d=10, n=100): snapshot + one oracle call,
    // exactly what a worker does per solve.
    let sig = signal::piecewise_constant(10, 100, 6, 2.0, 0.5, 3);
    let gfl = Gfl::new(10, 100, 0.01, sig.noisy.clone());
    let gfl_shared = SharedParam::new(&gfl.init_param());
    let mut block = 0usize;
    report.add("gfl worker loop allocating (read_vec+oracle)", 10000, || {
        let snapshot = gfl_shared.read_vec();
        block = (block + 1) % gfl.num_blocks();
        std::hint::black_box(gfl.oracle(&snapshot, block));
    });
    let mut snap: Vec<f32> = Vec::new();
    let mut slot = BlockOracle::empty();
    report.add("gfl worker loop zero-alloc (read+oracle_into)", 10000, || {
        gfl_shared.read(&mut snap);
        block = (block + 1) % gfl.num_blocks();
        gfl.oracle_into(&snap, block, &mut (), &mut slot);
        std::hint::black_box(slot.ls);
    });

    // Batched fan-out round: ONE snapshot read amortized over `b` oracle
    // solves (what a batched worker does per iteration). Compare the
    // per-round medians divided by b against the batch=1 row.
    for b in [4usize, 16] {
        let mut slots: Vec<BlockOracle> =
            (0..b).map(|_| BlockOracle::empty()).collect();
        report.add(
            &format!("gfl worker round read+{b}x oracle_into (batch={b})"),
            10000 / b,
            || {
                gfl_shared.read(&mut snap);
                for slot in slots.iter_mut() {
                    block = (block + 1) % gfl.num_blocks();
                    gfl.oracle_into(&snap, block, &mut (), slot);
                }
                std::hint::black_box(slots[0].ls);
            },
        );
    }

    // Chain SSVM at the paper shape (K=26, d=128, L=9).
    let data = Arc::new(ocr_like::generate(64, 26, 128, 9, 0.15, 4));
    let chain = ChainSsvm::new(data, 1.0);
    let w: Vec<f32> = rng.gaussian_vec(chain.dim());
    let chain_shared = SharedParam::new(&w);
    report.add("chain worker loop allocating (read_vec+oracle)", 1000, || {
        let snapshot = chain_shared.read_vec();
        block = (block + 1) % chain.num_blocks();
        std::hint::black_box(chain.oracle(&snapshot, block));
    });
    let mut cslot = BlockOracle::empty();
    let mut viterbi_sc = ViterbiScratch::default();
    report.add(
        "chain worker loop zero-alloc (read+oracle_into)",
        1000,
        || {
            chain_shared.read(&mut snap);
            block = (block + 1) % chain.num_blocks();
            chain.oracle_into(&snap, block, &mut viterbi_sc, &mut cslot);
            std::hint::black_box(cslot.ls);
        },
    );

    // ---- sparse oracle payloads: apply throughput + bytes per update ----
    // Multiclass SSVM at K=10 d=64 (dim 640): the server's fused
    // gap+direction apply over an 8-oracle batch, dense payloads vs their
    // sparse twins (bit-identical outputs by the payload contract — these
    // rows measure the bandwidth saving of never densifying).
    let mc_data = Arc::new(mixture::generate(64, 10, 64, 0.1, 5));
    let mc = MulticlassSsvm::new(mc_data, 0.01);
    let wm: Vec<f32> = rng.gaussian_vec(mc.dim());
    for kind in [PayloadKind::Dense, PayloadKind::Sparse] {
        let batch: Vec<BlockOracle> = (0..8)
            .map(|i| {
                let mut slot = BlockOracle::empty_with(kind);
                mc.oracle_into(&wm, i * 7, &mut (), &mut slot);
                slot
            })
            .collect();
        let label = match kind {
            PayloadKind::Dense => "dense",
            PayloadKind::Sparse => "sparse",
        };
        let mut state = mc.init_server();
        let mut w = wm.clone();
        report.add(
            &format!("ssvm apply fused batch=8 {label} (dim=640)"),
            2000,
            || {
                let info = mc.apply(
                    &mut state,
                    &mut w,
                    &batch,
                    ApplyOptions {
                        gamma: 0.05,
                        line_search: false,
                    },
                );
                std::hint::black_box(info.batch_gap);
            },
        );
        let bytes: usize = batch.iter().map(|o| o.s.wire_bytes()).sum();
        report.add_metric(
            &format!("ssvm payload bytes-per-oracle {label} (dim=640)"),
            "bytes_per_oracle",
            bytes as f64 / batch.len() as f64,
        );
    }

    // Real async engine runs with the payload knob forced both ways: the
    // shipped bytes per applied update, measured from the coordinator's
    // payload telemetry (multiclass SSVM, 2 workers, tau 4).
    println!();
    let mc_small = MulticlassSsvm::new(
        Arc::new(mixture::generate(48, 8, 32, 0.15, 6)),
        0.05,
    );
    for mode in [PayloadMode::Dense, PayloadMode::Sparse] {
        let cfg = RunSpec::new(Engine::asynchronous(2))
            .tau(4)
            .payload(mode)
            .sample_every(1 << 20)
            .max_epochs(30.0)
            .max_secs(10.0)
            .seed(3)
            .run_config()
            .expect("async spec lowers");
        let r = coord::run(&mc_small, &cfg);
        report.add_metric(
            &format!("async bytes-per-update payload={}", mode.name()),
            "bytes_per_update",
            r.counters.payload_bytes as f64
                / r.counters.updates_applied.max(1) as f64,
        );
        report.add_metric(
            &format!("async payload-nnz-per-oracle payload={}", mode.name()),
            "nnz_per_oracle",
            r.counters.payload_nnz as f64
                / r.counters.oracle_calls.max(1) as f64,
        );
    }
    println!();

    // ---- batched fan-out: snapshot reads per applied update ----
    // Real async engine runs on the paper-shape GFL (99 blocks, 2
    // workers): the headline metric the batched worker API exists to
    // improve. Version-gating already skips redundant reads at batch=1;
    // batching divides what remains by tau_w.
    println!();
    for b in [1usize, 4, 16] {
        let cfg = RunSpec::new(Engine::asynchronous(2))
            .tau(4)
            .batch(b)
            .sample_every(1 << 20)
            .max_epochs(30.0)
            .max_secs(10.0)
            .seed(3)
            .run_config()
            .expect("async spec lowers");
        let r = coord::run(&gfl, &cfg);
        report.add_metric(
            &format!("async snapshot-reads-per-update batch={b}"),
            "reads_per_update",
            r.counters.snapshot_reads as f64
                / r.counters.updates_applied.max(1) as f64,
        );
    }
    println!();

    // ---- delay-adaptive stepping: apply throughput off vs kappa ----
    // Real async engine runs on the paper-shape GFL: the kappa policy
    // adds one EMA observation per accepted update and one damping
    // multiply per apply, so its throughput row must track the pinned
    // off row closely — these two rows make any control-plane overhead
    // visible across PRs.
    println!();
    for (label, adapt) in [
        ("off", apbcfw::sim::adapt::AdaptSpec::default()),
        (
            "kappa",
            apbcfw::sim::adapt::AdaptSpec {
                step: apbcfw::sim::adapt::StepPolicy::Kappa,
                ..Default::default()
            },
        ),
    ] {
        let cfg = RunSpec::new(Engine::asynchronous(2))
            .tau(4)
            .adapt(adapt)
            .sample_every(1 << 20)
            .max_epochs(30.0)
            .max_secs(10.0)
            .seed(3)
            .run_config()
            .expect("async spec lowers");
        let r = coord::run(&gfl, &cfg);
        report.add_metric(
            &format!("async updates-per-sec adapt={label}"),
            "updates_per_sec",
            r.counters.updates_applied as f64 / r.elapsed_s.max(1e-9),
        );
    }
    println!();

    // ---- distributed transport: wire bytes per applied update ----
    // Self-hosted loopback serve+worker runs (multiclass SSVM, 2 workers
    // over 127.0.0.1) with the payload knob forced both ways: total frame
    // bytes the server received per applied update — the real wire cost
    // (headers included, docs/WIRE.md §4.4) that the sparse payload
    // pipeline exists to shrink, now measured through an actual TCP
    // codec round trip instead of the in-process channel estimate above.
    println!();
    let net_cfg = apbcfw::util::config::Config::parse(
        "[run]\nseed = 6\n\
         [multiclass]\nn = 48\nk = 8\nd = 32\nnoise = 0.15\nlambda = 0.05\n",
    )
    .expect("net bench config");
    for mode in [PayloadMode::Dense, PayloadMode::Sparse] {
        let spec = RunSpec::new(Engine::asynchronous(2))
            .tau(4)
            .payload(mode)
            .sample_every(1 << 20)
            .max_epochs(30.0)
            .max_secs(10.0)
            .seed(3);
        let r = apbcfw::net::solve_loopback(
            spec,
            "multiclass",
            &net_cfg,
            "127.0.0.1:0",
        )
        .expect("loopback bench run");
        report.add_metric(
            &format!(
                "net loopback wire bytes-per-update payload={}",
                mode.name()
            ),
            "bytes_per_update",
            r.counters.wire_rx_bytes as f64
                / r.counters.updates_applied.max(1) as f64,
        );
    }
    println!();

    // ---- wire v4: shipped update bytes across encoding modes ----
    // The same loopback run with the sparse payload pinned and the
    // `run.wire` knob swept: update-frame bytes as actually shipped
    // (post-quantization, `shipped_payload_bytes`) per applied update.
    // exact is the v3 byte-identical baseline; f16 halves the value
    // words, q8 quarters them plus one scale word per payload
    // (docs/WIRE.md §4.4).
    println!();
    for mode in ["exact", "f16", "q8"] {
        let mut cfg = net_cfg.clone();
        cfg.set("run.wire", mode);
        let spec = RunSpec::new(Engine::asynchronous(2))
            .tau(4)
            .payload(PayloadMode::Sparse)
            .sample_every(1 << 20)
            .max_epochs(30.0)
            .max_secs(10.0)
            .seed(3);
        let r = apbcfw::net::solve_loopback(
            spec,
            "multiclass",
            &cfg,
            "127.0.0.1:0",
        )
        .expect("wire-mode loopback bench run");
        report.add_metric(
            &format!("net loopback wire bytes-per-update wire={mode}"),
            "bytes_per_update",
            r.counters.shipped_payload_bytes as f64
                / r.counters.updates_applied.max(1) as f64,
        );
    }
    println!();

    // ---- sharded parameter plane: throughput + snapshot fan-out ----
    // Self-hosted loopback runs on a paper-shape GFL (64 blocks) with
    // the plane split into S shards: one serve loop per shard, workers
    // owner-route every update and fan each snapshot pull to all
    // shards. updates-per-sec tracks apply throughput as the plane
    // scales; bytes-per-pull is the server->worker snapshot cost of the
    // fan-out (S span-scoped answers per pull vs one plane-wide one).
    println!();
    let shard_cfg = apbcfw::util::config::Config::parse(
        "[run]\nseed = 6\n\
         [gfl]\nd = 8\nn = 65\nlambda = 0.1\nsegments = 5\nnoise = 0.5\n",
    )
    .expect("sharded bench config");
    for shards in [1usize, 2, 4] {
        let mut cfg = shard_cfg.clone();
        cfg.set("run.shards", &shards.to_string());
        let spec = RunSpec::new(Engine::asynchronous(2))
            .tau(4)
            .sample_every(1 << 20)
            .max_epochs(30.0)
            .max_secs(10.0)
            .seed(3);
        let r = apbcfw::net::solve_loopback(spec, "gfl", &cfg, "127.0.0.1:0")
            .expect("sharded loopback bench run");
        report.add_metric(
            &format!("net sharded updates-per-sec shards={shards}"),
            "updates_per_sec",
            r.counters.updates_applied as f64 / r.elapsed_s.max(1e-9),
        );
        if shards <= 2 {
            report.add_metric(
                &format!("snapshot fan-out bytes-per-pull shards={shards}"),
                "bytes_per_pull",
                r.counters.wire_tx_bytes as f64
                    / r.counters.snapshot_reads.max(1) as f64,
            );
        }
    }
    println!();

    // ---- simplex projection (PBCD hot path) ----
    let mut blk = rng.gaussian_vec(10);
    report.add("project_simplex dim=10", 20000, || {
        let mut b = blk.clone();
        apbcfw::util::la::project_simplex(&mut b);
        std::hint::black_box(&b);
    });
    blk[0] += 1.0;

    // Repo root (benches run with CWD = the rust/ package).
    report.write_json(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_hotpaths.json"
    ));

    // Gate for the PR's acceptance criterion: the zero-allocation loop
    // must not be slower than the allocating one. A small tolerance
    // absorbs noisy shared-CI hosts; a clear regression fails the run
    // (set HOTPATHS_NO_GATE=1 to measure without gating).
    let find = |name: &str| {
        report
            .rows
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.median)
            .unwrap_or_else(|| panic!("bench row {name:?} missing"))
    };
    let gfl_ratio = find("gfl worker loop allocating (read_vec+oracle)")
        / find("gfl worker loop zero-alloc (read+oracle_into)");
    let chain_ratio = find("chain worker loop allocating (read_vec+oracle)")
        / find("chain worker loop zero-alloc (read+oracle_into)");
    println!("\nzero-alloc speedup: gfl {gfl_ratio:.2}x, chain {chain_ratio:.2}x");
    let gated = std::env::var("HOTPATHS_NO_GATE").is_err();
    if gated && (gfl_ratio < 0.9 || chain_ratio < 0.9) {
        eprintln!(
            "FAIL: zero-alloc path regressed below the allocating path \
             (gfl {gfl_ratio:.2}x, chain {chain_ratio:.2}x; threshold 0.9)"
        );
        std::process::exit(1);
    }
}
