//! Coordinator-level benchmarks: end-to-end solve throughput per execution
//! mode, and apply/publish cost at realistic batch sizes.

mod bench_util;

use apbcfw::coordinator::{apbcfw as coord, lockfree, sync};
use apbcfw::data::signal;
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::{ApplyOptions, Problem};
use apbcfw::run::{Engine, RunSpec};
use apbcfw::solver::minibatch;
use bench_util::bench;

fn gfl() -> Gfl {
    let sig = signal::piecewise_constant(10, 100, 6, 2.0, 0.5, 6);
    Gfl::new(10, 100, 0.05, sig.noisy.clone())
}

fn main() {
    println!("== coordinator ==");
    let p = gfl();

    // server apply cost at tau = 16 (line search on/off)
    let param0 = p.init_param();
    for ls in [false, true] {
        let mut param = param0.clone();
        let batch: Vec<_> = (0..16).map(|t| p.oracle(&param, t * 6)).collect();
        bench(
            &format!("gfl apply tau=16 line_search={ls}"),
            5000,
            || {
                let mut prm = param.clone();
                std::hint::black_box(p.apply(
                    &mut (),
                    &mut prm,
                    &batch,
                    ApplyOptions {
                        gamma: 0.1,
                        line_search: ls,
                    },
                ));
            },
        );
        param[0] += 0.0;
    }

    // throughput: oracle calls per second per mode, fixed 1.0s budget
    let throughput_spec = |engine: Engine, seed: u64| {
        RunSpec::new(engine)
            .tau(8)
            .sample_every(1 << 20)
            .max_epochs(f64::INFINITY)
            .max_secs(1.0)
            .seed(seed)
    };
    let seq = minibatch::solve(
        &p,
        &throughput_spec(Engine::Seq, 1).solve_options(),
    );
    println!(
        "mode=sequential   tau=8          {:>10.0} oracle calls/s",
        seq.oracle_calls as f64 / seq.elapsed_s
    );
    for workers in [1usize, 2, 4] {
        let cfg = throughput_spec(Engine::asynchronous(workers), 2)
            .run_config()
            .unwrap();
        let r = coord::run(&p, &cfg);
        println!(
            "mode=async        tau=8 T={workers}      {:>10.0} oracle calls/s ({} applied, {} collisions)",
            r.counters.oracle_calls as f64 / r.elapsed_s,
            r.counters.updates_applied,
            r.counters.collisions,
        );
    }
    // Batched fan-out: tau_w blocks per snapshot amortize the O(dim)
    // shared-parameter read; reads-per-update is the §Perf headline.
    for batch in [4usize, 16] {
        let cfg = throughput_spec(Engine::asynchronous(4), 2)
            .batch(batch)
            .run_config()
            .unwrap();
        let r = coord::run(&p, &cfg);
        println!(
            "mode=async        tau=8 T=4 b={batch:<2} {:>10.0} oracle calls/s ({:.3} snapshot reads/update)",
            r.counters.oracle_calls as f64 / r.elapsed_s,
            r.counters.snapshot_reads as f64
                / r.counters.updates_applied.max(1) as f64,
        );
    }
    let r = sync::run(
        &p,
        &throughput_spec(Engine::synchronous(4), 3)
            .run_config()
            .unwrap(),
    );
    println!(
        "mode=sync         tau=8 T=4      {:>10.0} oracle calls/s",
        r.counters.oracle_calls as f64 / r.elapsed_s
    );
    let r = lockfree::run(
        &p,
        &throughput_spec(Engine::lockfree(4), 3)
            .run_config()
            .unwrap(),
    );
    println!(
        "mode=lockfree     tau=1 T=4      {:>10.0} oracle calls/s",
        r.counters.oracle_calls as f64 / r.elapsed_s
    );
}
