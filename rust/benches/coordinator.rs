//! Coordinator-level benchmarks: end-to-end solve throughput per execution
//! mode, and apply/publish cost at realistic batch sizes.

mod bench_util;

use apbcfw::coordinator::{apbcfw as coord, lockfree, sync, RunConfig};
use apbcfw::data::signal;
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::{ApplyOptions, Problem};
use apbcfw::sim::straggler::StragglerModel;
use apbcfw::solver::{minibatch, SolveOptions, StopCond};
use bench_util::bench;

fn gfl() -> Gfl {
    let sig = signal::piecewise_constant(10, 100, 6, 2.0, 0.5, 6);
    Gfl::new(10, 100, 0.05, sig.noisy.clone())
}

fn main() {
    println!("== coordinator ==");
    let p = gfl();

    // server apply cost at tau = 16 (line search on/off)
    let param0 = p.init_param();
    for ls in [false, true] {
        let mut param = param0.clone();
        let batch: Vec<_> = (0..16).map(|t| p.oracle(&param, t * 6)).collect();
        bench(
            &format!("gfl apply tau=16 line_search={ls}"),
            5000,
            || {
                let mut prm = param.clone();
                std::hint::black_box(p.apply(
                    &mut (),
                    &mut prm,
                    &batch,
                    ApplyOptions {
                        gamma: 0.1,
                        line_search: ls,
                    },
                ));
            },
        );
        param[0] += 0.0;
    }

    // throughput: oracle calls per second per mode, fixed 1.0s budget
    let budget = StopCond {
        max_epochs: f64::INFINITY,
        max_secs: 1.0,
        ..Default::default()
    };
    let seq = minibatch::solve(
        &p,
        &SolveOptions {
            tau: 8,
            sample_every: 1 << 20,
            exact_gap: false,
            stop: budget,
            seed: 1,
            ..Default::default()
        },
    );
    println!(
        "mode=sequential   tau=8          {:>10.0} oracle calls/s",
        seq.oracle_calls as f64 / seq.elapsed_s
    );
    for workers in [1usize, 2, 4] {
        let cfg = RunConfig {
            workers,
            tau: 8,
            straggler: StragglerModel::none(workers),
            sample_every: 1 << 20,
            exact_gap: false,
            stop: budget,
            seed: 2,
            ..Default::default()
        };
        let r = coord::run(&p, &cfg);
        println!(
            "mode=async        tau=8 T={workers}      {:>10.0} oracle calls/s ({} applied, {} collisions)",
            r.counters.oracle_calls as f64 / r.elapsed_s,
            r.counters.updates_applied,
            r.counters.collisions,
        );
    }
    let cfg = RunConfig {
        workers: 4,
        tau: 8,
        straggler: StragglerModel::none(4),
        sample_every: 1 << 20,
        exact_gap: false,
        stop: budget,
        seed: 3,
        ..Default::default()
    };
    let r = sync::run(&p, &cfg);
    println!(
        "mode=sync         tau=8 T=4      {:>10.0} oracle calls/s",
        r.counters.oracle_calls as f64 / r.elapsed_s
    );
    let r = lockfree::run(&p, &cfg);
    println!(
        "mode=lockfree     tau=1 T=4      {:>10.0} oracle calls/s",
        r.counters.oracle_calls as f64 / r.elapsed_s
    );
}
