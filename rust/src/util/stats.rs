//! Small statistics helpers shared by the bench harness and experiments.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// q-th quantile (0 <= q <= 1) by linear interpolation on sorted data.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Summary of repeated timing measurements, in whatever unit the caller used.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty());
        Self {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: quantile(xs, 0.0),
            median: median(xs),
            p95: quantile(xs, 0.95),
            max: quantile(xs, 1.0),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} sd={:.4} min={:.4} med={:.4} p95={:.4} max={:.4}",
            self.n, self.mean, self.stddev, self.min, self.median, self.p95,
            self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((quantile(&xs, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!(s.min <= s.median && s.median <= s.p95 && s.p95 <= s.max);
    }

    #[test]
    fn empty_mean_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
