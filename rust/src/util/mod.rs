//! Infrastructure substrates: PRNG, config, CSV, stats, metrics, vector
//! math, and a property-testing harness (see DESIGN.md §3 S19-S22).

pub mod config;
pub mod csv;
pub mod la;
pub mod metrics;
pub mod proptest;
pub mod rng;
pub mod simd;
pub mod stats;
