//! Miniature property-testing harness (offline substitute for `proptest`).
//!
//! Usage:
//! ```ignore
//! check(100, 42, |g| {
//!     let n = g.usize_in(1, 50);
//!     let xs = g.f32_vec(n, -10.0, 10.0);
//!     prop_assert(xs.len() == n, "length");
//! });
//! ```
//! On failure the harness re-raises with the failing case number and seed so
//! the case is reproducible (`Gen` is a thin deterministic wrapper over
//! `Pcg64`). No shrinking — cases are kept small instead.

use super::rng::Pcg64;

/// Value generator handed to each property case.
pub struct Gen {
    rng: Pcg64,
    /// Per-case seed (for failure reports).
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn gaussian_vec(&mut self, len: usize) -> Vec<f32> {
        self.rng.gaussian_vec(len)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn subset(&mut self, n: usize, tau: usize) -> Vec<usize> {
        self.rng.subset(n, tau)
    }

    /// Access the raw rng for anything else.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` property cases derived from `seed`. The closure should panic
/// (e.g. via `assert!`) on property violation.
pub fn check<F: FnMut(&mut Gen)>(cases: usize, seed: u64, mut prop: F) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case as u64);
        let mut g = Gen {
            rng: Pcg64::new(case_seed, 77),
            case_seed,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut g),
        ));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    payload.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed at case {case}/{cases} (case_seed={case_seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(25, 1, |g| {
            let n = g.usize_in(1, 10);
            assert!((1..=10).contains(&n));
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    fn failing_property_reports_case() {
        let r = std::panic::catch_unwind(|| {
            check(50, 2, |g| {
                let v = g.usize_in(0, 100);
                assert!(v < 50, "v too big: {v}");
            });
        });
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string>".into());
        assert!(msg.contains("property failed"), "{msg}");
        assert!(msg.contains("case_seed="), "{msg}");
    }

    #[test]
    fn generator_is_deterministic_per_case() {
        let mut first: Vec<usize> = vec![];
        check(10, 3, |g| first.push(g.usize_in(0, 1_000_000)));
        let mut second: Vec<usize> = vec![];
        check(10, 3, |g| second.push(g.usize_in(0, 1_000_000)));
        assert_eq!(first, second);
    }
}
