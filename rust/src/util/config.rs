//! INI-style configuration system.
//!
//! The launcher reads `[section]`-structured `key = value` files (plus
//! `--set section.key=value` CLI overrides) into a typed `Config`. No TOML
//! crate exists in the offline vendor set, so this is a small, strict parser
//! of the subset we need: sections, scalar keys, `#`/`;` comments, and
//! whitespace tolerance. Unknown keys are preserved (and listable) so
//! experiments can carry ad-hoc parameters.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed configuration: `section.key -> value` (strings; typed accessors).
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

/// Error with line information for parse failures.
#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse INI text. Later keys override earlier ones.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = Self::new();
        cfg.merge_str(text)?;
        Ok(cfg)
    }

    /// Parse and merge INI text into this config.
    pub fn merge_str(&mut self, text: &str) -> Result<(), ConfigError> {
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';')
            {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                // Allow trailing comments after the header.
                let rest = match rest.find(|c| c == '#' || c == ';') {
                    Some(pos) => rest[..pos].trim_end(),
                    None => rest,
                };
                let name = rest.strip_suffix(']').ok_or(ConfigError {
                    line: lineno + 1,
                    msg: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(ConfigError {
                        line: lineno + 1,
                        msg: "empty section name".into(),
                    });
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or(ConfigError {
                line: lineno + 1,
                msg: format!("expected key = value, got {line:?}"),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ConfigError {
                    line: lineno + 1,
                    msg: "empty key".into(),
                });
            }
            // Strip trailing comment from the value.
            let mut value = value.trim();
            if let Some(pos) = value.find(|c| c == '#' || c == ';') {
                value = value[..pos].trim();
            }
            self.set(&format!("{section}.{key}"), value);
        }
        Ok(())
    }

    /// Load from a file path.
    pub fn load(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    /// Set `section.key` (or bare `key` for the root section).
    pub fn set(&mut self, dotted: &str, value: &str) {
        let dotted = dotted.strip_prefix('.').unwrap_or(dotted);
        self.values.insert(dotted.to_string(), value.to_string());
    }

    /// Raw string lookup.
    pub fn get(&self, dotted: &str) -> Option<&str> {
        self.values.get(dotted).map(|s| s.as_str())
    }

    pub fn get_or(&self, dotted: &str, default: &str) -> String {
        self.get(dotted).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, dotted: &str, default: usize) -> usize {
        self.get(dotted)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("config {dotted}={v:?} is not a usize")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, dotted: &str, default: u64) -> u64 {
        self.get(dotted)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("config {dotted}={v:?} is not a u64")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, dotted: &str, default: f64) -> f64 {
        self.get(dotted)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("config {dotted}={v:?} is not a f64")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_bool(&self, dotted: &str, default: bool) -> bool {
        match self.get(dotted) {
            None => default,
            Some("true") | Some("1") | Some("yes") | Some("on") => true,
            Some("false") | Some("0") | Some("no") | Some("off") => false,
            Some(v) => panic!("config {dotted}={v:?} is not a bool"),
        }
    }

    /// Comma-separated list of usizes, e.g. `taus = 1, 2, 4, 8`.
    pub fn get_usize_list(&self, dotted: &str, default: &[usize]) -> Vec<usize> {
        match self.get(dotted) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().unwrap_or_else(|_| {
                        panic!("config {dotted}: bad usize {p:?}")
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated list of f64s.
    pub fn get_f64_list(&self, dotted: &str, default: &[f64]) -> Vec<f64> {
        match self.get(dotted) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().unwrap_or_else(|_| {
                        panic!("config {dotted}: bad f64 {p:?}")
                    })
                })
                .collect(),
        }
    }

    /// All keys under a section prefix.
    pub fn keys_under(&self, section: &str) -> Vec<String> {
        let prefix = format!("{section}.");
        self.values
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .cloned()
            .collect()
    }

    /// Iterate all entries (for dump/debug).
    pub fn iter(&self) -> impl Iterator<Item = (&String, &String)> {
        self.values.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
root_key = 7

[gfl]
d = 10
n = 100          # inline comment
lambda = 0.01
taus = 1, 2, 4, 8

[run]
line_search = true
mode = async
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_usize("root_key", 0), 7);
        assert_eq!(c.get_usize("gfl.d", 0), 10);
        assert_eq!(c.get_usize("gfl.n", 0), 100);
        assert!((c.get_f64("gfl.lambda", 0.0) - 0.01).abs() < 1e-12);
        assert_eq!(c.get_usize_list("gfl.taus", &[]), vec![1, 2, 4, 8]);
        assert!(c.get_bool("run.line_search", false));
        assert_eq!(c.get_or("run.mode", "sync"), "async");
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_usize("absent", 42), 42);
        assert!(!c.get_bool("absent", false));
        assert_eq!(c.get_f64_list("absent", &[1.5]), vec![1.5]);
    }

    #[test]
    fn later_overrides_earlier() {
        let mut c = Config::parse("[a]\nx = 1\n").unwrap();
        c.merge_str("[a]\nx = 2\n").unwrap();
        assert_eq!(c.get_usize("a.x", 0), 2);
    }

    #[test]
    fn cli_set_override() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set("gfl.d", "25");
        assert_eq!(c.get_usize("gfl.d", 0), 25);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("not a kv line").is_err());
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("[]\n").is_err());
        assert!(Config::parse(" = 3").is_err());
    }

    #[test]
    fn section_header_trailing_comment() {
        let c = Config::parse("[sec]   # note\nx = 1\n").unwrap();
        assert_eq!(c.get_usize("sec.x", 0), 1);
    }

    #[test]
    fn keys_under_section() {
        let c = Config::parse(SAMPLE).unwrap();
        let keys = c.keys_under("gfl");
        assert_eq!(keys.len(), 4);
        assert!(keys.iter().all(|k| k.starts_with("gfl.")));
    }

    #[test]
    #[should_panic]
    fn typed_accessor_panics_on_garbage() {
        let c = Config::parse("[a]\nx = banana\n").unwrap();
        c.get_usize("a.x", 0);
    }
}
