//! Dense vector primitives used on the coordinator hot path.
//!
//! These are the L3 inner loops (update application is `axpy` over block
//! slices; gap/line-search terms are `dot`s). Since the §Perf vectorization
//! pass they are thin re-exports of [`crate::util::simd`], which serves
//! 8-lane AVX2+FMA kernels (runtime-detected) with a portable chunked
//! fallback; the original scalar loops survive as `simd::*_scalar` for the
//! equivalence tests and old-vs-new bench rows. Numbers in
//! EXPERIMENTS.md §Perf.

use super::simd;

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    simd::axpy(a, x, y)
}

/// y = (1 - a) * y + a * x   (convex combination, FW block update)
#[inline]
pub fn lerp_into(a: f32, x: &[f32], y: &mut [f32]) {
    simd::lerp_into(a, x, y)
}

/// <x, y> accumulated in f64 for stability (8-way pairwise partials).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    simd::dot(x, y)
}

/// ||x||_2^2 in f64.
#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    simd::norm2_sq(x)
}

/// ||x||_2.
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

/// x scaled in place.
#[inline]
pub fn scale(a: f32, x: &mut [f32]) {
    simd::scale(a, x)
}

/// Scatter axpy over a sparse `(idx, val)` support: y[idx[k]] += a val[k].
#[inline]
pub fn axpy_sparse(a: f32, idx: &[u32], val: &[f32], y: &mut [f32]) {
    simd::axpy_sparse(a, idx, val, y)
}

/// Sparse convex-combination update y = (1-a) y + a x_sparse, bit-identical
/// to [`lerp_into`] on the densified x (scale-then-scatter-axpy; see
/// `util::simd` for the contraction contract).
#[inline]
pub fn lerp_into_sparse(a: f32, idx: &[u32], val: &[f32], y: &mut [f32]) {
    simd::lerp_into_sparse(a, idx, val, y)
}

/// <x_sparse, y> accumulated sequentially in f64 (monitoring-grade).
#[inline]
pub fn dot_sparse(idx: &[u32], val: &[f32], y: &[f32]) -> f64 {
    simd::dot_sparse(idx, val, y)
}

/// Euclidean projection of `x` onto the l2 ball of radius `r` (in place).
pub fn project_l2_ball(r: f64, x: &mut [f32]) {
    let n = norm2(x);
    if n > r {
        let s = (r / n) as f32;
        scale(s, x);
    }
}

/// Euclidean projection onto the probability simplex (Held et al. 1974 /
/// Duchi et al. 2008 sort-based algorithm), in place.
pub fn project_simplex(x: &mut [f32]) {
    let n = x.len();
    assert!(n > 0);
    let mut u: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0f64;
    let mut rho = 0usize;
    let mut theta = 0.0f64;
    for (j, &uj) in u.iter().enumerate() {
        css += uj;
        let t = (css - 1.0) / (j + 1) as f64;
        if uj - t > 0.0 {
            rho = j + 1;
            theta = t;
        }
    }
    debug_assert!(rho > 0);
    for v in x.iter_mut() {
        *v = ((*v as f64) - theta).max(0.0) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_lerp() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
        let mut z = [0.0f32, 0.0, 4.0];
        lerp_into(0.25, &x, &mut z);
        assert_eq!(z, [0.25, 0.5, 3.75]);
    }

    #[test]
    fn dot_and_norms() {
        let x = [3.0f32, 4.0];
        assert_eq!(dot(&x, &x), 25.0);
        assert_eq!(norm2(&x), 5.0);
        assert_eq!(norm2_sq(&x), 25.0);
    }

    #[test]
    fn l2_projection() {
        let mut x = [3.0f32, 4.0];
        project_l2_ball(10.0, &mut x);
        assert_eq!(x, [3.0, 4.0]); // inside: untouched
        project_l2_ball(1.0, &mut x);
        assert!((norm2(&x) - 1.0).abs() < 1e-6);
        assert!((x[1] / x[0] - 4.0 / 3.0).abs() < 1e-5); // direction kept
    }

    #[test]
    fn simplex_projection_basic() {
        let mut x = [0.2f32, 0.3, 0.5];
        project_simplex(&mut x);
        // already on the simplex: unchanged
        assert!((x[0] - 0.2).abs() < 1e-6 && (x[2] - 0.5).abs() < 1e-6);

        let mut y = [2.0f32, 0.0, 0.0];
        project_simplex(&mut y);
        assert_eq!(y, [1.0, 0.0, 0.0]);

        let mut z = [0.5f32, 0.5, 0.5];
        project_simplex(&mut z);
        let sum: f32 = z.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert!(z.iter().all(|&v| (v - 1.0 / 3.0).abs() < 1e-5));
    }

    #[test]
    fn simplex_projection_matches_definition() {
        // Projection must be the closest simplex point: check optimality via
        // random feasible comparisons.
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(9);
        for _ in 0..50 {
            let x0: Vec<f32> = (0..6).map(|_| rng.gaussian() as f32).collect();
            let mut p = x0.clone();
            project_simplex(&mut p);
            let sum: f64 = p.iter().map(|&v| v as f64).sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(p.iter().all(|&v| v >= -1e-7));
            let d_p: f64 = x0
                .iter()
                .zip(&p)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            for _ in 0..20 {
                // random simplex point
                let mut q: Vec<f64> = (0..6).map(|_| -rng.uniform().ln()).collect();
                let s: f64 = q.iter().sum();
                q.iter_mut().for_each(|v| *v /= s);
                let d_q: f64 = x0
                    .iter()
                    .zip(&q)
                    .map(|(a, b)| ((*a as f64) - b).powi(2))
                    .sum();
                assert!(d_p <= d_q + 1e-6);
            }
        }
    }
}
