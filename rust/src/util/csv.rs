//! Minimal CSV emission for experiment results.
//!
//! Writers quote only when needed (comma/quote/newline in a field) and keep
//! an in-memory copy so tests and the experiment runner can inspect rows
//! without re-reading the file.

use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;

/// A table being accumulated and (optionally) streamed to disk.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    sink: Option<BufWriter<File>>,
}

fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

impl CsvWriter {
    /// In-memory only.
    pub fn in_memory(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            sink: None,
        }
    }

    /// Streaming to a file (parent directories created).
    pub fn to_file(path: &Path, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut sink = BufWriter::new(File::create(path)?);
        let head_line = header
            .iter()
            .map(|h| escape(h))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(sink, "{head_line}")?;
        Ok(Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            sink: Some(sink),
        })
    }

    /// Append a row; panics if the arity does not match the header.
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(
            fields.len(),
            self.header.len(),
            "csv row arity {} != header arity {}",
            fields.len(),
            self.header.len()
        );
        if let Some(sink) = &mut self.sink {
            let line = fields
                .iter()
                .map(|f| escape(f))
                .collect::<Vec<_>>()
                .join(",");
            writeln!(sink, "{line}").expect("csv write");
        }
        self.rows.push(fields.to_vec());
    }

    /// Convenience for display-able fields.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) {
        let fs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&fs);
    }

    pub fn header(&self) -> &[String] {
        &self.header
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Flush the file sink (no-op in memory).
    pub fn flush(&mut self) -> anyhow::Result<()> {
        if let Some(sink) = &mut self.sink {
            sink.flush()?;
        }
        Ok(())
    }

    /// Render the whole table as a CSV string (from the in-memory copy).
    pub fn to_csv_string(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|h| escape(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|f| escape(f)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_memory_roundtrip() {
        let mut w = CsvWriter::in_memory(&["a", "b"]);
        w.row(&["1".into(), "2".into()]);
        w.rowd(&[&3.5, &"x,y"]);
        let s = w.to_csv_string();
        assert_eq!(s, "a,b\n1,2\n3.5,\"x,y\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut w = CsvWriter::in_memory(&["a", "b"]);
        w.row(&["only-one".into()]);
    }

    #[test]
    fn escaping_quotes() {
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn file_sink_writes() {
        let dir = std::env::temp_dir().join("apbcfw_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::to_file(&path, &["x"]).unwrap();
        w.row(&["7".into()]);
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x\n7\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
