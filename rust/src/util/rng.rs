//! Deterministic PRNG + distribution sampling.
//!
//! The offline image has no `rand` crate, so we implement PCG64 (O'Neill,
//! "PCG: A Family of Simple Fast Space-Efficient Statistically Good
//! Algorithms for Random Number Generation") plus the handful of
//! distributions the paper's simulations need: uniform, Gaussian
//! (Box-Muller), Poisson (Knuth / inversion), Pareto (inverse CDF), and
//! Fisher-Yates shuffling / reservoir-free subset sampling.

/// PCG-XSL-RR 128/64 generator. Deterministic, seedable, `Send`.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli with success probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Poisson(lambda). Knuth's product method for small lambda, normal
    /// approximation with continuity correction for large lambda.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // PTRS would be exact; a clamped normal approximation is fine
            // for the delay-simulation use case (lambda <= ~100).
            let x = lambda + lambda.sqrt() * self.gaussian();
            x.max(0.0).round() as u64
        }
    }

    /// Pareto(shape alpha, scale x_m) via inverse CDF, rounded to nearest
    /// integer as in the paper's Section 3.4 delay experiment.
    pub fn pareto(&mut self, alpha: f64, xm: f64) -> f64 {
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        xm / u.powf(1.0 / alpha)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random size-`tau` subset of [0, n) (partial Fisher-Yates).
    pub fn subset(&mut self, n: usize, tau: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.subset_into(n, tau, &mut out);
        out
    }

    /// [`Self::subset`] into a caller-owned buffer (identical sampling
    /// sequence, no allocation in steady state — the buffer keeps capacity
    /// n across calls). On return `out` holds exactly the tau samples.
    pub fn subset_into(&mut self, n: usize, tau: usize, out: &mut Vec<usize>) {
        assert!(tau <= n);
        out.clear();
        out.extend(0..n);
        for i in 0..tau {
            let j = i + self.below(n - i);
            out.swap(i, j);
        }
        out.truncate(tau);
    }

    /// Sample a standard-normal f32 vector.
    pub fn gaussian_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.gaussian() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval_and_mean() {
        let mut rng = Pcg64::seeded(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg64::seeded(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seeded(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut rng = Pcg64::seeded(4);
        for &lam in &[0.5, 3.0, 12.0, 60.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| rng.poisson(lam) as f64).sum::<f64>()
                / n as f64;
            assert!(
                (mean - lam).abs() < 0.1 * lam.max(1.0),
                "lambda={lam} mean={mean}"
            );
        }
    }

    #[test]
    fn pareto_support_and_median() {
        let mut rng = Pcg64::seeded(5);
        // alpha=2, xm=5 -> median = xm * 2^(1/2).
        let n = 40_000;
        let mut xs: Vec<f64> = (0..n).map(|_| rng.pareto(2.0, 5.0)).collect();
        assert!(xs.iter().all(|&x| x >= 5.0));
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[n / 2];
        assert!((med - 5.0 * 2f64.sqrt()).abs() < 0.15, "median={med}");
    }

    #[test]
    fn pareto_expectation_alpha2() {
        // E[X] = alpha*xm/(alpha-1) = 2*xm for alpha=2 (paper: xm = kappa/2
        // gives E = kappa).
        let mut rng = Pcg64::seeded(6);
        let n = 200_000;
        let kappa = 10.0;
        let mean = (0..n)
            .map(|_| rng.pareto(2.0, kappa / 2.0))
            .sum::<f64>()
            / n as f64;
        assert!((mean - kappa).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn subset_is_uniform_and_distinct() {
        let mut rng = Pcg64::seeded(7);
        let mut hits = [0usize; 10];
        for _ in 0..10_000 {
            let s = rng.subset(10, 3);
            assert_eq!(s.len(), 3);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 3, "duplicates in {s:?}");
            for i in s {
                hits[i] += 1;
            }
        }
        for &h in &hits {
            assert!((h as f64 - 3_000.0).abs() < 250.0, "{hits:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(8);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
