//! Vectorized L3 kernels with runtime CPU-feature dispatch.
//!
//! Every dense primitive on the coordinator hot path (`axpy`, `lerp_into`,
//! `dot`, `norm2_sq`, `scale`) is served from here in one of three forms:
//!
//! - **AVX2+FMA** (`x86_64`, detected once at runtime via
//!   `is_x86_feature_detected!`): 8 f32 lanes per step; reductions convert
//!   to f64 lanes and fuse with FMA, so `dot`/`norm2_sq` accumulate in
//!   8 parallel f64 partials.
//! - **Chunked portable fallback**: the same 8-lane shape written as plain
//!   slice code the autovectorizer handles on any target, with the same
//!   8-partial f64 accumulation.
//! - **Scalar reference** (`*_scalar`): the original single-accumulator
//!   loops, kept public as the ground truth for the equivalence property
//!   tests and the old-vs-new rows in `benches/hot_paths.rs`.
//!
//! Accumulation-order note: the vector forms sum reductions pairwise over
//! 8 f64 partials, so `dot`/`norm2_sq` are not bit-identical to the scalar
//! reference — they are at least as accurate (pairwise summation has lower
//! worst-case error) and the property tests pin them within ULP-scale
//! tolerance. `axpy` differs from scalar only by FMA contraction on the
//! AVX2 path; `lerp_into` and `scale` are deliberately UNFUSED on every
//! path, so they are bit-identical across dispatch AND bit-identical to
//! the sparse scale-then-scatter-axpy form ([`lerp_into_sparse`]) on the
//! nonzero support — the invariant the sparse-payload pipeline's
//! dense-vs-sparse equivalence tests pin.
//!
//! Sparse kernels ([`axpy_sparse`], [`lerp_into_sparse`], [`dot_sparse`])
//! operate on a strictly-ascending `(idx, val)` support over an implicit-
//! zero vector. Their dispatching entry points currently route to the
//! scalar forms (scatter/gather SIMD can slot in behind them later); the
//! `*_scalar` references are the canonical semantics either way.
//!
//! Perf numbers for every kernel are tracked in EXPERIMENTS.md §Perf via
//! `benches/hot_paths.rs` -> `BENCH_hotpaths.json`.

// Fixed-width indexed loops in the chunked kernels are deliberate:
// `chunks_exact` + constant bounds is the shape LLVM reliably vectorizes.
#![allow(clippy::needless_range_loop)]

/// Width (f32 lanes) of one vector step; also the number of f64 partial
/// accumulators used by reductions.
pub const LANES: usize = 8;

#[cfg(target_arch = "x86_64")]
fn have_avx2_fma() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    // 0 = unknown, 1 = absent, 2 = present. The cpuid probe is cheap but
    // not free; the hot loops call this per operation.
    static CACHE: AtomicU8 = AtomicU8::new(0);
    match CACHE.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let yes = is_x86_feature_detected!("avx2")
                && is_x86_feature_detected!("fma");
            CACHE.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

// ---------------------------------------------------------------------------
// Public dispatching entry points
// ---------------------------------------------------------------------------

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if have_avx2_fma() {
        // SAFETY: AVX2+FMA presence checked above.
        unsafe { avx2::axpy(a, x, y) };
        return;
    }
    axpy_chunked(a, x, y)
}

/// y = (1 - a) * y + a * x
#[inline]
pub fn lerp_into(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if have_avx2_fma() {
        // SAFETY: AVX2+FMA presence checked above.
        unsafe { avx2::lerp_into(a, x, y) };
        return;
    }
    lerp_into_chunked(a, x, y)
}

/// <x, y> accumulated in f64.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if have_avx2_fma() {
        // SAFETY: AVX2+FMA presence checked above.
        return unsafe { avx2::dot(x, y) };
    }
    dot_chunked(x, y)
}

/// ||x||^2 accumulated in f64.
#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if have_avx2_fma() {
        // SAFETY: AVX2+FMA presence checked above.
        return unsafe { avx2::norm2_sq(x) };
    }
    norm2_sq_chunked(x)
}

/// x *= a
#[inline]
pub fn scale(a: f32, x: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if have_avx2_fma() {
        // SAFETY: AVX2+FMA presence checked above.
        unsafe { avx2::scale(a, x) };
        return;
    }
    scale_chunked(a, x)
}

// ---------------------------------------------------------------------------
// Sparse kernels: strictly-ascending (idx, val) support, implicit zeros
// ---------------------------------------------------------------------------

/// Scatter axpy: `y[idx[k]] += a * val[k]`.
///
/// `idx` must be strictly ascending and in bounds. Unfused (`y + round(a*v)`)
/// on every path, matching the scalar `axpy` reference — and therefore the
/// on-support arithmetic of the unfused dense [`lerp_into`] when composed
/// by [`lerp_into_sparse`].
#[inline]
pub fn axpy_sparse(a: f32, idx: &[u32], val: &[f32], y: &mut [f32]) {
    axpy_sparse_scalar(a, idx, val, y)
}

/// Reference scatter axpy (the canonical semantics).
pub fn axpy_sparse_scalar(a: f32, idx: &[u32], val: &[f32], y: &mut [f32]) {
    debug_assert_eq!(idx.len(), val.len());
    for (&i, &v) in idx.iter().zip(val.iter()) {
        y[i as usize] += a * v;
    }
}

/// Sparse convex-combination update: `y = (1 - a) y + a x` for a sparse
/// `x`, realized as scale-by-`1-a`-then-scatter-axpy.
///
/// Bit-identical to the dense [`lerp_into`] applied to the densified `x`
/// for `a` in [0, 1] (the FW step range): off the support both compute
/// `round(b*y)` (dense adds an exact `+0.0`), on the support both compute
/// `round(round(b*y) + round(a*v))` — which is why the dense kernel is
/// deliberately unfused. At `a == 1` (`b == 0`, the clamped early-schedule
/// step) the off-support elements are written as exact `+0.0` to match the
/// dense `±0 + 0` sum, where plain scaling would leave `-0.0` for negative
/// `y`. Negative-zero / negative-underflow *inputs* are out of scope (no
/// problem emits them).
#[inline]
pub fn lerp_into_sparse(a: f32, idx: &[u32], val: &[f32], y: &mut [f32]) {
    let b = 1.0 - a;
    if b == 0.0 {
        y.fill(0.0);
    } else {
        scale(b, y);
    }
    axpy_sparse(a, idx, val, y);
}

/// Reference sparse lerp (scalar scale + scalar scatter).
pub fn lerp_into_sparse_scalar(
    a: f32,
    idx: &[u32],
    val: &[f32],
    y: &mut [f32],
) {
    let b = 1.0 - a;
    if b == 0.0 {
        y.fill(0.0);
    } else {
        scale_scalar(b, y);
    }
    axpy_sparse_scalar(a, idx, val, y);
}

/// Gather dot: `sum_k val[k] * y[idx[k]]` accumulated sequentially in f64.
///
/// Monitoring-grade: NOT bit-matched to the pairwise dense [`dot`] on the
/// densified vector (different accumulation tree); within summation-error
/// tolerance of it, pinned by the property tests.
#[inline]
pub fn dot_sparse(idx: &[u32], val: &[f32], y: &[f32]) -> f64 {
    dot_sparse_scalar(idx, val, y)
}

/// Reference gather dot.
pub fn dot_sparse_scalar(idx: &[u32], val: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(idx.len(), val.len());
    let mut acc = 0.0f64;
    for (&i, &v) in idx.iter().zip(val.iter()) {
        acc += v as f64 * y[i as usize] as f64;
    }
    acc
}

// ---------------------------------------------------------------------------
// Scalar references (the pre-vectorization kernels, verbatim)
// ---------------------------------------------------------------------------

/// Reference y += a * x (single accumulator order).
pub fn axpy_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// Reference y = (1-a) y + a x.
pub fn lerp_into_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let b = 1.0 - a;
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = b * *yi + a * *xi;
    }
}

/// Reference <x, y> with one sequential f64 accumulator.
pub fn dot_scalar(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (xi, yi) in x.iter().zip(y.iter()) {
        acc += (*xi as f64) * (*yi as f64);
    }
    acc
}

/// Reference ||x||^2 with one sequential f64 accumulator.
pub fn norm2_sq_scalar(x: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for xi in x {
        acc += (*xi as f64) * (*xi as f64);
    }
    acc
}

/// Reference x *= a.
pub fn scale_scalar(a: f32, x: &mut [f32]) {
    for xi in x {
        *xi *= a;
    }
}

// ---------------------------------------------------------------------------
// Portable chunked fallback (8-lane shape, autovectorizer-friendly)
//
// The fixed-width indexed loops are deliberate: `chunks_exact` + constant
// bounds is the shape LLVM reliably vectorizes.
// ---------------------------------------------------------------------------

/// Pairwise-combine 8 f64 partial sums (fixed reduction tree).
#[inline]
fn reduce8(acc: [f64; LANES]) -> f64 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

fn axpy_chunked(a: f32, x: &[f32], y: &mut [f32]) {
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact_mut(LANES);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        for k in 0..LANES {
            ys[k] += a * xs[k];
        }
    }
    for (xi, yi) in xc.remainder().iter().zip(yc.into_remainder()) {
        *yi += a * *xi;
    }
}

fn lerp_into_chunked(a: f32, x: &[f32], y: &mut [f32]) {
    let b = 1.0 - a;
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact_mut(LANES);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        for k in 0..LANES {
            ys[k] = b * ys[k] + a * xs[k];
        }
    }
    for (xi, yi) in xc.remainder().iter().zip(yc.into_remainder()) {
        *yi = b * *yi + a * *xi;
    }
}

/// Chunked dot with 8 f64 partials (public: the non-x86 production path,
/// and the cross-check target for the AVX2 path in tests).
pub fn dot_chunked(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        for k in 0..LANES {
            acc[k] += xs[k] as f64 * ys[k] as f64;
        }
    }
    let mut tail = 0.0f64;
    for (xi, yi) in xc.remainder().iter().zip(yc.remainder()) {
        tail += *xi as f64 * *yi as f64;
    }
    reduce8(acc) + tail
}

/// Chunked squared norm with 8 f64 partials.
pub fn norm2_sq_chunked(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut xc = x.chunks_exact(LANES);
    for xs in &mut xc {
        for k in 0..LANES {
            acc[k] += xs[k] as f64 * xs[k] as f64;
        }
    }
    let mut tail = 0.0f64;
    for xi in xc.remainder() {
        tail += *xi as f64 * *xi as f64;
    }
    reduce8(acc) + tail
}

fn scale_chunked(a: f32, x: &mut [f32]) {
    let mut xc = x.chunks_exact_mut(LANES);
    for xs in &mut xc {
        for k in 0..LANES {
            xs[k] *= a;
        }
    }
    for xi in xc.into_remainder() {
        *xi *= a;
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA path
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::LANES;
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 and FMA support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let va = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(
                y.as_mut_ptr().add(i),
                _mm256_fmadd_ps(va, vx, vy),
            );
            i += LANES;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 and FMA support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn lerp_into(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let b = 1.0 - a;
        let va = _mm256_set1_ps(a);
        let vb = _mm256_set1_ps(b);
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            // round(b*y) + round(a*x), deliberately UNFUSED: every lerp
            // path (this one, the chunked fallback, the scalar reference,
            // and the sparse scale-then-scatter form) then computes the
            // exact same two-rounding expression, which is what pins the
            // dense-vs-sparse payload equivalence bit-for-bit.
            let ax = _mm256_mul_ps(va, vx);
            let by = _mm256_mul_ps(vb, vy);
            _mm256_storeu_ps(
                y.as_mut_ptr().add(i),
                _mm256_add_ps(by, ax),
            );
            i += LANES;
        }
        while i < n {
            y[i] = b * y[i] + a * x[i];
            i += 1;
        }
    }

    /// Widen the two 4-lane halves of an 8-lane f32 vector to f64.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn widen(v: __m256) -> (__m256d, __m256d) {
        let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
        let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v));
        (lo, hi)
    }

    /// # Safety
    /// Caller must have verified AVX2 and FMA support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f64 {
        let n = x.len().min(y.len());
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let (xlo, xhi) = widen(vx);
            let (ylo, yhi) = widen(vy);
            acc_lo = _mm256_fmadd_pd(xlo, ylo, acc_lo);
            acc_hi = _mm256_fmadd_pd(xhi, yhi, acc_hi);
            i += LANES;
        }
        let mut buf = [0.0f64; 4];
        _mm256_storeu_pd(buf.as_mut_ptr(), _mm256_add_pd(acc_lo, acc_hi));
        let mut acc = (buf[0] + buf[1]) + (buf[2] + buf[3]);
        while i < n {
            acc += x[i] as f64 * y[i] as f64;
            i += 1;
        }
        acc
    }

    /// # Safety
    /// Caller must have verified AVX2 and FMA support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn norm2_sq(x: &[f32]) -> f64 {
        let n = x.len();
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let (xlo, xhi) = widen(vx);
            acc_lo = _mm256_fmadd_pd(xlo, xlo, acc_lo);
            acc_hi = _mm256_fmadd_pd(xhi, xhi, acc_hi);
            i += LANES;
        }
        let mut buf = [0.0f64; 4];
        _mm256_storeu_pd(buf.as_mut_ptr(), _mm256_add_pd(acc_lo, acc_hi));
        let mut acc = (buf[0] + buf[1]) + (buf[2] + buf[3]);
        while i < n {
            acc += x[i] as f64 * x[i] as f64;
            i += 1;
        }
        acc
    }

    /// # Safety
    /// Caller must have verified AVX2 and FMA support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn scale(a: f32, x: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), _mm256_mul_ps(va, vx));
            i += LANES;
        }
        while i < n {
            x[i] *= a;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn dispatch_matches_scalar_across_sizes() {
        let mut rng = Pcg64::seeded(11);
        for n in (0..=64).chain([100, 1000, 4003, 4096]) {
            let x = rng.gaussian_vec(n);
            let y0 = rng.gaussian_vec(n);

            // dot / norm2_sq: pairwise vs sequential within f64 ULP scale.
            assert!(
                close(dot(&x, &y0), dot_scalar(&x, &y0), 1e-12),
                "dot n={n}"
            );
            assert!(
                close(norm2_sq(&x), norm2_sq_scalar(&x), 1e-12),
                "norm2 n={n}"
            );
            assert!(
                close(dot_chunked(&x, &y0), dot_scalar(&x, &y0), 1e-12),
                "dot_chunked n={n}"
            );

            // axpy / lerp / scale: elementwise, FMA contraction only.
            let mut ya = y0.clone();
            let mut yb = y0.clone();
            axpy(0.37, &x, &mut ya);
            axpy_scalar(0.37, &x, &mut yb);
            for (a, b) in ya.iter().zip(&yb) {
                assert!(
                    ((a - b) as f64).abs() <= 1e-6 * (1.0 + (*b as f64).abs()),
                    "axpy n={n}: {a} vs {b}"
                );
            }

            let mut la = y0.clone();
            let mut lb = y0.clone();
            lerp_into(0.25, &x, &mut la);
            lerp_into_scalar(0.25, &x, &mut lb);
            // lerp is unfused on every path, so dispatch == scalar exactly.
            for (j, (a, b)) in la.iter().zip(&lb).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "lerp n={n} j={j}: {a} vs {b}"
                );
            }

            let mut sa = y0.clone();
            let mut sb = y0.clone();
            scale(-1.5, &mut sa);
            scale_scalar(-1.5, &mut sb);
            assert_eq!(sa, sb, "scale is exact (single multiply) n={n}");
        }
    }

    /// Random strictly-ascending support of ~density over [0, n).
    fn random_support(
        rng: &mut Pcg64,
        n: usize,
        density: f64,
    ) -> (Vec<u32>, Vec<f32>) {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            if (rng.uniform()) < density {
                idx.push(i as u32);
                // Gaussian draws are never exactly ±0.
                val.push(rng.gaussian() as f32);
            }
        }
        (idx, val)
    }

    fn densify(idx: &[u32], val: &[f32], n: usize) -> Vec<f32> {
        let mut x = vec![0.0f32; n];
        for (&i, &v) in idx.iter().zip(val.iter()) {
            x[i as usize] = v;
        }
        x
    }

    #[test]
    fn lerp_sparse_bit_identical_to_dense_lerp() {
        let mut rng = Pcg64::seeded(21);
        for n in [0usize, 1, 5, 8, 9, 33, 100, 1000] {
            for density in [0.0, 0.05, 0.5, 1.0] {
                let (idx, val) = random_support(&mut rng, n, density);
                let x = densify(&idx, &val, n);
                let y0 = rng.gaussian_vec(n);
                // Include both clamp endpoints of the FW step range.
                for a in [0.0f32, 0.12, 0.5, 0.999, 1.0] {
                    let mut yd = y0.clone();
                    let mut ys = y0.clone();
                    lerp_into(a, &x, &mut yd);
                    lerp_into_sparse(a, &idx, &val, &mut ys);
                    for (j, (d, s)) in yd.iter().zip(&ys).enumerate() {
                        assert_eq!(
                            d.to_bits(),
                            s.to_bits(),
                            "n={n} a={a} j={j}: dense {d} vs sparse {s}"
                        );
                    }
                    let mut yr = y0.clone();
                    lerp_into_sparse_scalar(a, &idx, &val, &mut yr);
                    assert_eq!(ys, yr, "scalar sparse ref n={n} a={a}");
                }
            }
        }
    }

    #[test]
    fn lerp_sparse_gamma_one_matches_dense_on_negative_iterates() {
        // The b == 0 branch: dense lerp leaves +0.0 off the support even
        // for negative y; plain scaling would leave -0.0.
        let idx = [2u32, 5];
        let val = [0.7f32, -1.3];
        let x = densify(&idx, &val, 8);
        let y0: Vec<f32> = (0..8).map(|i| -(i as f32) - 0.5).collect();
        let mut yd = y0.clone();
        let mut ys = y0.clone();
        lerp_into(1.0, &x, &mut yd);
        lerp_into_sparse(1.0, &idx, &val, &mut ys);
        for (j, (d, s)) in yd.iter().zip(&ys).enumerate() {
            assert_eq!(d.to_bits(), s.to_bits(), "j={j}: {d} vs {s}");
        }
        assert_eq!(ys[0].to_bits(), 0.0f32.to_bits(), "+0.0 off support");
    }

    #[test]
    fn axpy_sparse_matches_scalar_axpy_on_support() {
        let mut rng = Pcg64::seeded(22);
        for n in [0usize, 7, 64, 500] {
            let (idx, val) = random_support(&mut rng, n, 0.2);
            let x = densify(&idx, &val, n);
            let y0 = rng.gaussian_vec(n);
            let mut ya = y0.clone();
            let mut yb = y0.clone();
            axpy_sparse(0.37, &idx, &val, &mut ya);
            axpy_scalar(0.37, &x, &mut yb);
            assert_eq!(ya, yb, "n={n}");
        }
    }

    #[test]
    fn dot_sparse_matches_dense_dot_within_tolerance() {
        let mut rng = Pcg64::seeded(23);
        for n in [0usize, 9, 100, 4003] {
            let (idx, val) = random_support(&mut rng, n, 0.3);
            let x = densify(&idx, &val, n);
            let y = rng.gaussian_vec(n);
            let ds = dot_sparse(&idx, &val, &y);
            assert!(
                close(ds, dot(&x, &y), 1e-12),
                "n={n}: {ds} vs {}",
                dot(&x, &y)
            );
            assert_eq!(ds, dot_sparse_scalar(&idx, &val, &y));
        }
    }

    #[test]
    fn empty_inputs_are_noops() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(norm2_sq(&[]), 0.0);
        let mut y: Vec<f32> = vec![];
        axpy(2.0, &[], &mut y);
        lerp_into(0.5, &[], &mut y);
        scale(3.0, &mut y);
        assert!(y.is_empty());
    }

    #[test]
    fn exact_small_cases() {
        // Values where every intermediate is exactly representable: all
        // paths must agree bit-for-bit.
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let mut y = [10.0f32; 9];
        axpy(2.0, &x, &mut y);
        assert_eq!(
            y,
            [12.0, 14.0, 16.0, 18.0, 20.0, 22.0, 24.0, 26.0, 28.0]
        );
        assert_eq!(dot(&x, &x), 285.0);
        assert_eq!(norm2_sq(&x), 285.0);
        let mut z = [0.0f32, 0.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 8.0];
        lerp_into(0.25, &x[..9], &mut z);
        assert_eq!(z[0], 0.25);
        assert_eq!(z[2], 3.75);
        assert_eq!(z[8], 8.25);
    }
}
