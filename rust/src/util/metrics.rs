//! Run metrics: convergence traces and coordinator counters.
//!
//! A `Trace` records (iteration, oracle calls, wall-clock, primal value,
//! gap estimate) samples during a solve; experiments post-process traces
//! into the paper's figures. `Counters` aggregates coordinator-side event
//! counts (updates applied/dropped, collisions, oracle calls) with atomics
//! so worker threads can bump them without locks.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// One convergence sample.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// Server iteration k.
    pub iter: usize,
    /// Total oracle calls so far (epochs = calls / n).
    pub oracle_calls: u64,
    /// Seconds since solve start.
    pub elapsed_s: f64,
    /// Objective f(x^(k)).
    pub objective: f64,
    /// Surrogate duality-gap estimate (exact if computed over all blocks).
    pub gap: f64,
}

/// Convergence trace of a solve.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub samples: Vec<Sample>,
}

impl Trace {
    pub fn push(&mut self, s: Sample) {
        self.samples.push(s);
    }

    pub fn last(&self) -> Option<&Sample> {
        self.samples.last()
    }

    /// First sample index where objective - f_star <= eps; None if never.
    pub fn first_below(&self, f_star: f64, eps: f64) -> Option<&Sample> {
        self.samples
            .iter()
            .find(|s| s.objective - f_star <= eps)
    }

    /// First sample where gap <= eps.
    pub fn first_gap_below(&self, eps: f64) -> Option<&Sample> {
        self.samples.iter().find(|s| s.gap <= eps)
    }

    /// Epochs (oracle calls / n) needed to reach f - f_star <= eps.
    pub fn epochs_to(&self, f_star: f64, eps: f64, n: usize) -> Option<f64> {
        self.first_below(f_star, eps)
            .map(|s| s.oracle_calls as f64 / n as f64)
    }

    /// Wall-clock seconds to reach f - f_star <= eps.
    pub fn secs_to(&self, f_star: f64, eps: f64) -> Option<f64> {
        self.first_below(f_star, eps).map(|s| s.elapsed_s)
    }

    /// Best (lowest) objective seen.
    pub fn best_objective(&self) -> f64 {
        self.samples
            .iter()
            .map(|s| s.objective)
            .fold(f64::INFINITY, f64::min)
    }
}

/// Lock-free coordinator counters (shared across worker threads).
#[derive(Debug, Default)]
pub struct Counters {
    /// Oracle subproblems solved by workers.
    pub oracle_calls: AtomicU64,
    /// Updates applied by the server.
    pub updates_applied: AtomicU64,
    /// Updates overwritten due to block collision (paper Alg 1, step 1).
    pub collisions: AtomicU64,
    /// Updates dropped by the staleness rule (delay > k/2) or straggler sim.
    pub dropped: AtomicU64,
    /// Server iterations completed.
    pub iterations: AtomicU64,
    /// Full shared-parameter snapshot reads performed by workers. Batched
    /// fan-out exists to push snapshot_reads / oracle_calls well below 1;
    /// the `hot_paths` bench reports that ratio at batch 1/4/16.
    pub snapshot_reads: AtomicU64,
    /// Sum of explicitly stored payload values across every oracle shipped
    /// worker -> server (`OraclePayload::nnz`): dense payloads count the
    /// full dimension, sparse ones their support. `payload_nnz /
    /// oracle payload count` is the average shipped density.
    pub payload_nnz: AtomicU64,
    /// Sum of *logical* payload wire bytes across every oracle shipped
    /// (`OraclePayload::wire_bytes` — the exact-mode encoding cost,
    /// independent of `run.wire`). `payload_bytes / updates_applied` is
    /// the `hot_paths` bench's bytes-per-update row — the
    /// communication-efficiency axis the sparse payload pipeline exists to
    /// shrink. Compare against `shipped_payload_bytes` to see what v4
    /// quantization saved on top.
    pub payload_bytes: AtomicU64,
    /// Update-frame bytes as actually shipped over the transport (after
    /// any `run.wire` quantization), counted by the serve role's readers
    /// at frame receipt. Under `run.wire = exact` this tracks
    /// `payload_bytes` plus per-frame framing overhead; under f16/q8 it
    /// is the smaller, post-quantization figure — the number the v4 wire
    /// exists to shrink. Zero for in-process engines.
    pub shipped_payload_bytes: AtomicU64,
    /// Frame bytes written to the network transport (headers included) —
    /// counted only by the `net` serve role; zero for in-process engines.
    pub wire_tx_bytes: AtomicU64,
    /// Frame bytes read off the network transport (headers included).
    pub wire_rx_bytes: AtomicU64,
    /// Sum over applied updates of the observed delay (server iterations
    /// between the snapshot an oracle was computed from and its apply).
    /// `delay_sum / updates_applied` is the empirical expected delay kappa
    /// — the quantity the paper's §2.3/§3.4 convergence bounds depend on.
    pub delay_sum: AtomicU64,
    /// Largest observed delay among applied updates.
    pub delay_max: AtomicU64,
    /// Workers accepted into the fleet after the run started (elastic
    /// membership): mid-run joiners and reconnectors alike.
    pub workers_joined: AtomicU64,
    /// Connections declared dead mid-run (socket error, invalid payload,
    /// or liveness timeout) whose in-flight work was requeued.
    pub workers_lost: AtomicU64,
    /// Blocks returned to the sampling pool when their worker was
    /// declared dead: the outstanding fan-out round plus any updates of
    /// that worker still buffered in the assembler.
    pub blocks_requeued: AtomicU64,
    /// Sessions that announced themselves as resuming a broken one
    /// (`Join { resumed: true }` — the worker-side reconnect-with-backoff
    /// loop succeeding).
    pub reconnects: AtomicU64,
    /// Times a reader thread found the server's event channel full and had
    /// to block (the bounded-backpressure stall metric — persistent growth
    /// means the fleet outpaces the apply loop).
    pub event_stalls: AtomicU64,
    /// Durable per-shard checkpoints written (`run.checkpoint_every > 0`).
    pub checkpoints_written: AtomicU64,
    /// Serve loops that resumed from a durable checkpoint instead of a
    /// fresh parameter (crash recovery; each restore bumps the session
    /// generation).
    pub restores: AtomicU64,
    /// Update frames fenced because they carried a stale generation — a
    /// pre-crash in-flight oracle arriving after a restore. Fenced frames
    /// never reach the assembler, so they can never corrupt the restored
    /// master parameter.
    pub stale_fenced: AtomicU64,
    /// Accumulated step-damping deficit under `run.adapt.step = kappa`,
    /// in parts-per-thousand per apply: each apply adds
    /// `round((1 - damp) * 1000)`. Zero when adaptivity is off or no
    /// delay has been observed; strictly positive once damping bites
    /// (the adaptive chaos smoke greps for that).
    pub gamma_damped_sum: AtomicU64,
    /// Updates rejected by the `quantile:Q` drop policy that the plain
    /// k/2 rule would have accepted — the *marginal* drops adaptivity is
    /// responsible for. Identically zero under `run.adapt.drop = k2`.
    pub drops_adaptive: AtomicU64,
    /// Worker batch (tau_w) changes decided by the
    /// `run.adapt.batch = auto` controller, counted by the serve role as
    /// payload-length transitions per worker. Zero with a fixed batch.
    pub batch_resizes: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            oracle_calls: self.oracle_calls.load(Ordering::Relaxed),
            updates_applied: self.updates_applied.load(Ordering::Relaxed),
            collisions: self.collisions.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            iterations: self.iterations.load(Ordering::Relaxed),
            snapshot_reads: self.snapshot_reads.load(Ordering::Relaxed),
            payload_nnz: self.payload_nnz.load(Ordering::Relaxed),
            payload_bytes: self.payload_bytes.load(Ordering::Relaxed),
            shipped_payload_bytes: self
                .shipped_payload_bytes
                .load(Ordering::Relaxed),
            wire_tx_bytes: self.wire_tx_bytes.load(Ordering::Relaxed),
            wire_rx_bytes: self.wire_rx_bytes.load(Ordering::Relaxed),
            delay_sum: self.delay_sum.load(Ordering::Relaxed),
            delay_max: self.delay_max.load(Ordering::Relaxed),
            workers_joined: self.workers_joined.load(Ordering::Relaxed),
            workers_lost: self.workers_lost.load(Ordering::Relaxed),
            blocks_requeued: self.blocks_requeued.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            event_stalls: self.event_stalls.load(Ordering::Relaxed),
            checkpoints_written: self
                .checkpoints_written
                .load(Ordering::Relaxed),
            restores: self.restores.load(Ordering::Relaxed),
            stale_fenced: self.stale_fenced.load(Ordering::Relaxed),
            gamma_damped_sum: self
                .gamma_damped_sum
                .load(Ordering::Relaxed),
            drops_adaptive: self.drops_adaptive.load(Ordering::Relaxed),
            batch_resizes: self.batch_resizes.load(Ordering::Relaxed),
        }
    }

    /// Pre-load these counters from a checkpointed snapshot (crash
    /// recovery: the restored serve loop continues the dead loop's
    /// telemetry instead of restarting it from zero, so post-restore
    /// reports stay comparable to an uninterrupted run's).
    pub fn absorb(&self, s: &CounterSnapshot) {
        Self::add(&self.oracle_calls, s.oracle_calls);
        Self::add(&self.updates_applied, s.updates_applied);
        Self::add(&self.collisions, s.collisions);
        Self::add(&self.dropped, s.dropped);
        Self::add(&self.iterations, s.iterations);
        Self::add(&self.snapshot_reads, s.snapshot_reads);
        Self::add(&self.payload_nnz, s.payload_nnz);
        Self::add(&self.payload_bytes, s.payload_bytes);
        Self::add(&self.shipped_payload_bytes, s.shipped_payload_bytes);
        Self::add(&self.wire_tx_bytes, s.wire_tx_bytes);
        Self::add(&self.wire_rx_bytes, s.wire_rx_bytes);
        Self::add(&self.delay_sum, s.delay_sum);
        Self::max_of(&self.delay_max, s.delay_max);
        Self::add(&self.workers_joined, s.workers_joined);
        Self::add(&self.workers_lost, s.workers_lost);
        Self::add(&self.blocks_requeued, s.blocks_requeued);
        Self::add(&self.reconnects, s.reconnects);
        Self::add(&self.event_stalls, s.event_stalls);
        Self::add(&self.checkpoints_written, s.checkpoints_written);
        Self::add(&self.restores, s.restores);
        Self::add(&self.stale_fenced, s.stale_fenced);
        Self::add(&self.gamma_damped_sum, s.gamma_damped_sum);
        Self::add(&self.drops_adaptive, s.drops_adaptive);
        Self::add(&self.batch_resizes, s.batch_resizes);
    }

    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Raise a running-maximum counter (e.g. `delay_max`) to at least `v`.
    #[inline]
    pub fn max_of(counter: &AtomicU64, v: u64) {
        counter.fetch_max(v, Ordering::Relaxed);
    }
}

/// Plain-data copy of `Counters`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub oracle_calls: u64,
    pub updates_applied: u64,
    pub collisions: u64,
    pub dropped: u64,
    pub iterations: u64,
    pub snapshot_reads: u64,
    pub payload_nnz: u64,
    pub payload_bytes: u64,
    pub shipped_payload_bytes: u64,
    pub wire_tx_bytes: u64,
    pub wire_rx_bytes: u64,
    pub delay_sum: u64,
    pub delay_max: u64,
    pub workers_joined: u64,
    pub workers_lost: u64,
    pub blocks_requeued: u64,
    pub reconnects: u64,
    pub event_stalls: u64,
    pub checkpoints_written: u64,
    pub restores: u64,
    pub stale_fenced: u64,
    pub gamma_damped_sum: u64,
    pub drops_adaptive: u64,
    pub batch_resizes: u64,
}

impl CounterSnapshot {
    /// Mean observed delay of applied updates — the empirical expected
    /// delay kappa of the paper's delayed-update analysis. Zero when
    /// nothing was applied.
    pub fn mean_delay(&self) -> f64 {
        if self.updates_applied == 0 {
            0.0
        } else {
            self.delay_sum as f64 / self.updates_applied as f64
        }
    }

    /// The `adapt:` summary line — the delay-adaptive control layer's
    /// one-line report. Renders all-zero (no NaN, no panic) before the
    /// first applied update and under all-off policies.
    pub fn adapt_summary(&self) -> String {
        format!(
            "adapt: gamma_damped_sum={} drops_adaptive={} \
             batch_resizes={}",
            self.gamma_damped_sum, self.drops_adaptive, self.batch_resizes
        )
    }
}

/// Simple wall-clock stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_trace() -> Trace {
        let mut t = Trace::default();
        for k in 0..10 {
            t.push(Sample {
                iter: k,
                oracle_calls: (k as u64 + 1) * 5,
                elapsed_s: k as f64 * 0.1,
                objective: 10.0 / (k as f64 + 1.0),
                gap: 20.0 / (k as f64 + 1.0),
            });
        }
        t
    }

    #[test]
    fn first_below_finds_threshold() {
        let t = mk_trace();
        // f - 0 <= 2.0 first at k: 10/(k+1) <= 2 -> k >= 4.
        let s = t.first_below(0.0, 2.0).unwrap();
        assert_eq!(s.iter, 4);
        assert!(t.first_below(0.0, 0.5).is_none());
    }

    #[test]
    fn epochs_and_secs() {
        let t = mk_trace();
        let e = t.epochs_to(0.0, 2.0, 5).unwrap();
        assert_eq!(e, 5.0); // k=4 -> calls=25 -> /5
        let s = t.secs_to(0.0, 2.0).unwrap();
        assert!((s - 0.4).abs() < 1e-12);
    }

    #[test]
    fn gap_threshold() {
        let t = mk_trace();
        let s = t.first_gap_below(4.0).unwrap();
        assert_eq!(s.iter, 4);
    }

    #[test]
    fn counters_threaded() {
        use std::sync::Arc;
        let c = Arc::new(Counters::new());
        let mut handles = vec![];
        for _ in 0..4 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    Counters::bump(&c.oracle_calls);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.snapshot().oracle_calls, 4000);
    }

    #[test]
    fn best_objective() {
        let t = mk_trace();
        assert!((t.best_objective() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_delay_is_zero_before_first_applied_update() {
        // The zero-updates path: no NaN, no panic, exactly 0.0 — the
        // kappa EMA seeded from this must start undamped.
        let snap = Counters::new().snapshot();
        assert_eq!(snap.updates_applied, 0);
        let kappa = snap.mean_delay();
        assert_eq!(kappa, 0.0);
        assert!(!kappa.is_nan());
    }

    #[test]
    fn adapt_summary_renders_zeroes_before_first_update() {
        let snap = Counters::new().snapshot();
        assert_eq!(
            snap.adapt_summary(),
            "adapt: gamma_damped_sum=0 drops_adaptive=0 batch_resizes=0"
        );
    }

    #[test]
    fn adapt_counters_survive_snapshot_and_absorb() {
        let c = Counters::new();
        Counters::add(&c.gamma_damped_sum, 123);
        Counters::bump(&c.drops_adaptive);
        Counters::add(&c.batch_resizes, 7);
        let snap = c.snapshot();
        assert_eq!(snap.gamma_damped_sum, 123);
        assert_eq!(snap.drops_adaptive, 1);
        assert_eq!(snap.batch_resizes, 7);
        let other = Counters::new();
        other.absorb(&snap);
        assert_eq!(other.snapshot().gamma_damped_sum, 123);
        assert_eq!(other.snapshot().batch_resizes, 7);
        assert!(snap
            .adapt_summary()
            .contains("gamma_damped_sum=123"));
    }
}
