//! Curvature estimation (paper Eq. 4-5, Theorem 3, Examples 1-3).
//!
//! The set curvature `C_f^(S)` is a sup over feasible x, oracle-vertex s and
//! gamma in [0,1]; we lower-bound it empirically by sampling all three, and
//! compare against the paper's closed-form Theorem-3 upper bound
//! `C_f^tau <= 4(tau B + tau (tau-1) mu)` using exact B/mu where available
//! (SimplexQp) or the paper's analytic bounds (GFL: B <= 2 lam^2 d,
//! mu <= lam^2 d).

use crate::problems::{ApplyOptions, Problem};
use crate::util::rng::Pcg64;

/// Theorem 3 bound: C_f^tau <= 4 (tau B + tau (tau - 1) mu).
pub fn theorem3_bound(tau: usize, b: f64, mu: f64) -> f64 {
    let t = tau as f64;
    4.0 * (t * b + t * (t - 1.0) * mu)
}

/// Paper Example 2 analytic parameters for GFL: (B, mu) = (2 lam^2 d, lam^2 d).
pub fn gfl_bounds(lam: f64, d: usize) -> (f64, f64) {
    (2.0 * lam * lam * d as f64, lam * lam * d as f64)
}

/// Paper Example 3 (worst case) structural SVM: B, mu <= R^2/(lam n^2).
pub fn ssvm_worstcase_bounds(r: f64, lam: f64, n: usize) -> (f64, f64) {
    let b = r * r / (lam * (n * n) as f64);
    (b, b)
}

/// Sample a random feasible point by a short randomized FW walk from init.
fn random_feasible<P: Problem<ServerState = ()>>(
    p: &P,
    steps: usize,
    rng: &mut Pcg64,
) -> Vec<f32> {
    let mut x = p.init_param();
    let n = p.num_blocks();
    for _ in 0..steps {
        let i = rng.below(n);
        // Random vertex: the oracle at a randomly perturbed point gives a
        // (data-dependent) extreme point; stepping with random gamma keeps
        // x a convex combination of extreme points -> feasible.
        let o = p.oracle(&x, i);
        let gamma = rng.uniform() as f32;
        p.apply(
            &mut (),
            &mut x,
            &[o],
            ApplyOptions {
                gamma,
                line_search: false,
            },
        );
    }
    x
}

/// Empirical lower bound on C_f^(S) for a fixed block set S.
///
/// Samples (x, s_(S), gamma) triples and evaluates the curvature quotient
/// `2/gamma^2 [ f(y) - f(x) - gamma <s_S - x_S, grad_S f(x)> ]` where the
/// inner product is taken from the finite-difference directional derivative.
pub fn estimate_set_curvature<P: Problem<ServerState = ()>>(
    p: &P,
    blocks: &[usize],
    samples: usize,
    rng: &mut Pcg64,
) -> f64 {
    let mut best = 0.0f64;
    for _ in 0..samples {
        let x = random_feasible(p, 8, rng);
        // s: oracle vertices at an independent random point (so s is a
        // generic vertex of M_S, not the descent direction at x).
        let xprobe = random_feasible(p, 4, rng);
        let batch: Vec<_> =
            blocks.iter().map(|&i| p.oracle(&xprobe, i)).collect();
        let gamma = 0.05 + 0.95 * rng.uniform();
        // y = x + gamma (s_[S] - x_[S]) via apply on a copy.
        let mut y = x.clone();
        p.apply(
            &mut (),
            &mut y,
            &batch,
            ApplyOptions {
                gamma: gamma as f32,
                line_search: false,
            },
        );
        let fx = p.objective_from(&x, 0.0);
        let fy = p.objective_from(&y, 0.0);
        // directional derivative along (y - x) at x, via central difference
        let eps = 1e-4f64;
        let dir: Vec<f32> = y.iter().zip(x.iter()).map(|(a, b)| a - b).collect();
        let mut xp = x.clone();
        let mut xm = x.clone();
        for ((p1, m1), dv) in xp.iter_mut().zip(xm.iter_mut()).zip(dir.iter()) {
            *p1 += eps as f32 * dv;
            *m1 -= eps as f32 * dv;
        }
        let dd = (p.objective_from(&xp, 0.0) - p.objective_from(&xm, 0.0))
            / (2.0 * eps);
        let quotient = 2.0 / (gamma * gamma) * (fy - fx - dd);
        if quotient.is_finite() && quotient > best {
            best = quotient;
        }
    }
    best
}

/// Empirical estimate of the expected set curvature C_f^tau: mean of the
/// per-subset estimates over uniformly drawn subsets of size tau.
pub fn estimate_expected_curvature<P: Problem<ServerState = ()>>(
    p: &P,
    tau: usize,
    subsets: usize,
    samples_per_subset: usize,
    rng: &mut Pcg64,
) -> f64 {
    let n = p.num_blocks();
    let mut acc = 0.0f64;
    for _ in 0..subsets {
        let s = rng.subset(n, tau.min(n));
        acc += estimate_set_curvature(p, &s, samples_per_subset, rng);
    }
    acc / subsets as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::problems::simplex_qp::SimplexQp;

    #[test]
    fn theorem3_bound_shapes() {
        // mu = 0: linear in tau.
        let b = 3.0;
        assert_eq!(theorem3_bound(1, b, 0.0), 12.0);
        assert_eq!(theorem3_bound(4, b, 0.0), 48.0);
        // mu > 0: superlinear.
        let with_mu: Vec<f64> =
            (1..=4).map(|t| theorem3_bound(t, 1.0, 1.0)).collect();
        assert!(with_mu[3] > 4.0 * with_mu[0]);
    }

    #[test]
    fn empirical_curvature_below_theorem3_bound_qp() {
        let qp = SimplexQp::random(10, 4, 1.0, 0.5, 3, 21);
        let mut rng = Pcg64::seeded(22);
        // exact B and mu from the instance
        let n = qp.n;
        let b: f64 =
            (0..n).map(|i| qp.boundedness(i)).sum::<f64>() / n as f64;
        let mut mu_acc = 0.0;
        let mut cnt = 0usize;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    mu_acc += qp.incoherence(i, j);
                    cnt += 1;
                }
            }
        }
        let mu = mu_acc / cnt as f64;
        for tau in [1usize, 3, 6] {
            let est = estimate_expected_curvature(&qp, tau, 4, 12, &mut rng);
            let bound = theorem3_bound(tau, b, mu.max(0.0));
            assert!(
                est <= bound + 1e-6,
                "tau={tau}: est {est} > bound {bound}"
            );
            assert!(est >= 0.0);
        }
    }

    #[test]
    fn gfl_curvature_scales_linearly_in_tau() {
        // Example 2: C_f^tau <= 4 tau lam^2 d — linear in tau. Check the
        // empirical estimate respects the bound.
        let mut rng = Pcg64::seeded(23);
        let (d, n, lam) = (4, 24, 0.5);
        let y = rng.gaussian_vec(d * n);
        let gfl = Gfl::new(d, n, lam, y);
        let (b, mu) = gfl_bounds(lam, d);
        assert_eq!(b, 2.0 * lam * lam * d as f64);
        for tau in [1usize, 4] {
            let est = estimate_expected_curvature(&gfl, tau, 3, 10, &mut rng);
            // paper Example 2 final bound: 4 tau lam^2 d
            let bound = 4.0 * tau as f64 * lam * lam * d as f64;
            assert!(
                est <= bound + 1e-6,
                "tau={tau}: est {est} > {bound}"
            );
            let _ = mu;
        }
    }
}
