//! Analysis toolkit: curvature estimation (paper §2.1-2.2) and
//! duality-gap utilities.

pub mod curvature;
pub mod gap;
