//! Surrogate duality-gap utilities (paper Eq. 7).
//!
//! `g(x) = sum_i g_i(x)` is exact but costs one oracle call per block; the
//! paper's estimator `g-hat(x) = (n/|S|) sum_{i in S} g_i(x)` is unbiased
//! over a uniform random subset S and concentrates by McDiarmid as tau
//! grows. Both are provided here, plus a subsampled confidence check used
//! as a stopping heuristic.

use crate::problems::Problem;
use crate::util::rng::Pcg64;

/// Exact surrogate gap (n oracle calls).
pub fn exact_gap<P: Problem>(
    problem: &P,
    state: &P::ServerState,
    param: &[f32],
) -> f64 {
    problem.full_gap(state, param)
}

/// Unbiased subset estimate g-hat over `sample` uniformly chosen blocks.
pub fn estimate_gap<P: Problem>(
    problem: &P,
    state: &P::ServerState,
    param: &[f32],
    sample: usize,
    rng: &mut Pcg64,
) -> f64 {
    let n = problem.num_blocks();
    let m = sample.clamp(1, n);
    let subset = rng.subset(n, m);
    let mut acc = 0.0f64;
    for i in subset {
        let o = problem.oracle(param, i);
        acc += problem.block_gap(state, param, &o);
    }
    acc * n as f64 / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::problems::Problem;
    use crate::util::rng::Pcg64;

    fn instance() -> (Gfl, Vec<f32>) {
        let mut rng = Pcg64::seeded(13);
        let (d, n, lam) = (5, 30, 0.3);
        let y = rng.gaussian_vec(d * n);
        let gfl = Gfl::new(d, n, lam, y);
        let mut u = rng.gaussian_vec(d * (n - 1));
        for t in 0..n - 1 {
            crate::util::la::project_l2_ball(lam, &mut u[t * d..(t + 1) * d]);
        }
        (gfl, u)
    }

    #[test]
    fn estimator_is_unbiased() {
        let (gfl, u) = instance();
        let exact = exact_gap(&gfl, &(), &u);
        let mut rng = Pcg64::seeded(14);
        let reps = 400;
        let mean: f64 = (0..reps)
            .map(|_| estimate_gap(&gfl, &(), &u, 5, &mut rng))
            .sum::<f64>()
            / reps as f64;
        assert!(
            (mean - exact).abs() < 0.05 * exact.max(1.0),
            "mean={mean} exact={exact}"
        );
    }

    #[test]
    fn full_subset_equals_exact() {
        let (gfl, u) = instance();
        let exact = exact_gap(&gfl, &(), &u);
        let mut rng = Pcg64::seeded(15);
        let est = estimate_gap(&gfl, &(), &u, gfl.num_blocks(), &mut rng);
        assert!((est - exact).abs() < 1e-9);
    }

    #[test]
    fn variance_shrinks_with_sample_size() {
        let (gfl, u) = instance();
        let mut rng = Pcg64::seeded(16);
        let var = |m: usize, rng: &mut Pcg64| {
            let xs: Vec<f64> = (0..200)
                .map(|_| estimate_gap(&gfl, &(), &u, m, rng))
                .collect();
            crate::util::stats::stddev(&xs)
        };
        let s1 = var(2, &mut rng);
        let s2 = var(15, &mut rng);
        assert!(s2 < s1, "sd(m=2)={s1} sd(m=15)={s2}");
    }
}
