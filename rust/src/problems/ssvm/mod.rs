//! Structural SVM dual, solved in primal `w`-space as in BCFW
//! (Lacoste-Julien et al. 2013, Algorithm 4; paper Appendix C).
//!
//! The dual variable `alpha` lives on a product of simplices with
//! exponentially many vertices per block, so — exactly as the paper does —
//! the implementation never materializes `alpha`. Each block i keeps
//! `w_i = A_i alpha_i` and `l_i = b_i^T alpha_i`; the shared parameter is
//! `w = sum_i w_i` (what workers need for decoding); the server additionally
//! tracks `l = sum_i l_i`. The dual objective is
//!
//!   f(alpha) = lambda/2 ||w||^2 - l,
//!
//! the block oracle is loss-augmented decoding (`argmax_y H_i(y; w)`), the
//! block gap is `g_i = lambda <w, w_i - w_s> - l_i + l_s`, and exact line
//! search is `gamma* = gap_S / (lambda ||sum_i (w_s - w_i)||^2)`.

pub mod chain;
pub mod multiclass;

use super::{BlockOracle, OraclePayload};
use crate::util::la;
use anyhow::{ensure, Result};

/// Server-side per-block bookkeeping shared by both SSVM variants.
pub struct SsvmState {
    /// Per-block primal contributions, flattened (n x dim).
    pub wi: Vec<f32>,
    /// Per-block loss contributions l_i.
    pub li: Vec<f64>,
    /// l = sum_i l_i.
    pub l: f64,
    /// Parameter dimension.
    pub dim: usize,
    /// Direction buffer for [`ssvm_apply`] — the server applies batches in
    /// a tight loop, so the O(dim) direction vector lives in the explicit
    /// server state (caller-owned, like the oracle scratch) instead of
    /// being reallocated per batch or hidden in a thread-local.
    dw: Vec<f32>,
}

impl SsvmState {
    pub fn new(n: usize, dim: usize) -> Self {
        Self {
            wi: vec![0.0; n * dim],
            li: vec![0.0; n],
            l: 0.0,
            dim,
            dw: Vec::new(),
        }
    }

    #[inline]
    pub fn wi(&self, i: usize) -> &[f32] {
        &self.wi[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn wi_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.wi[i * self.dim..(i + 1) * self.dim]
    }

    /// Serialize the durable bookkeeping — `wi`, `li`, `l` — for a crash
    /// checkpoint, bit-exactly (raw little-endian f32/f64 bits). The `dw`
    /// apply scratch is transient and deliberately excluded. Both SSVM
    /// variants delegate their `Problem::checkpoint_server_state` here.
    pub fn encode(&self) -> Vec<u8> {
        let n = self.li.len();
        let mut out =
            Vec::with_capacity(16 + 4 * self.wi.len() + 8 * n + 8);
        out.extend_from_slice(&(n as u64).to_le_bytes());
        out.extend_from_slice(&(self.dim as u64).to_le_bytes());
        for v in &self.wi {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.li {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&self.l.to_le_bytes());
        out
    }

    /// Inverse of [`SsvmState::encode`], validating every length against
    /// this instance's shape so a checkpoint from a different problem
    /// configuration fails cleanly instead of poisoning the apply path.
    pub fn decode(&mut self, raw: &[u8]) -> Result<()> {
        let n = self.li.len();
        let want = 16 + 4 * self.wi.len() + 8 * n + 8;
        ensure!(
            raw.len() == want,
            "ssvm server-state checkpoint is {} bytes (expected {want})",
            raw.len()
        );
        let header_n =
            u64::from_le_bytes(raw[0..8].try_into().unwrap()) as usize;
        let header_dim =
            u64::from_le_bytes(raw[8..16].try_into().unwrap()) as usize;
        ensure!(
            header_n == n && header_dim == self.dim,
            "ssvm server-state checkpoint shape ({header_n} x \
             {header_dim}) does not match this instance ({n} x {})",
            self.dim
        );
        let mut pos = 16;
        for v in &mut self.wi {
            *v = f32::from_le_bytes(raw[pos..pos + 4].try_into().unwrap());
            pos += 4;
        }
        for v in &mut self.li {
            *v = f64::from_le_bytes(raw[pos..pos + 8].try_into().unwrap());
            pos += 8;
        }
        self.l = f64::from_le_bytes(raw[pos..pos + 8].try_into().unwrap());
        Ok(())
    }
}

/// `g_i = lambda <w, w_i - w_s> - l_i + l_s` at the current (w, state).
///
/// Accepts either payload representation. Monitoring callers feed it
/// dense oracles (`Problem::oracle`); the sparse arm's gather dot is
/// tolerance-equivalent, not bit-matched, to the pairwise dense `dot` —
/// the bit-pinned batch gap lives in [`ssvm_apply`]'s fused traversal.
pub fn ssvm_block_gap(
    lam: f64,
    state: &SsvmState,
    w: &[f32],
    o: &BlockOracle,
) -> f64 {
    let wi = state.wi(o.block);
    let w_dot_s = match &o.s {
        OraclePayload::Dense(s) => la::dot(w, s),
        OraclePayload::Sparse { idx, val, .. } => la::dot_sparse(idx, val, w),
    };
    lam * (la::dot(w, wi) - w_dot_s) - state.li[o.block] + o.ls
}

/// Apply a disjoint-block batch; returns (gamma_used, batch_gap).
///
/// The direction build and the gap evaluation are FUSED into one traversal
/// of the batch payloads: each oracle's contribution to both
/// `Delta_w = sum_i (w_s - w_i)` and `<w, Delta_w>` is accumulated in the
/// same pass over the dim-length vectors, so the batch gap costs no second
/// O(dim) sweep (the historical implementation rebuilt the dot product
/// from the finished direction).
///
/// Payloads may be dense or sparse; the traversal streams a sparse payload
/// through `dense_iter` (never materializing it), which yields exactly the
/// dense payload's floats, so both representations accumulate bit-identical
/// `dw`/`batch_gap` — and the per-block `w_i` convex update uses the sparse
/// scale-then-scatter lerp, bit-identical to the dense `lerp_into` (see
/// `util::simd`).
pub fn ssvm_apply(
    lam: f64,
    state: &mut SsvmState,
    w: &mut [f32],
    batch: &[BlockOracle],
    gamma: f32,
    line_search: bool,
) -> (f32, f64) {
    let dim = state.dim;
    // Detach the direction buffer so the per-block `state.wi(..)` views
    // below can borrow `state` immutably alongside it; reattached at the
    // end, so its capacity persists across calls.
    let mut dw = std::mem::take(&mut state.dw);
    dw.clear();
    dw.resize(dim, 0.0);
    let mut dl = 0.0f64;
    // <w, Delta_w>, accumulated per oracle in the fused pass.
    let mut w_dot_dw = 0.0f64;
    for o in batch {
        debug_assert_eq!(o.s.dim(), dim);
        let wi = state.wi(o.block);
        let mut acc = 0.0f64;
        // Per-oracle match so the dense arm keeps the plain slice loop
        // (no per-element iterator dispatch on the hot path); the sparse
        // arm streams dense_iter, which yields exactly the dense
        // payload's floats — both accumulate identical bits.
        match &o.s {
            OraclePayload::Dense(s) => {
                for ((dwr, &wr), (sr, wir)) in dw
                    .iter_mut()
                    .zip(w.iter())
                    .zip(s.iter().zip(wi.iter()))
                {
                    let d = sr - wir;
                    *dwr += d;
                    acc += wr as f64 * d as f64;
                }
            }
            OraclePayload::Sparse { .. } => {
                for ((dwr, &wr), (sr, wir)) in dw
                    .iter_mut()
                    .zip(w.iter())
                    .zip(o.s.dense_iter().zip(wi.iter()))
                {
                    let d = sr - wir;
                    *dwr += d;
                    acc += wr as f64 * d as f64;
                }
            }
        }
        w_dot_dw += acc;
        dl += o.ls - state.li[o.block];
    }
    let batch_gap = -lam * w_dot_dw + dl;
    let g = if line_search {
        let denom = lam * la::norm2_sq(&dw);
        if denom <= 0.0 {
            0.0
        } else {
            (batch_gap / denom).clamp(0.0, 1.0) as f32
        }
    } else {
        gamma
    };
    for o in batch {
        let li = state.li[o.block];
        state.li[o.block] = li + g as f64 * (o.ls - li);
        let wi = state.wi_mut(o.block);
        match &o.s {
            OraclePayload::Dense(s) => la::lerp_into(g, s, wi),
            OraclePayload::Sparse { idx, val, .. } => {
                la::lerp_into_sparse(g, idx, val, wi)
            }
        }
    }
    state.l += g as f64 * dl;
    la::axpy(g, &dw, w);
    state.dw = dw;
    (g, batch_gap)
}

/// Dual objective f(alpha) = lambda/2 ||w||^2 - l.
pub fn ssvm_objective(lam: f64, state: &SsvmState, w: &[f32]) -> f64 {
    0.5 * lam * la::norm2_sq(w) - state.l
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_state_checkpoint_roundtrips_bit_exactly() {
        let (n, dim) = (3, 5);
        let mut st = SsvmState::new(n, dim);
        for (j, v) in st.wi.iter_mut().enumerate() {
            *v = (j as f32 + 0.25) * if j % 2 == 0 { 1.0 } else { -1.0 };
        }
        for (i, v) in st.li.iter_mut().enumerate() {
            *v = i as f64 * 0.125 - 0.5;
        }
        st.l = 3.75;
        let raw = st.encode();

        let mut back = SsvmState::new(n, dim);
        back.decode(&raw).unwrap();
        assert_eq!(
            back.wi.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            st.wi.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            back.li.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            st.li.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(back.l.to_bits(), st.l.to_bits());
    }

    #[test]
    fn server_state_decode_rejects_wrong_shapes_cleanly() {
        let raw = SsvmState::new(2, 7).encode();

        // Truncated / extended payloads: clean errors, no panic.
        let mut st = SsvmState::new(2, 7);
        assert!(st.decode(&raw[..raw.len() - 1]).is_err());
        let mut longer = raw.clone();
        longer.push(0);
        assert!(st.decode(&longer).is_err());
        assert!(st.decode(&[]).is_err());

        // Same byte length, different declared shape: a 6 x 1 state
        // encodes to exactly as many bytes as 2 x 7 (4*n*dim + 8*n agree),
        // so only the header shape check can catch the mismatch.
        let swapped = SsvmState::new(6, 1).encode();
        assert_eq!(swapped.len(), raw.len());
        assert!(st.decode(&swapped).is_err());

        // A clean decode still works after the failed attempts.
        st.decode(&raw).unwrap();
    }

    fn mk_oracle(block: usize, s: Vec<f32>, ls: f64) -> BlockOracle {
        BlockOracle::dense(block, s, ls)
    }

    /// Sparse twin of a dense payload: explicit support of the nonzeros.
    fn sparsify(o: &BlockOracle) -> BlockOracle {
        let s = o.s.as_dense().unwrap();
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (j, &v) in s.iter().enumerate() {
            if v != 0.0 {
                idx.push(j as u32);
                val.push(v);
            }
        }
        BlockOracle {
            block: o.block,
            s: OraclePayload::Sparse {
                idx,
                val,
                dim: s.len() as u32,
            },
            ls: o.ls,
        }
    }

    #[test]
    fn sparse_batch_applies_bit_identically_to_dense() {
        let (n, dim, lam) = (4, 7, 0.5);
        let batches = vec![
            vec![mk_oracle(0, vec![1.0, 0.0, 0.0, -2.0, 0.0, 0.5, 0.0], 0.1)],
            vec![
                mk_oracle(1, vec![0.0; 7], 0.0), // empty support
                mk_oracle(2, vec![0.5, -0.5, 0.0, 0.0, 1.5, 0.0, 0.25], 0.05),
            ],
            vec![mk_oracle(0, vec![-1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0], 0.3)],
        ];
        for line_search in [false, true] {
            let mut st_d = SsvmState::new(n, dim);
            let mut w_d = vec![0.0f32; dim];
            let mut st_s = SsvmState::new(n, dim);
            let mut w_s = vec![0.0f32; dim];
            for (k, b) in batches.iter().enumerate() {
                // k = 0 exercises the clamped gamma = 1 step.
                let gamma = 2.0 / (k as f32 + 2.0);
                let sb: Vec<BlockOracle> = b.iter().map(sparsify).collect();
                let (gd, gapd) =
                    ssvm_apply(lam, &mut st_d, &mut w_d, b, gamma, line_search);
                let (gs, gaps) =
                    ssvm_apply(lam, &mut st_s, &mut w_s, &sb, gamma, line_search);
                assert_eq!(gd.to_bits(), gs.to_bits(), "gamma k={k}");
                assert_eq!(gapd.to_bits(), gaps.to_bits(), "gap k={k}");
            }
            for (a, b) in w_d.iter().zip(&w_s) {
                assert_eq!(a.to_bits(), b.to_bits(), "w");
            }
            for (a, b) in st_d.wi.iter().zip(&st_s.wi) {
                assert_eq!(a.to_bits(), b.to_bits(), "wi");
            }
            assert_eq!(st_d.l.to_bits(), st_s.l.to_bits());
        }
    }

    #[test]
    fn apply_maintains_w_equals_sum_wi() {
        let (n, dim, lam) = (5, 3, 0.5);
        let mut st = SsvmState::new(n, dim);
        let mut w = vec![0.0f32; dim];
        let batches = vec![
            vec![mk_oracle(0, vec![1.0, 0.0, 0.0], 0.1)],
            vec![
                mk_oracle(1, vec![0.0, 2.0, 0.0], 0.2),
                mk_oracle(2, vec![0.5, 0.5, 0.5], 0.05),
            ],
            vec![mk_oracle(0, vec![-1.0, 0.0, 1.0], 0.3)],
        ];
        for (k, b) in batches.iter().enumerate() {
            let gamma = 2.0 / (k as f32 + 2.0);
            ssvm_apply(lam, &mut st, &mut w, b, gamma, false);
        }
        let mut sum = vec![0.0f32; dim];
        for i in 0..n {
            la::axpy(1.0, st.wi(i), &mut sum);
        }
        for (a, b) in w.iter().zip(sum.iter()) {
            assert!((a - b).abs() < 1e-5, "w={w:?} sum={sum:?}");
        }
        let l_sum: f64 = st.li.iter().sum();
        assert!((st.l - l_sum).abs() < 1e-10);
    }

    #[test]
    fn line_search_gamma_optimal_for_quadratic() {
        let (n, dim, lam) = (3, 4, 1.0);
        let mut st = SsvmState::new(n, dim);
        let mut w = vec![0.0f32; dim];
        // seed with one fixed-step update so w != 0
        ssvm_apply(
            lam,
            &mut st,
            &mut w,
            &[mk_oracle(0, vec![1.0, -1.0, 0.5, 0.0], 0.4)],
            0.7,
            false,
        );
        let batch = vec![mk_oracle(1, vec![0.2, 0.3, -0.1, 0.9], 0.6)];
        // line-search objective must be <= any fixed step's
        let base_state_w = (st.wi.clone(), st.li.clone(), st.l, w.clone());
        let run = |gamma: f32, ls: bool| {
            let mut st2 = SsvmState::new(n, dim);
            st2.wi = base_state_w.0.clone();
            st2.li = base_state_w.1.clone();
            st2.l = base_state_w.2;
            let mut w2 = base_state_w.3.clone();
            ssvm_apply(lam, &mut st2, &mut w2, &batch, gamma, ls);
            ssvm_objective(lam, &st2, &w2)
        };
        let f_ls = run(0.0, true);
        for gamma in [0.0f32, 0.1, 0.3, 0.5, 0.9, 1.0] {
            assert!(f_ls <= run(gamma, false) + 1e-9, "gamma={gamma}");
        }
    }

    #[test]
    fn gap_formula_matches_objective_decrease_rate() {
        // For the quadratic dual, d/dgamma f(x + gamma d)|_0 = -batch_gap.
        let (n, dim, lam) = (2, 3, 0.8);
        let mut st = SsvmState::new(n, dim);
        let mut w = vec![0.0f32; dim];
        ssvm_apply(
            lam,
            &mut st,
            &mut w,
            &[mk_oracle(0, vec![1.0, 2.0, -1.0], 0.5)],
            0.6,
            false,
        );
        let batch = vec![mk_oracle(1, vec![-0.5, 1.0, 0.25], 0.2)];
        let f0 = ssvm_objective(lam, &st, &w);
        let gap = {
            let mut st2 = SsvmState::new(n, dim);
            st2.wi = st.wi.clone();
            st2.li = st.li.clone();
            st2.l = st.l;
            let mut w2 = w.clone();
            let (_, bg) = ssvm_apply(lam, &mut st2, &mut w2, &batch, 1e-4, false);
            let f1 = ssvm_objective(lam, &st2, &w2);
            // (f1 - f0)/gamma ~= -gap at gamma -> 0
            assert!(
                ((f1 - f0) / 1e-4 + bg).abs() < 1e-2,
                "fd={} gap={}",
                (f1 - f0) / 1e-4,
                bg
            );
            bg
        };
        let o = &batch[0];
        let manual = ssvm_block_gap(lam, &st, &w, o);
        assert!((gap - manual).abs() < 1e-9);
    }
}
