//! Multiclass structural SVM (paper Example 1).
//!
//! Parameter layout: `w = (K x d, row-major)`, dimension `D = K*d`. The
//! block oracle is loss-augmented argmax over K classes with 0/1 loss:
//! `y* = argmax_j [ 1{j != y_i} + <w_j - w_{y_i}, x_i> ]`.

use super::super::{
    ApplyInfo, ApplyOptions, BlockOracle, PayloadKind, Problem,
};
use super::{ssvm_apply, ssvm_block_gap, SsvmState};
use crate::data::mixture::MulticlassDataset;
use std::sync::Arc;

/// Pluggable decoder (XLA artifact path implements this).
pub trait MulticlassDecoder: Send + Sync {
    /// Returns (y*, H_i) for datapoint i against weights `w`.
    fn decode(&self, w: &[f32], i: usize, loss_weight: f32) -> (usize, f64);
}

/// Multiclass SSVM over a [`MulticlassDataset`].
pub struct MulticlassSsvm {
    pub data: Arc<MulticlassDataset>,
    pub lam: f64,
    pub decoder: Option<Arc<dyn MulticlassDecoder>>,
}

impl MulticlassSsvm {
    pub fn new(data: Arc<MulticlassDataset>, lam: f64) -> Self {
        Self {
            data,
            lam,
            decoder: None,
        }
    }

    pub fn with_decoder(mut self, d: Arc<dyn MulticlassDecoder>) -> Self {
        self.decoder = Some(d);
        self
    }

    pub fn dim(&self) -> usize {
        self.data.k * self.data.d
    }

    /// Native loss-augmented argmax: (y*, H_i). Single pass, no score
    /// buffer — the per-class score and augmented max are tracked inline,
    /// which keeps [`Problem::oracle_into`] allocation-free.
    pub fn argmax(&self, w: &[f32], i: usize, loss_weight: f32) -> (usize, f64) {
        let (k, d) = (self.data.k, self.data.d);
        let x = self.data.feature(i);
        let yt = self.data.label(i);
        let mut best = f64::NEG_INFINITY;
        let mut arg = 0usize;
        let mut score_true = 0.0f64;
        for c in 0..k {
            let row = &w[c * d..(c + 1) * d];
            let mut s = 0.0f64;
            for r in 0..d {
                s += row[r] as f64 * x[r] as f64;
            }
            if c == yt {
                score_true = s;
            }
            let aug = s + if c != yt { loss_weight as f64 } else { 0.0 };
            if aug > best {
                best = aug;
                arg = c;
            }
        }
        (arg, best - score_true)
    }

    /// BCFW payload for decode y*: w_s = psi_i(y*)/(lam n), l_s = 1{y* != y_i}/n.
    pub fn payload(&self, i: usize, ystar: usize) -> (Vec<f32>, f64) {
        let mut ws = Vec::new();
        let ls = self.payload_into(i, ystar, &mut ws);
        (ws, ls)
    }

    /// Payload written into a caller-owned buffer; returns l_s.
    pub fn payload_into(
        &self,
        i: usize,
        ystar: usize,
        ws: &mut Vec<f32>,
    ) -> f64 {
        let (d, n) = (self.data.d, self.data.n);
        ws.clear();
        ws.resize(self.dim(), 0.0);
        let yt = self.data.label(i);
        if ystar != yt {
            let scale = (1.0 / (self.lam * n as f64)) as f32;
            let x = self.data.feature(i);
            for r in 0..d {
                ws[yt * d + r] += scale * x[r];
                ws[ystar * d + r] -= scale * x[r];
            }
            1.0 / n as f64
        } else {
            0.0
        }
    }

    /// Sparse form of [`MulticlassSsvm::payload_into`]: the support is the
    /// true and decoded class rows (empty when `y* == y_i`), emitted in
    /// ascending index order with exactly the dense accumulation's values
    /// (`0.0 ± scale*x[r]`), so the payload densifies bit-identically.
    /// Returns l_s.
    pub fn payload_into_sparse(
        &self,
        i: usize,
        ystar: usize,
        idx: &mut Vec<u32>,
        val: &mut Vec<f32>,
    ) -> f64 {
        let (d, n) = (self.data.d, self.data.n);
        idx.clear();
        val.clear();
        let yt = self.data.label(i);
        if ystar == yt {
            return 0.0;
        }
        let scale = (1.0 / (self.lam * n as f64)) as f32;
        let x = self.data.feature(i);
        let (lo, hi, lo_is_true) = if yt < ystar {
            (yt, ystar, true)
        } else {
            (ystar, yt, false)
        };
        for r in 0..d {
            idx.push((lo * d + r) as u32);
            val.push(if lo_is_true {
                0.0 + scale * x[r]
            } else {
                0.0 - scale * x[r]
            });
        }
        for r in 0..d {
            idx.push((hi * d + r) as u32);
            val.push(if lo_is_true {
                0.0 - scale * x[r]
            } else {
                0.0 + scale * x[r]
            });
        }
        1.0 / n as f64
    }

    /// 0/1 test error of plain argmax prediction.
    pub fn zero_one_error(&self, w: &[f32], indices: &[usize]) -> f64 {
        let mut wrong = 0usize;
        for &i in indices {
            let (pred, _) = self.decode(w, i, 0.0);
            if pred != self.data.label(i) {
                wrong += 1;
            }
        }
        wrong as f64 / indices.len().max(1) as f64
    }

    fn decode(&self, w: &[f32], i: usize, lw: f32) -> (usize, f64) {
        match &self.decoder {
            Some(d) => d.decode(w, i, lw),
            None => self.argmax(w, i, lw),
        }
    }
}

impl Problem for MulticlassSsvm {
    type ServerState = SsvmState;
    // The single-pass argmax tracks its running max inline; the payload is
    // built straight into the caller's slot, so no scratch is needed.
    type Scratch = ();

    fn name(&self) -> &'static str {
        "ssvm_multiclass"
    }

    fn num_blocks(&self) -> usize {
        self.data.n
    }

    fn param_dim(&self) -> usize {
        self.dim()
    }

    fn init_param(&self) -> Vec<f32> {
        vec![0.0; self.dim()]
    }

    fn init_server(&self) -> SsvmState {
        SsvmState::new(self.data.n, self.dim())
    }

    fn checkpoint_server_state(&self, state: &SsvmState) -> Vec<u8> {
        state.encode()
    }

    fn restore_server_state(
        &self,
        state: &mut SsvmState,
        raw: &[u8],
    ) -> anyhow::Result<()> {
        state.decode(raw)
    }

    fn preferred_payload(&self) -> PayloadKind {
        // One class row of ±psi_i(y*)/(lambda n): 2d entries (or none)
        // versus the K*d dense vector.
        PayloadKind::Sparse
    }

    fn oracle(&self, param: &[f32], block: usize) -> BlockOracle {
        let (ystar, _h) = self.decode(param, block, 1.0);
        let (ws, ls) = self.payload(block, ystar);
        BlockOracle::dense(block, ws, ls)
    }

    fn oracle_into(
        &self,
        param: &[f32],
        block: usize,
        _scratch: &mut (),
        out: &mut BlockOracle,
    ) {
        // Decode through whichever backend is active, but always build the
        // payload into the caller's pooled `out.s` container (in whichever
        // representation it requests) — the external-decoder path used to
        // delegate to `oracle` and re-allocate a dim-D payload per call.
        let (ystar, _h) = self.decode(param, block, 1.0);
        out.block = block;
        out.ls = match out.s.kind() {
            PayloadKind::Dense => {
                self.payload_into(block, ystar, out.s.ensure_dense())
            }
            PayloadKind::Sparse => {
                let (idx, val) = out.s.make_sparse(self.dim());
                self.payload_into_sparse(block, ystar, idx, val)
            }
        };
    }

    fn block_gap(
        &self,
        state: &SsvmState,
        param: &[f32],
        o: &BlockOracle,
    ) -> f64 {
        ssvm_block_gap(self.lam, state, param, o)
    }

    fn apply(
        &self,
        state: &mut SsvmState,
        param: &mut [f32],
        batch: &[BlockOracle],
        opts: ApplyOptions,
    ) -> ApplyInfo {
        let (gamma, batch_gap) = ssvm_apply(
            self.lam,
            state,
            param,
            batch,
            opts.gamma,
            opts.line_search,
        );
        ApplyInfo { gamma, batch_gap }
    }

    fn aux(&self, state: &SsvmState) -> f64 {
        state.l
    }

    fn objective_from(&self, param: &[f32], aux: f64) -> f64 {
        0.5 * self.lam * crate::util::la::norm2_sq(param) - aux
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mixture;
    use crate::util::rng::Pcg64;

    fn instance() -> MulticlassSsvm {
        let data = Arc::new(mixture::generate(80, 5, 16, 0.2, 1));
        MulticlassSsvm::new(data, 0.1)
    }

    #[test]
    fn argmax_matches_bruteforce() {
        let p = instance();
        let mut rng = Pcg64::seeded(2);
        let w: Vec<f32> = rng.gaussian_vec(p.dim());
        for i in 0..p.data.n {
            let (ys, h) = p.argmax(&w, i, 1.0);
            let x = p.data.feature(i);
            let yt = p.data.label(i);
            let score = |c: usize| -> f64 {
                let row = &w[c * p.data.d..(c + 1) * p.data.d];
                row.iter()
                    .zip(x.iter())
                    .map(|(a, b)| *a as f64 * *b as f64)
                    .sum::<f64>()
            };
            let (mut best, mut arg) = (f64::NEG_INFINITY, 0);
            for c in 0..p.data.k {
                let v = score(c) + if c != yt { 1.0 } else { 0.0 };
                if v > best {
                    best = v;
                    arg = c;
                }
            }
            assert_eq!(ys, arg);
            assert!((h - (best - score(yt))).abs() < 1e-9);
            assert!(h >= -1e-12);
        }
    }

    #[test]
    fn payload_norm_matches_example1_boundedness() {
        // Paper Example 1: B_i = 2/(n^2 lam) when x on unit sphere; check
        // ||w_s||^2 = ||psi||^2/(lam n)^2 = 2/(lam n)^2 for y* != y.
        let p = instance();
        let i = 3;
        let yt = p.data.label(i);
        let ystar = (yt + 1) % p.data.k;
        let (ws, ls) = p.payload(i, ystar);
        let norm_sq = crate::util::la::norm2_sq(&ws);
        let expected = 2.0 / (p.lam * p.data.n as f64).powi(2);
        assert!(
            (norm_sq - expected).abs() < 1e-6 * expected,
            "{norm_sq} vs {expected}"
        );
        assert!((ls - 1.0 / p.data.n as f64).abs() < 1e-15);
    }

    #[test]
    fn sparse_payload_densifies_bit_identically() {
        let p = instance();
        let mut rng = Pcg64::seeded(9);
        let w: Vec<f32> = rng.gaussian_vec(p.dim());
        let mut slot = BlockOracle::empty_with(PayloadKind::Sparse);
        for i in 0..p.data.n {
            p.oracle_into(&w, i, &mut (), &mut slot);
            slot.s.debug_check_invariants();
            let dense = p.oracle(&w, i);
            assert_eq!(slot.ls.to_bits(), dense.ls.to_bits(), "ls {i}");
            let d = dense.s.as_dense().unwrap();
            let ds = slot.s.to_dense_vec();
            for (j, (a, b)) in ds.iter().zip(d.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "i={i} j={j}");
            }
            assert!(slot.s.nnz() == 0 || slot.s.nnz() == 2 * p.data.d);
        }
        // Empty-support arm (y* == y_i), driven deterministically: the
        // emitter must clear a dirty container and return ls = 0.
        let (mut idx, mut val) = (vec![7u32], vec![3.0f32]);
        let ls = p.payload_into_sparse(4, p.data.label(4), &mut idx, &mut val);
        assert_eq!(ls, 0.0);
        assert!(idx.is_empty() && val.is_empty(), "stale support kept");
    }

    #[test]
    fn bcfw_training_reduces_error_and_dual() {
        let p = instance();
        let mut st = p.init_server();
        let mut w = p.init_param();
        let n = p.num_blocks();
        let idx: Vec<usize> = (0..n).collect();
        let err0 = p.zero_one_error(&w, &idx);
        let mut rng = Pcg64::seeded(5);
        for k in 0..800 {
            let i = rng.below(n);
            let o = p.oracle(&w, i);
            let gamma = 2.0 * n as f32 / (k as f32 + 2.0 * n as f32);
            p.apply(
                &mut st,
                &mut w,
                &[o],
                ApplyOptions {
                    gamma,
                    line_search: true,
                },
            );
        }
        let err1 = p.zero_one_error(&w, &idx);
        assert!(err1 < err0, "error {err0} -> {err1}");
        assert!(p.objective(&st, &w) < 0.0, "dual must go below f(0)=0");
        let gap = p.full_gap(&st, &w);
        assert!(gap >= -1e-8);
    }

    #[test]
    fn oracle_block_gap_consistency() {
        // gap_i computed via ssvm_block_gap equals <alpha_i - s_i, grad_i f>
        // evaluated through the identity gap_i = H_i(w) - [lam<w,w_i> - l_i]*...
        // We verify the cheaper identity: for alpha at init (w_i=0, l_i=0),
        // gap_i = l_s - lam <w, w_s> = H_i(y*;w)/n.
        let p = instance();
        let st = p.init_server();
        let mut rng = Pcg64::seeded(6);
        let w: Vec<f32> = rng.gaussian_vec(p.dim());
        for i in 0..10 {
            let o = p.oracle(&w, i);
            let gap = p.block_gap(&st, &w, &o);
            let (_, h) = p.argmax(&w, i, 1.0);
            assert!(
                (gap - h / p.data.n as f64).abs() < 1e-6,
                "gap={gap} h/n={}",
                h / p.data.n as f64
            );
        }
    }
}
