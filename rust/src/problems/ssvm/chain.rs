//! Chain-structured structural SVM (sequence labeling / OCR task).
//!
//! Parameter layout: `w = [wu (K x d, row-major) | trans (K x K, row-major)]`
//! with dimension `D = K*d + K*K`. The block oracle is loss-augmented
//! Viterbi decoding (normalized Hamming loss), served either by the native
//! rust DP below or by the AOT-compiled `ssvm_chain` Pallas artifact via
//! [`ChainDecoder`].

use super::super::{
    ApplyInfo, ApplyOptions, BlockOracle, PayloadKind, Problem,
};
use super::{ssvm_apply, ssvm_block_gap, SsvmState};
use crate::data::ocr_like::ChainDataset;
use std::sync::Arc;

/// Reusable buffers for one loss-augmented Viterbi solve — the chain
/// SSVM's caller-owned [`Problem::Scratch`]. Workers keep one next to
/// their [`BlockOracle`] slot and thread it through
/// [`Problem::oracle_into`] (or explicitly through
/// [`ChainSsvm::viterbi_into`]); buffers are resized on first use and
/// reused afterwards, so the decode hot loop performs no allocation and
/// stays reentrant across differently-shaped instances.
#[derive(Default)]
pub struct ViterbiScratch {
    /// Node scores theta (ell x k).
    theta: Vec<f64>,
    /// Forward max-sum values (k).
    alpha: Vec<f64>,
    /// Next-step values (k), swapped with `alpha` per step.
    next: Vec<f64>,
    /// Backpointers (ell x k).
    ptr: Vec<u16>,
    /// Decoded label sequence (ell) — the solve's output.
    pub ys: Vec<u16>,
    /// Sparse-payload accumulation buffer (dim, all-zero between calls):
    /// the feature-map difference is accumulated here with exactly the
    /// dense emitter's `+=` order, then the touched cells are gathered and
    /// re-zeroed — so the sparse payload densifies bit-identically without
    /// an O(dim) sweep per oracle.
    pay: Vec<f32>,
    /// Indices touched while accumulating `pay` (with duplicates until the
    /// sort+dedup gather).
    touched: Vec<u32>,
}

/// Pluggable loss-augmented decoder (XLA artifact path implements this).
pub trait ChainDecoder: Send + Sync {
    /// Decode sequence i against weights `w`; returns (y*, H_i(y*; w)).
    /// `loss_weight` = 1.0 for training oracle, 0.0 for plain inference.
    fn decode(
        &self,
        w: &[f32],
        i: usize,
        loss_weight: f32,
    ) -> (Vec<u16>, f64);
}

/// Chain SSVM problem over a [`ChainDataset`].
pub struct ChainSsvm {
    pub data: Arc<ChainDataset>,
    /// Regularization lambda.
    pub lam: f64,
    /// Optional external decoder (None = native Viterbi).
    pub decoder: Option<Arc<dyn ChainDecoder>>,
}

impl ChainSsvm {
    pub fn new(data: Arc<ChainDataset>, lam: f64) -> Self {
        Self {
            data,
            lam,
            decoder: None,
        }
    }

    pub fn with_decoder(mut self, d: Arc<dyn ChainDecoder>) -> Self {
        self.decoder = Some(d);
        self
    }

    /// Parameter dimension D = K*d + K*K.
    pub fn dim(&self) -> usize {
        self.data.k * self.data.d + self.data.k * self.data.k
    }

    #[inline]
    fn wu<'a>(&self, w: &'a [f32]) -> &'a [f32] {
        &w[..self.data.k * self.data.d]
    }

    #[inline]
    fn trans<'a>(&self, w: &'a [f32]) -> &'a [f32] {
        &w[self.data.k * self.data.d..]
    }

    /// Native loss-augmented Viterbi: returns (y*, H_i(y*; w)).
    pub fn viterbi(&self, w: &[f32], i: usize, loss_weight: f32) -> (Vec<u16>, f64) {
        let mut sc = ViterbiScratch::default();
        let h = self.viterbi_into(w, i, loss_weight, &mut sc);
        (sc.ys, h)
    }

    /// Allocation-free Viterbi: identical numerics to [`Self::viterbi`],
    /// with all DP state in the caller-owned scratch. Returns H_i(y*; w);
    /// the decode y* is left in `sc.ys`.
    pub fn viterbi_into(
        &self,
        w: &[f32],
        i: usize,
        loss_weight: f32,
        sc: &mut ViterbiScratch,
    ) -> f64 {
        let (k, d, ell) = (self.data.k, self.data.d, self.data.ell);
        let wu = self.wu(w);
        let tr = self.trans(w);
        let ytrue = self.data.label_seq(i);
        // Node scores theta[t][c] = <wu_c, x_t> + lw/L * 1{c != y_t}.
        // Scratch buffers are length-fixed only — every cell that is read
        // below is assigned first, so no zero-fill is needed.
        if sc.theta.len() != ell * k {
            sc.theta.resize(ell * k, 0.0);
        }
        for t in 0..ell {
            let x = self.data.feature(i, t);
            for c in 0..k {
                let mut s = 0.0f64;
                let row = &wu[c * d..(c + 1) * d];
                for r in 0..d {
                    s += row[r] as f64 * x[r] as f64;
                }
                if c != ytrue[t] as usize {
                    s += loss_weight as f64 / ell as f64;
                }
                sc.theta[t * k + c] = s;
            }
        }
        // Forward max-sum with backpointers.
        sc.alpha.clear();
        sc.alpha.extend_from_slice(&sc.theta[..k]);
        if sc.ptr.len() != ell * k {
            sc.ptr.resize(ell * k, 0);
        }
        if sc.next.len() != k {
            sc.next.resize(k, 0.0);
        }
        for t in 1..ell {
            for c in 0..k {
                let mut best = f64::NEG_INFINITY;
                let mut arg = 0u16;
                for j in 0..k {
                    let v = sc.alpha[j] + tr[j * k + c] as f64;
                    if v > best {
                        best = v;
                        arg = j as u16;
                    }
                }
                sc.ptr[t * k + c] = arg;
                sc.next[c] = best + sc.theta[t * k + c];
            }
            std::mem::swap(&mut sc.alpha, &mut sc.next);
        }
        let (mut yc, mut v) = (0usize, f64::NEG_INFINITY);
        for (c, &a) in sc.alpha.iter().enumerate() {
            if a > v {
                v = a;
                yc = c;
            }
        }
        if sc.ys.len() != ell {
            sc.ys.resize(ell, 0);
        }
        sc.ys[ell - 1] = yc as u16;
        for t in (0..ell - 1).rev() {
            sc.ys[t] = sc.ptr[(t + 1) * k + sc.ys[t + 1] as usize];
        }
        // Score of the ground truth (no loss).
        let mut score_true = 0.0f64;
        for t in 0..ell {
            score_true += sc.theta[t * k + ytrue[t] as usize];
            // theta includes no loss at the true label, so this is the raw
            // unary score already.
            if t > 0 {
                score_true +=
                    tr[ytrue[t - 1] as usize * k + ytrue[t] as usize] as f64;
            }
        }
        v - score_true
    }

    /// Build the BCFW payload for decode y*: w_s = psi_i(y*)/(lam n),
    /// l_s = Hamming(y*, y_i)/(L n).
    pub fn payload(&self, i: usize, ystar: &[u16]) -> (Vec<f32>, f64) {
        let mut ws = Vec::new();
        let ls = self.payload_into(i, ystar, &mut ws);
        (ws, ls)
    }

    /// Payload written into a caller-owned buffer; returns l_s.
    pub fn payload_into(
        &self,
        i: usize,
        ystar: &[u16],
        ws: &mut Vec<f32>,
    ) -> f64 {
        let (k, d, ell, n) = (
            self.data.k,
            self.data.d,
            self.data.ell,
            self.data.n,
        );
        let scale = (1.0 / (self.lam * n as f64)) as f32;
        ws.clear();
        ws.resize(self.dim(), 0.0);
        let ytrue = self.data.label_seq(i);
        let mut mistakes = 0usize;
        for t in 0..ell {
            let x = self.data.feature(i, t);
            let yt = ytrue[t] as usize;
            let yst = ystar[t] as usize;
            if yt != yst {
                mistakes += 1;
                // unary: + x at true block, - x at decoded block
                let base_t = yt * d;
                let base_s = yst * d;
                for r in 0..d {
                    ws[base_t + r] += scale * x[r];
                    ws[base_s + r] -= scale * x[r];
                }
            }
            if t > 0 {
                let (pt, ps) =
                    (ytrue[t - 1] as usize, ystar[t - 1] as usize);
                if pt != ps || yt != yst {
                    let off = k * d;
                    ws[off + pt * k + yt] += scale;
                    ws[off + ps * k + yst] -= scale;
                }
            }
        }
        mistakes as f64 / (ell as f64 * n as f64)
    }

    /// Sparse form of [`ChainSsvm::payload_into`]: the support is the
    /// emission features of mistaken positions plus the touched transition
    /// counts. Values are accumulated in `pay` (a caller-owned dim-length
    /// buffer, all-zero between calls — [`ViterbiScratch::pay`] at the
    /// `oracle_into` site) with the dense emitter's exact `+=` order, so
    /// the payload densifies bit-identically (explicit zeros from
    /// cancelling transitions included), gathered in ascending index order
    /// into `(idx, val)`, and the touched cells are re-zeroed for the next
    /// call. Returns l_s.
    pub fn payload_into_sparse(
        &self,
        i: usize,
        ystar: &[u16],
        pay: &mut Vec<f32>,
        touched: &mut Vec<u32>,
        idx: &mut Vec<u32>,
        val: &mut Vec<f32>,
    ) -> f64 {
        let (k, d, ell, n) =
            (self.data.k, self.data.d, self.data.ell, self.data.n);
        let dim = self.dim();
        let scale = (1.0 / (self.lam * n as f64)) as f32;
        if pay.len() != dim {
            pay.clear();
            pay.resize(dim, 0.0);
        }
        touched.clear();
        let ytrue = self.data.label_seq(i);
        let mut mistakes = 0usize;
        for t in 0..ell {
            let x = self.data.feature(i, t);
            let yt = ytrue[t] as usize;
            let yst = ystar[t] as usize;
            if yt != yst {
                mistakes += 1;
                let base_t = yt * d;
                let base_s = yst * d;
                for r in 0..d {
                    pay[base_t + r] += scale * x[r];
                    touched.push((base_t + r) as u32);
                    pay[base_s + r] -= scale * x[r];
                    touched.push((base_s + r) as u32);
                }
            }
            if t > 0 {
                let (pt, ps) = (ytrue[t - 1] as usize, ystar[t - 1] as usize);
                if pt != ps || yt != yst {
                    let off = k * d;
                    pay[off + pt * k + yt] += scale;
                    touched.push((off + pt * k + yt) as u32);
                    pay[off + ps * k + yst] -= scale;
                    touched.push((off + ps * k + yst) as u32);
                }
            }
        }
        touched.sort_unstable();
        touched.dedup();
        idx.clear();
        val.clear();
        for &c in touched.iter() {
            idx.push(c);
            val.push(pay[c as usize]);
            pay[c as usize] = 0.0;
        }
        mistakes as f64 / (ell as f64 * n as f64)
    }

    /// Average Hamming test error of plain (non-loss-augmented) decoding.
    pub fn hamming_error(&self, w: &[f32], indices: &[usize]) -> f64 {
        let mut wrong = 0usize;
        let mut total = 0usize;
        for &i in indices {
            let (ys, _) = self.decode(w, i, 0.0);
            let ytrue = self.data.label_seq(i);
            for t in 0..self.data.ell {
                if ys[t] != ytrue[t] {
                    wrong += 1;
                }
                total += 1;
            }
        }
        wrong as f64 / total.max(1) as f64
    }

    fn decode(&self, w: &[f32], i: usize, lw: f32) -> (Vec<u16>, f64) {
        match &self.decoder {
            Some(d) => d.decode(w, i, lw),
            None => self.viterbi(w, i, lw),
        }
    }

    /// Primal objective P(w) = lam/2 ||w||^2 + (1/n) sum_i H_i(w)
    /// (expensive: decodes every sequence).
    pub fn primal_objective(&self, w: &[f32]) -> f64 {
        let mut hinge = 0.0f64;
        for i in 0..self.data.n {
            let (_, h) = self.decode(w, i, 1.0);
            hinge += h.max(0.0);
        }
        0.5 * self.lam * crate::util::la::norm2_sq(w)
            + hinge / self.data.n as f64
    }
}

impl Problem for ChainSsvm {
    type ServerState = SsvmState;
    type Scratch = ViterbiScratch;

    fn name(&self) -> &'static str {
        "ssvm_chain"
    }

    fn num_blocks(&self) -> usize {
        self.data.n
    }

    fn param_dim(&self) -> usize {
        self.dim()
    }

    fn init_param(&self) -> Vec<f32> {
        vec![0.0; self.dim()]
    }

    fn init_server(&self) -> SsvmState {
        SsvmState::new(self.data.n, self.dim())
    }

    fn checkpoint_server_state(&self, state: &SsvmState) -> Vec<u8> {
        state.encode()
    }

    fn restore_server_state(
        &self,
        state: &mut SsvmState,
        raw: &[u8],
    ) -> anyhow::Result<()> {
        state.decode(raw)
    }

    fn preferred_payload(&self) -> PayloadKind {
        // The feature-map difference touches only the emission features of
        // mistaken positions plus a few transition counts — tiny next to
        // dim = K*d + K*K.
        PayloadKind::Sparse
    }

    fn oracle(&self, param: &[f32], block: usize) -> BlockOracle {
        let (ystar, _h) = self.decode(param, block, 1.0);
        let (ws, ls) = self.payload(block, &ystar);
        BlockOracle::dense(block, ws, ls)
    }

    fn oracle_into(
        &self,
        param: &[f32],
        block: usize,
        sc: &mut ViterbiScratch,
        out: &mut BlockOracle,
    ) {
        // Both paths build the payload into the caller's pooled `out.s`
        // container, in whichever representation it requests: the
        // external-decoder (XLA artifact / fallback) path used to delegate
        // to `oracle` and drop the pooled buffer on every call,
        // re-allocating a dim-D payload each oracle.
        out.block = block;
        match &self.decoder {
            Some(dec) => {
                // External decode lands in `sc.ys` too, so both arms feed
                // one payload-build path below.
                let (ystar, _h) = dec.decode(param, block, 1.0);
                sc.ys.clear();
                sc.ys.extend_from_slice(&ystar);
            }
            None => {
                self.viterbi_into(param, block, 1.0, sc);
            }
        }
        // Split the scratch so the decode output (ys) and the sparse
        // accumulation buffers (pay/touched) borrow disjointly.
        let ViterbiScratch {
            ys, pay, touched, ..
        } = sc;
        match out.s.kind() {
            PayloadKind::Dense => {
                let s = out.s.ensure_dense();
                out.ls = self.payload_into(block, ys, s);
            }
            PayloadKind::Sparse => {
                let (idx, val) = out.s.make_sparse(self.dim());
                out.ls =
                    self.payload_into_sparse(block, ys, pay, touched, idx, val);
            }
        }
    }

    fn block_gap(
        &self,
        state: &SsvmState,
        param: &[f32],
        o: &BlockOracle,
    ) -> f64 {
        ssvm_block_gap(self.lam, state, param, o)
    }

    fn apply(
        &self,
        state: &mut SsvmState,
        param: &mut [f32],
        batch: &[BlockOracle],
        opts: ApplyOptions,
    ) -> ApplyInfo {
        let (gamma, batch_gap) = ssvm_apply(
            self.lam,
            state,
            param,
            batch,
            opts.gamma,
            opts.line_search,
        );
        ApplyInfo { gamma, batch_gap }
    }

    fn aux(&self, state: &SsvmState) -> f64 {
        state.l
    }

    fn objective_from(&self, param: &[f32], aux: f64) -> f64 {
        0.5 * self.lam * crate::util::la::norm2_sq(param) - aux
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ocr_like;
    use crate::util::rng::Pcg64;

    fn instance() -> ChainSsvm {
        let data = Arc::new(ocr_like::generate(30, 4, 8, 5, 0.1, 42));
        ChainSsvm::new(data, 0.1)
    }

    #[test]
    fn viterbi_is_exact_vs_bruteforce() {
        let p = instance();
        let mut rng = Pcg64::seeded(1);
        let w: Vec<f32> = rng.gaussian_vec(p.dim());
        let (k, ell) = (p.data.k, p.data.ell);
        for i in [0usize, 7, 29] {
            let (ys, h) = p.viterbi(&w, i, 1.0);
            // brute force over k^ell labelings
            let ytrue = p.data.label_seq(i);
            let mut best = f64::NEG_INFINITY;
            let mut besty = vec![0u16; ell];
            let total = (k as u64).pow(ell as u32);
            let wu = &w[..k * p.data.d];
            let tr = &w[k * p.data.d..];
            for code in 0..total {
                let mut lab = vec![0u16; ell];
                let mut c = code;
                for t in 0..ell {
                    lab[t] = (c % k as u64) as u16;
                    c /= k as u64;
                }
                let mut v = 0.0f64;
                for t in 0..ell {
                    let x = p.data.feature(i, t);
                    let row = &wu[lab[t] as usize * p.data.d..];
                    for r in 0..p.data.d {
                        v += row[r] as f64 * x[r] as f64;
                    }
                    if lab[t] != ytrue[t] {
                        v += 1.0 / ell as f64;
                    }
                    if t > 0 {
                        v += tr[lab[t - 1] as usize * k + lab[t] as usize]
                            as f64;
                    }
                }
                if v > best {
                    best = v;
                    besty = lab;
                }
            }
            assert_eq!(ys, besty, "sequence {i}");
            // H = best - score(ytrue)
            let mut st = 0.0f64;
            for t in 0..ell {
                let x = p.data.feature(i, t);
                let row = &wu[ytrue[t] as usize * p.data.d..];
                for r in 0..p.data.d {
                    st += row[r] as f64 * x[r] as f64;
                }
                if t > 0 {
                    st += tr[ytrue[t - 1] as usize * k + ytrue[t] as usize]
                        as f64;
                }
            }
            assert!((h - (best - st)).abs() < 1e-9);
        }
    }

    #[test]
    fn oracle_h_nonnegative() {
        let p = instance();
        let mut rng = Pcg64::seeded(2);
        let w: Vec<f32> = rng.gaussian_vec(p.dim());
        for i in 0..p.data.n {
            let (_, h) = p.viterbi(&w, i, 1.0);
            assert!(h >= -1e-9, "H_{i} = {h}");
        }
    }

    #[test]
    fn sparse_payload_densifies_bit_identically() {
        let p = instance();
        let mut rng = Pcg64::seeded(12);
        let w: Vec<f32> = rng.gaussian_vec(p.dim());
        let mut sc = ViterbiScratch::default();
        let mut slot = BlockOracle::empty_with(PayloadKind::Sparse);
        for i in 0..p.data.n {
            p.oracle_into(&w, i, &mut sc, &mut slot);
            slot.s.debug_check_invariants();
            let dense = p.oracle(&w, i);
            assert_eq!(slot.ls.to_bits(), dense.ls.to_bits(), "ls {i}");
            let d = dense.s.as_dense().unwrap();
            let ds = slot.s.to_dense_vec();
            for (j, (a, b)) in ds.iter().zip(d.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "seq {i} elem {j}");
            }
            // The accumulation buffer must be back to all-zero, or the
            // next oracle would leak values.
            assert!(sc.pay.iter().all(|&v| v == 0.0), "pay not re-zeroed");
            // The support is tiny relative to dim (that is the point).
            assert!(slot.s.nnz() <= 2 * p.data.ell * (p.data.d + 1));
        }
    }

    #[test]
    fn payload_zero_when_decode_equals_truth() {
        let p = instance();
        let ytrue: Vec<u16> = p.data.label_seq(3).to_vec();
        let (ws, ls) = p.payload(3, &ytrue);
        assert!(ws.iter().all(|&v| v == 0.0));
        assert_eq!(ls, 0.0);
    }

    #[test]
    fn payload_matches_feature_map_difference() {
        let p = instance();
        let i = 5;
        let mut ystar: Vec<u16> = p.data.label_seq(i).to_vec();
        ystar[2] = (ystar[2] + 1) % p.data.k as u16; // one mistake
        let (ws, ls) = p.payload(i, &ystar);
        assert!((ls - 1.0 / (p.data.ell as f64 * p.data.n as f64)).abs() < 1e-12);
        // <w_s, w> for any w equals (phi(x,y) - phi(x,y*)) . w / (lam n).
        let mut rng = Pcg64::seeded(3);
        let w: Vec<f32> = rng.gaussian_vec(p.dim());
        let dot_ws = crate::util::la::dot(&ws, &w);
        // manual: score(ytrue) - score(ystar) scaled
        let score = |lab: &[u16]| {
            let (k, d) = (p.data.k, p.data.d);
            let mut v = 0.0f64;
            for t in 0..p.data.ell {
                let x = p.data.feature(i, t);
                for r in 0..d {
                    v += w[lab[t] as usize * d + r] as f64 * x[r] as f64;
                }
                if t > 0 {
                    v += w[k * d + lab[t - 1] as usize * k + lab[t] as usize]
                        as f64;
                }
            }
            v
        };
        let expected = (score(p.data.label_seq(i)) - score(&ystar))
            / (p.lam * p.data.n as f64);
        assert!(
            (dot_ws - expected).abs() < 1e-4,
            "{dot_ws} vs {expected}"
        );
    }

    #[test]
    fn bcfw_loop_decreases_dual_and_gap_valid() {
        let p = instance();
        let mut st = p.init_server();
        let mut w = p.init_param();
        let n = p.num_blocks();
        let mut rng = Pcg64::seeded(4);
        let f0 = p.objective(&st, &w);
        assert_eq!(f0, 0.0);
        for k in 0..200 {
            let i = rng.below(n);
            let o = p.oracle(&w, i);
            let gamma = 2.0 * n as f32 / (k as f32 + 2.0 * n as f32);
            p.apply(
                &mut st,
                &mut w,
                &[o],
                ApplyOptions {
                    gamma,
                    line_search: true,
                },
            );
        }
        let f_end = p.objective(&st, &w);
        assert!(f_end < f0, "dual should decrease: {f_end}");
        let gap = p.full_gap(&st, &w);
        assert!(gap >= -1e-6, "gap={gap}");
        // weak duality: primal >= -dual_min => P(w) + f >= 0 at any point
        let primal = p.primal_objective(&w);
        assert!(primal + f_end >= -1e-6);
    }

    #[test]
    fn training_reduces_hamming_error() {
        let data = Arc::new(ocr_like::generate(60, 4, 16, 5, 0.05, 7));
        let p = ChainSsvm::new(data, 0.05);
        let mut st = p.init_server();
        let mut w = p.init_param();
        let n = p.num_blocks();
        let idx: Vec<usize> = (0..n).collect();
        let err0 = p.hamming_error(&w, &idx);
        let mut rng = Pcg64::seeded(8);
        for k in 0..600 {
            let i = rng.below(n);
            let o = p.oracle(&w, i);
            let gamma = 2.0 * n as f32 / (k as f32 + 2.0 * n as f32);
            p.apply(
                &mut st,
                &mut w,
                &[o],
                ApplyOptions {
                    gamma,
                    line_search: true,
                },
            );
        }
        let err1 = p.hamming_error(&w, &idx);
        assert!(
            err1 < err0.min(0.5),
            "training error {err0} -> {err1} should drop"
        );
    }
}
