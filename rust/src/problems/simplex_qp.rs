//! QP over a product of probability simplices with tunable block coupling.
//!
//! f(x) = 1/2 x^T Q x + c^T x  with  Q = b I + mu A A^T  (A random, dense),
//! over M = Delta_m x ... x Delta_m (n blocks). The `mu` knob directly
//! controls the paper's expected-incoherence parameter (Theorem 3), making
//! this the testbed for the curvature studies (Examples 1-3 analogues) and
//! the §D.4 comparison against parallel block-coordinate descent: the block
//! linear oracle (vertex of the simplex) and the block Euclidean projection
//! are both available.

use super::{
    ApplyInfo, ApplyOptions, BlockOracle, OraclePayload, PayloadKind, Problem,
    ProjectableProblem,
};
use crate::util::la;
use crate::util::rng::Pcg64;

/// Caller-owned scratch for the allocation-free QP oracle/gradient path:
/// the coupling vector `z = A^T x` (p-dim) and the block gradient (m-dim).
/// Buffers are sized lazily on first use and reused afterwards; owning it
/// at the call site (rather than in a thread-local) keeps `oracle_into`
/// reentrant and free of resize thrash when differently-shaped instances
/// share a thread.
#[derive(Default)]
pub struct QpScratch {
    /// z = A^T x (p-dim).
    z: Vec<f64>,
    /// Block gradient (m-dim, f64 accumulation).
    g: Vec<f64>,
}

/// Product-of-simplices QP instance.
pub struct SimplexQp {
    /// Number of blocks n.
    pub n: usize,
    /// Block size m (each block is the simplex Delta_m).
    pub m: usize,
    /// Diagonal weight b (>0 for strict convexity on blocks).
    pub b: f64,
    /// Coupling weight mu (>= 0).
    pub mu: f64,
    /// Coupling factor A, (n*m x p) row-major.
    pub a: Vec<f32>,
    /// Rank of the coupling factor.
    pub p: usize,
    /// Linear term c (n*m).
    pub c: Vec<f32>,
}

impl SimplexQp {
    /// Random instance. `mu = 0` gives a fully separable problem.
    pub fn random(n: usize, m: usize, b: f64, mu: f64, p: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 400);
        let dim = n * m;
        let scale = 1.0 / (p as f64).sqrt();
        let a: Vec<f32> =
            (0..dim * p).map(|_| (rng.gaussian() * scale) as f32).collect();
        let c: Vec<f32> = rng.gaussian_vec(dim);
        Self { n, m, b, mu, a, p, c }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.n * self.m
    }

    /// z = A^T x  (p-dim).
    fn at_x(&self, x: &[f32]) -> Vec<f64> {
        let mut z = Vec::new();
        self.at_x_into(x, &mut z);
        z
    }

    /// z = A^T x into a caller-owned buffer (cleared + resized to p).
    fn at_x_into(&self, x: &[f32], z: &mut Vec<f64>) {
        z.clear();
        z.resize(self.p, 0.0);
        for (r, &xr) in x.iter().enumerate() {
            if xr != 0.0 {
                let row = &self.a[r * self.p..(r + 1) * self.p];
                for (zj, &arj) in z.iter_mut().zip(row.iter()) {
                    *zj += xr as f64 * arj as f64;
                }
            }
        }
    }

    /// Full gradient Qx + c (O(dim*p)).
    pub fn gradient(&self, x: &[f32]) -> Vec<f64> {
        let z = self.at_x(x);
        let mut g = vec![0.0f64; self.dim()];
        for r in 0..self.dim() {
            let row = &self.a[r * self.p..(r + 1) * self.p];
            let mut az = 0.0f64;
            for (j, &arj) in row.iter().enumerate() {
                az += arj as f64 * z[j];
            }
            g[r] = self.b * x[r] as f64 + self.mu * az + self.c[r] as f64;
        }
        g
    }

    /// Gradient of one block (O(dim*p) due to the coupling term).
    pub fn block_gradient(&self, x: &[f32], block: usize) -> Vec<f64> {
        let mut z = Vec::new();
        let mut g = Vec::new();
        self.at_x_into(x, &mut z);
        self.block_gradient_given_z(x, block, &z, &mut g);
        g
    }

    /// Block gradient given a precomputed z = A^T x, written into `g`
    /// (cleared + resized to m). Same arithmetic as [`Self::block_gradient`].
    fn block_gradient_given_z(
        &self,
        x: &[f32],
        block: usize,
        z: &[f64],
        g: &mut Vec<f64>,
    ) {
        let lo = block * self.m;
        // Every element is assigned below; only fix the length.
        if g.len() != self.m {
            g.resize(self.m, 0.0);
        }
        for (off, gr) in g.iter_mut().enumerate() {
            let r = lo + off;
            let row = &self.a[r * self.p..(r + 1) * self.p];
            let mut az = 0.0f64;
            for (j, &arj) in row.iter().enumerate() {
                az += arj as f64 * z[j];
            }
            *gr = self.b * x[r] as f64 + self.mu * az + self.c[r] as f64;
        }
    }

    /// f(x) = 1/2 b ||x||^2 + 1/2 mu ||A^T x||^2 + <c, x>.
    pub fn objective_of(&self, x: &[f32]) -> f64 {
        let z = self.at_x(x);
        let zz: f64 = z.iter().map(|v| v * v).sum();
        0.5 * self.b * la::norm2_sq(x)
            + 0.5 * self.mu * zz
            + la::dot(&self.c, x)
    }

    /// Quadratic form d^T Q d for a direction (for exact line search).
    pub fn quad_form(&self, d: &[f32]) -> f64 {
        let z = self.at_x(d);
        let zz: f64 = z.iter().map(|v| v * v).sum();
        self.b * la::norm2_sq(d) + self.mu * zz
    }

    /// Paper Theorem 3 boundedness B_i = sup_{x_i in Delta} x_i^T Q_ii x_i
    /// (attained at a vertex since the form is convex).
    pub fn boundedness(&self, block: usize) -> f64 {
        let lo = block * self.m;
        let mut best = f64::NEG_INFINITY;
        for off in 0..self.m {
            let r = lo + off;
            let row = &self.a[r * self.p..(r + 1) * self.p];
            let aa: f64 =
                row.iter().map(|&v| v as f64 * v as f64).sum();
            best = best.max(self.b + self.mu * aa);
        }
        best
    }

    /// Paper Theorem 3 incoherence mu_ij = sup x_i^T Q_ij x_j over the two
    /// simplices (attained at a vertex pair for a bilinear form).
    pub fn incoherence(&self, bi: usize, bj: usize) -> f64 {
        let (li, lj) = (bi * self.m, bj * self.m);
        let mut best = f64::NEG_INFINITY;
        for oi in 0..self.m {
            let ri = li + oi;
            let rowi = &self.a[ri * self.p..(ri + 1) * self.p];
            for oj in 0..self.m {
                let rj = lj + oj;
                let rowj = &self.a[rj * self.p..(rj + 1) * self.p];
                let mut q = 0.0f64;
                for (ai, aj) in rowi.iter().zip(rowj.iter()) {
                    q += *ai as f64 * *aj as f64;
                }
                best = best.max(self.mu * q);
            }
        }
        best
    }
}

impl Problem for SimplexQp {
    type ServerState = ();
    type Scratch = QpScratch;

    fn name(&self) -> &'static str {
        "simplex_qp"
    }

    fn num_blocks(&self) -> usize {
        self.n
    }

    fn param_dim(&self) -> usize {
        self.dim()
    }

    fn init_param(&self) -> Vec<f32> {
        // Uniform distribution in every block.
        vec![1.0 / self.m as f32; self.dim()]
    }

    fn init_server(&self) -> Self::ServerState {}

    fn preferred_payload(&self) -> PayloadKind {
        // The simplex oracle is a 1-hot vertex: one (idx, val) pair versus
        // an m-length dense vector.
        PayloadKind::Sparse
    }

    fn oracle(&self, param: &[f32], block: usize) -> BlockOracle {
        // Single implementation of the oracle arithmetic: delegate to the
        // scratch form (bit-identity between the two by construction).
        let mut sc = QpScratch::default();
        let mut out = BlockOracle::empty();
        self.oracle_into(param, block, &mut sc, &mut out);
        out
    }

    fn oracle_into(
        &self,
        param: &[f32],
        block: usize,
        sc: &mut QpScratch,
        out: &mut BlockOracle,
    ) {
        self.at_x_into(param, &mut sc.z);
        self.block_gradient_given_z(param, block, &sc.z, &mut sc.g);
        let mut arg = 0usize;
        let mut best = f64::INFINITY;
        for (j, &gj) in sc.g.iter().enumerate() {
            if gj < best {
                best = gj;
                arg = j;
            }
        }
        out.block = block;
        out.ls = 0.0;
        // Emit the representation the caller's container requests (the
        // densified sparse form is bit-identical to the dense emission: a
        // single 1.0 over implicit zeros).
        match out.s.kind() {
            PayloadKind::Dense => {
                // make_dense clears, so the resize zero-fills every slot.
                let s = out.s.make_dense();
                s.resize(self.m, 0.0);
                s[arg] = 1.0;
            }
            PayloadKind::Sparse => {
                let (idx, val) = out.s.make_sparse(self.m);
                idx.push(arg as u32);
                val.push(1.0);
            }
        }
    }

    fn block_gap(
        &self,
        _state: &Self::ServerState,
        param: &[f32],
        o: &BlockOracle,
    ) -> f64 {
        let g = self.block_gradient(param, o.block);
        let lo = o.block * self.m;
        debug_assert_eq!(o.s.dim(), self.m);
        let mut gap = 0.0f64;
        // The sparse arm's implicit zeros yield the same f64 terms as the
        // dense payload's stored zeros (x - 0.0 == x), so both
        // representations accumulate identical bits; the dense arm keeps
        // the plain indexed loop.
        match &o.s {
            OraclePayload::Dense(s) => {
                for j in 0..self.m {
                    gap += (param[lo + j] as f64 - s[j] as f64) * g[j];
                }
            }
            OraclePayload::Sparse { .. } => {
                for (j, sj) in o.s.dense_iter().enumerate() {
                    gap += (param[lo + j] as f64 - sj as f64) * g[j];
                }
            }
        }
        gap
    }

    fn apply(
        &self,
        _state: &mut Self::ServerState,
        param: &mut [f32],
        batch: &[BlockOracle],
        opts: ApplyOptions,
    ) -> ApplyInfo {
        // One coupling pass z = A^T x shared by every block gap in the
        // batch (each `block_gap` call would recompute it from scratch:
        // O(tau * dim * p) -> O(dim * p + tau * m * p)). The z bits are a
        // deterministic function of `param`, so sharing is bit-identical
        // to the per-oracle recompute.
        let mut z: Vec<f64> = Vec::new();
        let mut g: Vec<f64> = Vec::new();
        self.at_x_into(param, &mut z);
        let mut batch_gap = 0.0f64;
        for o in batch {
            self.block_gradient_given_z(param, o.block, &z, &mut g);
            let lo = o.block * self.m;
            debug_assert_eq!(o.s.dim(), self.m);
            // Same dense/sparse split — and the same per-block grouping —
            // as summing `block_gap`, so the reported gap is bit-identical
            // to the per-oracle path it replaces.
            let mut gap_o = 0.0f64;
            match &o.s {
                OraclePayload::Dense(s) => {
                    for (j, gj) in g.iter().enumerate() {
                        gap_o += (param[lo + j] as f64 - s[j] as f64) * gj;
                    }
                }
                OraclePayload::Sparse { .. } => {
                    for (j, sj) in o.s.dense_iter().enumerate() {
                        gap_o += (param[lo + j] as f64 - sj as f64) * g[j];
                    }
                }
            }
            batch_gap += gap_o;
        }
        let gamma = if opts.line_search {
            // Curvature d^T Q d = b ||d||^2 + mu ||A^T d||^2 for the
            // direction d = s - x, which is supported on the batch blocks
            // only: accumulate zd = A^T d over those support rows instead
            // of materializing a dim-length dense direction and scanning
            // all of A (the ROADMAP "support rows only" item from the
            // sparse-payload PR). Dense and sparse payloads walk the same
            // rows in the same order, so the step stays bit-identical
            // across representations.
            let mut zd = vec![0.0f64; self.p];
            let mut norm_sq = 0.0f64;
            for o in batch {
                let lo = o.block * self.m;
                let mut support_row = |j: usize, sj: f32| {
                    let d = sj - param[lo + j];
                    if d != 0.0 {
                        norm_sq += d as f64 * d as f64;
                        let r = lo + j;
                        let row = &self.a[r * self.p..(r + 1) * self.p];
                        for (zj, &arj) in zd.iter_mut().zip(row.iter()) {
                            *zj += d as f64 * arj as f64;
                        }
                    }
                };
                match &o.s {
                    OraclePayload::Dense(s) => {
                        for (j, &sj) in s.iter().enumerate() {
                            support_row(j, sj);
                        }
                    }
                    OraclePayload::Sparse { .. } => {
                        for (j, sj) in o.s.dense_iter().enumerate() {
                            support_row(j, sj);
                        }
                    }
                }
            }
            let zz: f64 = zd.iter().map(|v| v * v).sum();
            let quad = self.b * norm_sq + self.mu * zz;
            if quad <= 0.0 {
                1.0
            } else {
                (batch_gap / quad).clamp(0.0, 1.0) as f32
            }
        } else {
            opts.gamma
        };
        for o in batch {
            let lo = o.block * self.m;
            debug_assert_eq!(o.s.dim(), self.m);
            let blk = &mut param[lo..lo + self.m];
            match &o.s {
                OraclePayload::Dense(s) => la::lerp_into(gamma, s, blk),
                OraclePayload::Sparse { idx, val, .. } => {
                    la::lerp_into_sparse(gamma, idx, val, blk)
                }
            }
        }
        ApplyInfo { gamma, batch_gap }
    }

    fn objective_from(&self, param: &[f32], _aux: f64) -> f64 {
        self.objective_of(param)
    }

    fn touched_ranges(
        &self,
        batch: &[BlockOracle],
    ) -> Option<Vec<std::ops::Range<usize>>> {
        Some(
            batch
                .iter()
                .map(|o| o.block * self.m..(o.block + 1) * self.m)
                .collect(),
        )
    }
}

impl ProjectableProblem for SimplexQp {
    fn block_range(&self, block: usize) -> std::ops::Range<usize> {
        block * self.m..(block + 1) * self.m
    }

    fn block_grad(&self, param: &[f32], block: usize) -> Vec<f32> {
        self.block_gradient(param, block)
            .into_iter()
            .map(|v| v as f32)
            .collect()
    }

    fn block_grad_into(
        &self,
        param: &[f32],
        block: usize,
        sc: &mut QpScratch,
        out: &mut Vec<f32>,
    ) {
        self.at_x_into(param, &mut sc.z);
        self.block_gradient_given_z(param, block, &sc.z, &mut sc.g);
        out.clear();
        out.extend(sc.g.iter().map(|&v| v as f32));
    }

    fn project_block(&self, _block: usize, x: &mut [f32]) {
        la::project_simplex(x);
    }

    fn block_lipschitz(&self, block: usize) -> f64 {
        // ||Q_ii||_2 <= b + mu ||A_i||_2^2 <= b + mu ||A_i||_F^2.
        let lo = block * self.m;
        let mut frob = 0.0f64;
        for r in lo..lo + self.m {
            for &v in &self.a[r * self.p..(r + 1) * self.p] {
                frob += v as f64 * v as f64;
            }
        }
        self.b + self.mu * frob
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn instance(mu: f64) -> SimplexQp {
        SimplexQp::random(8, 5, 1.0, mu, 4, 11)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let qp = instance(0.5);
        let x = qp.init_param();
        let g = qp.gradient(&x);
        let eps = 1e-3f32;
        for r in [0usize, 7, 20, 39] {
            let mut xp = x.clone();
            xp[r] += eps;
            let mut xm = x.clone();
            xm[r] -= eps;
            let fd = (qp.objective_of(&xp) - qp.objective_of(&xm))
                / (2.0 * eps as f64);
            assert!((fd - g[r]).abs() < 1e-3, "r={r}: {fd} vs {}", g[r]);
        }
    }

    #[test]
    fn oracle_picks_min_gradient_vertex() {
        let qp = instance(1.0);
        let x = qp.init_param();
        for i in 0..qp.n {
            let o = qp.oracle(&x, i);
            let g = qp.block_gradient(&x, i);
            let s = o.s.as_dense().expect("oracle() returns dense");
            let picked = s.iter().position(|&v| v == 1.0).unwrap();
            let min = g.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!((g[picked] - min).abs() < 1e-12);
            assert_eq!(s.iter().filter(|&&v| v != 0.0).count(), 1);
        }
    }

    #[test]
    fn sparse_oracle_is_one_hot_and_densifies_identically() {
        let qp = instance(0.6);
        let x = qp.init_param();
        let mut sc = QpScratch::default();
        let mut slot = BlockOracle::empty_with(PayloadKind::Sparse);
        for i in 0..qp.n {
            qp.oracle_into(&x, i, &mut sc, &mut slot);
            assert_eq!(slot.s.nnz(), 1, "1-hot vertex");
            assert_eq!(slot.s.dim(), qp.m);
            slot.s.debug_check_invariants();
            let dense = qp.oracle(&x, i);
            assert_eq!(
                slot.s.to_dense_vec(),
                dense.s.as_dense().unwrap(),
                "block {i}"
            );
        }
    }

    #[test]
    fn feasibility_and_descent_under_fw() {
        let qp = instance(0.7);
        let mut x = qp.init_param();
        let mut rng = Pcg64::seeded(3);
        let n = qp.n;
        let mut f_prev = qp.objective_of(&x);
        for k in 0..150 {
            let i = rng.below(n);
            let o = qp.oracle(&x, i);
            qp.apply(
                &mut (),
                &mut x,
                &[o],
                ApplyOptions {
                    gamma: 2.0 * n as f32 / (k as f32 + 2.0 * n as f32),
                    line_search: true,
                },
            );
            // feasibility
            for b in 0..n {
                let blk = &x[b * qp.m..(b + 1) * qp.m];
                let sum: f64 = blk.iter().map(|&v| v as f64).sum();
                assert!((sum - 1.0).abs() < 1e-4);
                assert!(blk.iter().all(|&v| v >= -1e-6));
            }
        }
        let f_end = qp.objective_of(&x);
        assert!(f_end < f_prev, "{f_prev} -> {f_end}");
        f_prev = f_end;
        let _ = f_prev;
        assert!(qp.full_gap(&(), &x) >= -1e-9);
    }

    #[test]
    fn separable_case_has_zero_incoherence() {
        let qp = instance(0.0);
        for i in 0..3 {
            for j in 0..3 {
                if i != j {
                    assert_eq!(qp.incoherence(i, j), 0.0);
                }
            }
        }
        assert!(qp.boundedness(0) >= qp.b);
    }

    #[test]
    fn incoherence_scales_with_mu() {
        let q1 = instance(0.5);
        let q2 = instance(1.0); // same seed -> same A
        let r1 = q1.incoherence(0, 1);
        let r2 = q2.incoherence(0, 1);
        assert!((r2 - 2.0 * r1).abs() < 1e-9, "{r1} {r2}");
    }

    #[test]
    fn support_row_line_search_matches_dense_direction_reference() {
        // The apply's curvature pass accumulates A^T d over the batch's
        // support rows only; it must agree with the materialized-direction
        // reference (`quad_form`) it replaced, and the fused batch gap
        // must stay bit-identical to summing `block_gap`.
        let qp = instance(0.9);
        let mut x = qp.init_param();
        let mut rng = Pcg64::seeded(7);
        for _ in 0..10 {
            let i = rng.below(qp.n);
            let o = qp.oracle(&x, i);
            qp.apply(
                &mut (),
                &mut x,
                &[o],
                ApplyOptions {
                    gamma: 0.3,
                    line_search: false,
                },
            );
        }
        // Mixed-representation 3-block batch.
        let mut batch =
            vec![qp.oracle(&x, 0), qp.oracle(&x, 3), qp.oracle(&x, 5)];
        let mut sc = QpScratch::default();
        let mut sparse = BlockOracle::empty_with(PayloadKind::Sparse);
        qp.oracle_into(&x, 3, &mut sc, &mut sparse);
        batch[1] = sparse;

        let mut gap_ref = 0.0f64;
        for o in &batch {
            gap_ref += qp.block_gap(&(), &x, o);
        }
        let mut dir = vec![0.0f32; qp.dim()];
        for o in &batch {
            let lo = o.block * qp.m;
            for (j, sj) in o.s.dense_iter().enumerate() {
                dir[lo + j] = sj - x[lo + j];
            }
        }
        let quad_ref = qp.quad_form(&dir);
        let gamma_ref = (gap_ref / quad_ref).clamp(0.0, 1.0) as f32;

        let mut x2 = x.clone();
        let info = qp.apply(
            &mut (),
            &mut x2,
            &batch,
            ApplyOptions {
                gamma: 0.0,
                line_search: true,
            },
        );
        assert_eq!(info.batch_gap, gap_ref, "fused gap must be bit-identical");
        let tol = 1e-5f32 * gamma_ref.abs().max(1e-3);
        assert!(
            (info.gamma - gamma_ref).abs() <= tol,
            "gamma {} vs reference {gamma_ref}",
            info.gamma
        );
    }

    #[test]
    fn block_lipschitz_upper_bounds_hessian_action() {
        let qp = instance(0.8);
        let li = qp.block_lipschitz(2);
        // For any unit block direction d: d^T Q_ii d <= L_i.
        let mut rng = Pcg64::seeded(4);
        for _ in 0..20 {
            let mut d = vec![0.0f32; qp.dim()];
            let blk = rng.gaussian_vec(qp.m);
            let nrm = la::norm2(&blk);
            for (j, v) in blk.iter().enumerate() {
                d[2 * qp.m + j] = (v / nrm as f32) as f32;
            }
            assert!(qp.quad_form(&d) <= li + 1e-6);
        }
    }
}
