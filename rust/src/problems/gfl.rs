//! Group Fused Lasso dual (paper Example 2, Eq. 10).
//!
//! Variables `U in R^{d x m}` (m = n-1 blocks, one per change point), block
//! constraint `||U[:, t]||_2 <= lambda`. Objective
//!
//!   f(U) = 1/2 ||U D^T||_F^2 - <U, B>,   B = Y D,
//!
//! gradient the tridiagonal stencil `G[:,t] = -u_{t-1} + 2u_t - u_{t+1} - b_t`,
//! linear oracle `s_t = -lambda g_t / ||g_t||`. The parameter vector IS the
//! flattened U (column-major), so workers can evaluate the stencil locally
//! from three columns of the shared parameter.
//!
//! The oracle can be served either natively (default) or by the AOT-compiled
//! `gfl_step` XLA artifact through [`GflOracleBackend`] — the two are
//! cross-validated in integration tests.

use super::{
    ApplyInfo, ApplyOptions, BlockOracle, OraclePayload, Problem,
    ProjectableProblem,
};
use crate::util::la;
use std::sync::Arc;

/// Pluggable full-step evaluator (the XLA artifact path implements this).
pub trait GflOracleBackend: Send + Sync {
    /// Given flattened U, return (G, S, gap, f) exactly as the
    /// `gfl_step` artifact does.
    fn step(&self, u: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>, f64);
}

/// Group Fused Lasso dual problem instance.
pub struct Gfl {
    /// Feature dimension d.
    pub d: usize,
    /// Number of blocks m = n - 1.
    pub m: usize,
    /// Ball radius lambda.
    pub lam: f64,
    /// B = Y D, flattened column-major (d x m).
    pub b: Vec<f32>,
    /// Observations Y (d x n), kept for primal recovery.
    pub y: Vec<f32>,
    /// Optional XLA backend for the oracle (None = native).
    pub backend: Option<Arc<dyn GflOracleBackend>>,
}

impl Gfl {
    /// Build from observations `y` (d x n column-major).
    pub fn new(d: usize, n: usize, lam: f64, y: Vec<f32>) -> Self {
        assert!(n >= 2, "need at least 2 time points");
        assert_eq!(y.len(), d * n);
        let m = n - 1;
        let mut b = vec![0.0f32; d * m];
        for t in 0..m {
            for r in 0..d {
                b[t * d + r] = y[(t + 1) * d + r] - y[t * d + r];
            }
        }
        Self {
            d,
            m,
            lam,
            b,
            y,
            backend: None,
        }
    }

    pub fn with_backend(mut self, be: Arc<dyn GflOracleBackend>) -> Self {
        self.backend = Some(be);
        self
    }

    #[inline]
    fn col<'a>(&self, u: &'a [f32], t: usize) -> &'a [f32] {
        &u[t * self.d..(t + 1) * self.d]
    }

    /// Gradient column t at `u` (the tridiagonal stencil).
    pub fn grad_col(&self, u: &[f32], t: usize) -> Vec<f32> {
        let mut g = vec![0.0f32; self.d];
        self.grad_col_into(u, t, &mut g);
        g
    }

    /// Gradient column t written into a caller-owned buffer of length `d`
    /// (the allocation-free form used by [`Problem::oracle_into`]).
    pub fn grad_col_into(&self, u: &[f32], t: usize, g: &mut [f32]) {
        let d = self.d;
        debug_assert_eq!(g.len(), d);
        let ut = self.col(u, t);
        let bt = &self.b[t * d..(t + 1) * d];
        for r in 0..d {
            g[r] = 2.0 * ut[r] - bt[r];
        }
        if t > 0 {
            let up = self.col(u, t - 1);
            for r in 0..d {
                g[r] -= up[r];
            }
        }
        if t + 1 < self.m {
            let un = self.col(u, t + 1);
            for r in 0..d {
                g[r] -= un[r];
            }
        }
    }

    /// Objective f(U) = 1/2 <U, U D^T D> - <U, B> (O(dm)).
    pub fn objective_of(&self, u: &[f32]) -> f64 {
        let mut ug = 0.0f64;
        let mut ub = 0.0f64;
        for t in 0..self.m {
            let g = self.grad_col(u, t);
            let ut = self.col(u, t);
            let bt = &self.b[t * self.d..(t + 1) * self.d];
            // grad = (U D^T D)_t - b_t, so <u_t, (UD^TD)_t> = <u_t, g_t + b_t>.
            ug += la::dot(ut, &g) + la::dot(ut, bt);
            ub += la::dot(ut, bt);
        }
        0.5 * ug - ub
    }

    /// Primal recovery X = Y - U D^T (d x n, column-major).
    pub fn primal_signal(&self, u: &[f32]) -> Vec<f32> {
        let d = self.d;
        let n = self.m + 1;
        let mut x = self.y.clone();
        for j in 0..n {
            for r in 0..d {
                let mut udt = 0.0f32;
                if j >= 1 {
                    udt += u[(j - 1) * d + r];
                }
                if j < self.m {
                    udt -= u[j * d + r];
                }
                x[j * d + r] -= udt;
            }
        }
        x
    }

    /// Primal objective 1/2||X - Y||^2 + lam * sum_t ||x_{t+1} - x_t||.
    pub fn primal_objective(&self, u: &[f32]) -> f64 {
        let d = self.d;
        let n = self.m + 1;
        let x = self.primal_signal(u);
        let mut quad = 0.0f64;
        for j in 0..d * n {
            let r = (x[j] - self.y[j]) as f64;
            quad += r * r;
        }
        let mut tv = 0.0f64;
        for t in 0..n - 1 {
            let mut s = 0.0f64;
            for r in 0..d {
                let diff = (x[(t + 1) * d + r] - x[t * d + r]) as f64;
                s += diff * diff;
            }
            tv += s.sqrt();
        }
        0.5 * quad + self.lam * tv
    }
}

impl Problem for Gfl {
    type ServerState = ();
    // The oracle writes the gradient column straight into the payload
    // buffer, so there is no intermediate state to own.
    type Scratch = ();

    fn name(&self) -> &'static str {
        "gfl"
    }

    fn num_blocks(&self) -> usize {
        self.m
    }

    fn param_dim(&self) -> usize {
        self.d * self.m
    }

    fn init_param(&self) -> Vec<f32> {
        vec![0.0; self.d * self.m]
    }

    fn init_server(&self) -> Self::ServerState {}

    fn oracle(&self, param: &[f32], block: usize) -> BlockOracle {
        if let Some(be) = &self.backend {
            // Artifact path: full-step evaluation, slice the block column.
            let (_g, s, _gap, _f) = be.step(param);
            let d = self.d;
            return BlockOracle::dense(
                block,
                s[block * d..(block + 1) * d].to_vec(),
                0.0,
            );
        }
        // Native path: delegate to `oracle_into` so there is exactly ONE
        // implementation of the oracle arithmetic (bit-identity by
        // construction). No recursion: `oracle_into` only calls back into
        // `oracle` on the backend path, which returned above.
        let mut out = BlockOracle::empty();
        self.oracle_into(param, block, &mut (), &mut out);
        out
    }

    fn oracle_into(
        &self,
        param: &[f32],
        block: usize,
        _scratch: &mut (),
        out: &mut BlockOracle,
    ) {
        if self.backend.is_some() {
            // Artifact path keeps its own buffers; fall back.
            *out = self.oracle(param, block);
            return;
        }
        // Compute the gradient directly into the payload buffer, then
        // rescale in place — same operation order as `oracle`, so the
        // result is bit-identical (property-tested). No zero-fill:
        // `grad_col_into` assigns every element. GFL's oracle is a dense
        // ball-boundary column, so a sparse container request is
        // overridden (the documented dense fallback of the payload
        // representation contract).
        out.block = block;
        out.ls = 0.0;
        let s = out.s.ensure_dense();
        if s.len() != self.d {
            s.resize(self.d, 0.0);
        }
        self.grad_col_into(param, block, s);
        let nrm = la::norm2(s);
        if nrm > 0.0 {
            la::scale((-self.lam / nrm) as f32, s);
        } else {
            s.iter_mut().for_each(|v| *v = 0.0);
        }
    }

    fn block_gap(
        &self,
        _state: &Self::ServerState,
        param: &[f32],
        o: &BlockOracle,
    ) -> f64 {
        let g = self.grad_col(param, o.block);
        let ut = self.col(param, o.block);
        let s_dot_g = match &o.s {
            OraclePayload::Dense(s) => la::dot(s, &g),
            // Never produced by this problem; accepted for the consumer
            // contract (hand-built batches).
            OraclePayload::Sparse { idx, val, .. } => {
                la::dot_sparse(idx, val, &g)
            }
        };
        la::dot(ut, &g) - s_dot_g
    }

    fn apply(
        &self,
        _state: &mut Self::ServerState,
        param: &mut [f32],
        batch: &[BlockOracle],
        opts: ApplyOptions,
    ) -> ApplyInfo {
        let d = self.d;
        // Gap of the batch at the current parameter (also the negative
        // directional derivative, used by line search).
        let mut batch_gap = 0.0f64;
        for o in batch {
            batch_gap += self.block_gap(&(), param, o);
        }
        let gamma = if opts.line_search {
            // f(U + gamma Delta) quadratic in gamma:
            //   gamma* = batch_gap / <Delta, Delta (D^T D)>.
            // Delta is supported on the batch columns.
            let mut delta = std::collections::HashMap::new();
            for o in batch {
                let ut = self.col(param, o.block);
                let dcol: Vec<f32> = match &o.s {
                    OraclePayload::Dense(s) => {
                        s.iter().zip(ut.iter()).map(|(s, u)| s - u).collect()
                    }
                    OraclePayload::Sparse { .. } => o
                        .s
                        .dense_iter()
                        .zip(ut.iter())
                        .map(|(s, u)| s - u)
                        .collect(),
                };
                delta.insert(o.block, dcol);
            }
            let zeros = vec![0.0f32; d];
            let mut quad = 0.0f64;
            for (&t, dc) in &delta {
                // (Delta D^T D)_t = 2 dc_t - dc_{t-1} - dc_{t+1}
                let prev = if t > 0 {
                    delta.get(&(t - 1)).map(|v| v.as_slice()).unwrap_or(&zeros)
                } else {
                    &zeros
                };
                let next = delta
                    .get(&(t + 1))
                    .map(|v| v.as_slice())
                    .unwrap_or(&zeros);
                for r in 0..d {
                    quad += dc[r] as f64
                        * (2.0 * dc[r] as f64
                            - prev[r] as f64
                            - next[r] as f64);
                }
            }
            if quad <= 0.0 {
                1.0
            } else {
                (batch_gap / quad).clamp(0.0, 1.0) as f32
            }
        } else {
            opts.gamma
        };
        for o in batch {
            debug_assert_eq!(o.s.dim(), d);
            let col = &mut param[o.block * d..(o.block + 1) * d];
            match &o.s {
                OraclePayload::Dense(s) => la::lerp_into(gamma, s, col),
                OraclePayload::Sparse { idx, val, .. } => {
                    la::lerp_into_sparse(gamma, idx, val, col)
                }
            }
        }
        ApplyInfo { gamma, batch_gap }
    }

    fn objective_from(&self, param: &[f32], _aux: f64) -> f64 {
        self.objective_of(param)
    }

    fn touched_ranges(
        &self,
        batch: &[BlockOracle],
    ) -> Option<Vec<std::ops::Range<usize>>> {
        Some(
            batch
                .iter()
                .map(|o| o.block * self.d..(o.block + 1) * self.d)
                .collect(),
        )
    }
}

impl ProjectableProblem for Gfl {
    fn block_range(&self, block: usize) -> std::ops::Range<usize> {
        block * self.d..(block + 1) * self.d
    }

    fn block_grad(&self, param: &[f32], block: usize) -> Vec<f32> {
        self.grad_col(param, block)
    }

    fn block_grad_into(
        &self,
        param: &[f32],
        block: usize,
        _scratch: &mut (),
        out: &mut Vec<f32>,
    ) {
        if out.len() != self.d {
            out.resize(self.d, 0.0);
        }
        self.grad_col_into(param, block, out);
    }

    fn project_block(&self, _block: usize, x: &mut [f32]) {
        la::project_l2_ball(self.lam, x);
    }

    fn block_lipschitz(&self, _block: usize) -> f64 {
        // Diagonal block of D^T D is 2 I.
        2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn instance(seed: u64) -> (Gfl, Vec<f32>) {
        let (d, n, lam) = (4, 20, 0.3);
        let mut rng = Pcg64::seeded(seed);
        let y = rng.gaussian_vec(d * n);
        let gfl = Gfl::new(d, n, lam, y);
        // random feasible U
        let mut u = rng.gaussian_vec(d * (n - 1));
        for t in 0..n - 1 {
            la::project_l2_ball(lam, &mut u[t * d..(t + 1) * d]);
        }
        (gfl, u)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (gfl, u) = instance(1);
        let mut rng = Pcg64::seeded(2);
        let eps = 1e-3;
        for _ in 0..5 {
            let t = rng.below(gfl.m);
            let g = gfl.grad_col(&u, t);
            let r = rng.below(gfl.d);
            let mut up = u.clone();
            up[t * gfl.d + r] += eps;
            let mut um = u.clone();
            um[t * gfl.d + r] -= eps;
            let fd = (gfl.objective_of(&up) - gfl.objective_of(&um))
                / (2.0 * eps as f64);
            assert!(
                (fd - g[r] as f64).abs() < 1e-2,
                "fd={fd} g={} (t={t},r={r})",
                g[r]
            );
        }
    }

    #[test]
    fn oracle_is_ball_boundary_minimizer() {
        let (gfl, u) = instance(3);
        let mut rng = Pcg64::seeded(4);
        for t in [0usize, 5, gfl.m - 1] {
            let o = gfl.oracle(&u, t);
            let g = gfl.grad_col(&u, t);
            let s = o.s.as_dense().expect("gfl oracle is dense");
            let val = la::dot(s, &g);
            assert!((la::norm2(s) - gfl.lam).abs() < 1e-5);
            for _ in 0..30 {
                let mut v = rng.gaussian_vec(gfl.d);
                la::project_l2_ball(gfl.lam, &mut v);
                assert!(val <= la::dot(&v, &g) + 1e-6);
            }
        }
    }

    #[test]
    fn gap_nonnegative_and_zero_only_near_opt() {
        let (gfl, u) = instance(5);
        for t in 0..gfl.m {
            let o = gfl.oracle(&u, t);
            assert!(gfl.block_gap(&(), &u, &o) >= -1e-8);
        }
    }

    #[test]
    fn apply_fixed_step_decreases_objective_for_small_gamma() {
        let (gfl, u) = instance(6);
        let mut param = u.clone();
        let batch: Vec<BlockOracle> =
            (0..4).map(|t| gfl.oracle(&param, t * 3)).collect();
        let f0 = gfl.objective_of(&param);
        gfl.apply(
            &mut (),
            &mut param,
            &batch,
            ApplyOptions {
                gamma: 0.05,
                line_search: false,
            },
        );
        assert!(gfl.objective_of(&param) < f0);
    }

    #[test]
    fn line_search_beats_fixed_step() {
        let (gfl, u) = instance(7);
        let batch: Vec<BlockOracle> =
            (0..5).map(|t| gfl.oracle(&u, t)).collect();
        let mut p_ls = u.clone();
        let info = gfl.apply(
            &mut (),
            &mut p_ls,
            &batch,
            ApplyOptions {
                gamma: 0.0,
                line_search: true,
            },
        );
        assert!(info.gamma > 0.0 && info.gamma <= 1.0);
        let f_ls = gfl.objective_of(&p_ls);
        for gamma in [0.01f32, 0.1, 0.5, 1.0] {
            let mut p = u.clone();
            gfl.apply(
                &mut (),
                &mut p,
                &batch,
                ApplyOptions {
                    gamma,
                    line_search: false,
                },
            );
            assert!(f_ls <= gfl.objective_of(&p) + 1e-6, "gamma={gamma}");
        }
    }

    #[test]
    fn feasibility_preserved_by_apply() {
        let (gfl, u) = instance(8);
        let mut param = u;
        for k in 0..50 {
            let t = k % gfl.m;
            let o = gfl.oracle(&param, t);
            gfl.apply(
                &mut (),
                &mut param,
                &[o],
                ApplyOptions {
                    gamma: 0.3,
                    line_search: false,
                },
            );
        }
        for t in 0..gfl.m {
            let nrm = la::norm2(&param[t * gfl.d..(t + 1) * gfl.d]);
            assert!(nrm <= gfl.lam + 1e-5, "block {t} norm {nrm}");
        }
    }

    #[test]
    fn primal_dual_consistency_at_zero() {
        let (gfl, _) = instance(9);
        let u0 = gfl.init_param();
        let x = gfl.primal_signal(&u0);
        assert_eq!(x, gfl.y);
        assert_eq!(gfl.objective_of(&u0), 0.0);
    }

    #[test]
    fn full_gap_bounds_suboptimality() {
        // g(x) >= f(x) - f(x*): run BCFW-ish loop; check invariant en route.
        let (gfl, _) = instance(10);
        let mut param = gfl.init_param();
        let n = gfl.m;
        let mut rng = Pcg64::seeded(11);
        let mut last_f = f64::INFINITY;
        for k in 0..300 {
            let t = rng.below(n);
            let o = gfl.oracle(&param, t);
            let gamma = 2.0 * n as f32 / (k as f32 + 2.0 * n as f32);
            gfl.apply(
                &mut (),
                &mut param,
                &[o],
                ApplyOptions {
                    gamma,
                    line_search: false,
                },
            );
            last_f = gfl.objective_of(&param);
        }
        let gap = gfl.full_gap(&(), &param);
        assert!(gap >= 0.0);
        // crude f* lower bound from the gap: f* >= f - gap
        assert!(last_f - gap <= last_f);
    }
}
