//! Problem abstraction for block-separable Frank-Wolfe (paper Eq. 2).
//!
//! A [`Problem`] is `min_x f(x)` over `M = M_1 x ... x M_n`. The split
//! between *parameter* and *server state* mirrors the paper's system model:
//!
//! - the **parameter** is the small dense vector broadcast to workers (for
//!   Group Fused Lasso it is the dual matrix `U` itself; for structural SVM
//!   it is the primal `w = A alpha`, not the exponentially large `alpha`);
//! - the **server state** is per-block bookkeeping only the server needs to
//!   apply updates (e.g. BCFW's per-datapoint `w_i`, `l_i`).
//!
//! Workers call [`Problem::oracle`] on a (possibly stale) parameter
//! snapshot; the server calls [`Problem::apply`] with a batch of oracles for
//! *disjoint* blocks, the paper's Algorithm 1 step 3.
//!
//! # Oracle scratch ownership
//!
//! Every problem names an explicit [`Problem::Scratch`] type — the working
//! memory its oracle needs beyond the output payload (Viterbi DP tables for
//! the chain SSVM, the `A^T x` coupling buffers for the simplex QP, nothing
//! for GFL/multiclass). The CALLER owns the scratch: a worker constructs one
//! `Scratch::default()` next to its [`BlockOracle`] slot and threads both
//! through every [`Problem::oracle_into`] call. This replaces the historical
//! hidden `thread_local!` `RefCell` scratch, which was non-reentrant and
//! resize-thrashed whenever two differently-shaped instances of the same
//! problem type shared a thread.
//! Because `Scratch: Send`, the scratch moves with its worker — batched
//! workers solving several blocks per snapshot reuse one scratch across the
//! whole batch with zero allocation (see `rust/tests/hot_path_equivalence.rs`
//! for the reentrancy property tests).

pub mod gfl;
pub mod simplex_qp;
pub mod ssvm;

/// A linear-oracle solution for one block.
///
/// `s` is the payload the server needs to apply the update: the oracle
/// vertex itself for parameter-space problems (GFL: the s-column; simplex
/// QP: the vertex), or the derived primal direction for structural SVM
/// (`w_s = psi_i(y*)/(lambda n)`).
#[derive(Debug, Clone)]
pub struct BlockOracle {
    /// Block index in [0, n).
    pub block: usize,
    /// Solution payload (dimension = problem-specific block payload dim).
    pub s: Vec<f32>,
    /// Scalar payload (SSVM: l_s = L_i(y*)/n; unused elsewhere).
    pub ls: f64,
}

impl BlockOracle {
    /// An empty oracle slot, ready to be filled by
    /// [`Problem::oracle_into`]. Allocation happens lazily on first fill
    /// and is reused afterwards.
    pub fn empty() -> Self {
        Self {
            block: 0,
            s: Vec::new(),
            ls: 0.0,
        }
    }
}

/// Options controlling how the server applies a minibatch.
#[derive(Debug, Clone, Copy)]
pub struct ApplyOptions {
    /// Fixed step size to use when `line_search` is false.
    pub gamma: f32,
    /// Exact coordinate line search (paper's line-search variant).
    pub line_search: bool,
}

/// Result of applying a minibatch.
#[derive(Debug, Clone, Copy)]
pub struct ApplyInfo {
    /// Step size actually used.
    pub gamma: f32,
    /// Surrogate-gap mass of the applied batch, evaluated at the
    /// pre-update parameter: sum_{i in S} <x_i - s_i, grad_i f(x)>.
    pub batch_gap: f64,
}

/// Caller-owned oracle scratch for problem `P` — shorthand for the
/// associated [`Problem::Scratch`] type at worker declaration sites.
pub type OracleScratch<P> = <P as Problem>::Scratch;

/// A block-separable Frank-Wolfe problem (paper Eq. 2).
pub trait Problem: Send + Sync {
    /// Server-side bookkeeping state.
    type ServerState: Send;

    /// Caller-owned oracle working memory (see the module docs' scratch
    /// ownership contract). `()` for problems whose oracle writes straight
    /// into the payload buffer. `Default` gives an empty scratch whose
    /// buffers are sized lazily on first use and reused afterwards; `Send`
    /// lets the scratch move with its worker thread.
    type Scratch: Send + Default;

    fn name(&self) -> &'static str;

    /// Number of coordinate blocks n.
    fn num_blocks(&self) -> usize;

    /// Dimension of the shared parameter vector.
    fn param_dim(&self) -> usize;

    /// Feasible initial parameter.
    fn init_param(&self) -> Vec<f32>;

    fn init_server(&self) -> Self::ServerState;

    /// Solve the block linear subproblem (paper Eq. 3) at `param`.
    fn oracle(&self, param: &[f32], block: usize) -> BlockOracle;

    /// Allocation-free oracle: solve the block subproblem into a
    /// caller-owned [`BlockOracle`], reusing `out.s`'s buffer and the
    /// caller-owned `scratch` for any intermediate state. Workers hold one
    /// (scratch, slot) pair and call this in their hot loop — batched
    /// workers reuse the same pair across every block of a snapshot — so a
    /// steady-state run performs no per-oracle allocation (§Perf).
    ///
    /// The default delegates to [`Problem::oracle`]; implementations MUST
    /// produce bit-identical output to `oracle` regardless of the scratch's
    /// prior contents (property-tested in
    /// `rust/tests/hot_path_equivalence.rs`).
    fn oracle_into(
        &self,
        param: &[f32],
        block: usize,
        scratch: &mut Self::Scratch,
        out: &mut BlockOracle,
    ) {
        let _ = scratch;
        *out = self.oracle(param, block);
    }

    /// Surrogate-gap contribution of `o` evaluated at the *current* param
    /// and state: `g_i = <x_i - s_i, grad_i f(x)>`.
    fn block_gap(
        &self,
        state: &Self::ServerState,
        param: &[f32],
        o: &BlockOracle,
    ) -> f64;

    /// Apply a batch of oracles for pairwise-distinct blocks.
    fn apply(
        &self,
        state: &mut Self::ServerState,
        param: &mut [f32],
        batch: &[BlockOracle],
        opts: ApplyOptions,
    ) -> ApplyInfo;

    /// Auxiliary scalar that must be averaged alongside the parameter for
    /// weighted iterate averaging (SSVM: the loss accumulator `l`; 0.0 for
    /// parameter-space problems).
    fn aux(&self, _state: &Self::ServerState) -> f64 {
        0.0
    }

    /// Objective as a function of (param, aux) — evaluable on averaged
    /// iterates without server state.
    fn objective_from(&self, param: &[f32], aux: f64) -> f64;

    /// Objective f(x) (cheap; uses cached state where possible).
    fn objective(&self, state: &Self::ServerState, param: &[f32]) -> f64 {
        self.objective_from(param, self.aux(state))
    }

    /// Parameter index ranges a batch's `apply` writes, or `None` when the
    /// whole parameter may change (e.g. SSVM, whose `w` update is dense).
    /// Lets the coordinator publish only the dirty ranges (§Perf).
    fn touched_ranges(
        &self,
        _batch: &[BlockOracle],
    ) -> Option<Vec<std::ops::Range<usize>>> {
        None
    }

    /// Exact surrogate duality gap g(x) = sum_i g_i(x) (expensive: one
    /// oracle call per block; monitoring only).
    fn full_gap(&self, state: &Self::ServerState, param: &[f32]) -> f64 {
        let mut total = 0.0;
        for i in 0..self.num_blocks() {
            let o = self.oracle(param, i);
            total += self.block_gap(state, param, &o);
        }
        total
    }
}

/// Problems additionally supporting block projections + block gradients,
/// needed by the parallel block-coordinate-descent baseline (paper §D.4).
pub trait ProjectableProblem: Problem {
    /// Dimension of block i's coordinates inside the parameter vector.
    fn block_range(&self, block: usize) -> std::ops::Range<usize>;

    /// grad_i f(param) as a dense block vector.
    fn block_grad(&self, param: &[f32], block: usize) -> Vec<f32>;

    /// Allocation-free block gradient into a caller-owned buffer (cleared
    /// and resized to the block dimension), using the same caller-owned
    /// [`Problem::Scratch`] as the oracle path. Default delegates to
    /// [`ProjectableProblem::block_grad`]; native implementations reuse
    /// the buffers so the PBCD hot loop stays allocation-free.
    fn block_grad_into(
        &self,
        param: &[f32],
        block: usize,
        scratch: &mut Self::Scratch,
        out: &mut Vec<f32>,
    ) {
        let _ = scratch;
        out.clear();
        out.extend_from_slice(&self.block_grad(param, block));
    }

    /// Euclidean projection of a block vector onto M_i (in place).
    fn project_block(&self, block: usize, x: &mut [f32]);

    /// Block gradient Lipschitz constant L_i (for the 1/L_i step).
    fn block_lipschitz(&self, block: usize) -> f64;
}
