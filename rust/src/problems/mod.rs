//! Problem abstraction for block-separable Frank-Wolfe (paper Eq. 2).
//!
//! A [`Problem`] is `min_x f(x)` over `M = M_1 x ... x M_n`. The split
//! between *parameter* and *server state* mirrors the paper's system model:
//!
//! - the **parameter** is the small dense vector broadcast to workers (for
//!   Group Fused Lasso it is the dual matrix `U` itself; for structural SVM
//!   it is the primal `w = A alpha`, not the exponentially large `alpha`);
//! - the **server state** is per-block bookkeeping only the server needs to
//!   apply updates (e.g. BCFW's per-datapoint `w_i`, `l_i`).
//!
//! Workers call [`Problem::oracle`] on a (possibly stale) parameter
//! snapshot; the server calls [`Problem::apply`] with a batch of oracles for
//! *disjoint* blocks, the paper's Algorithm 1 step 3.
//!
//! # Oracle scratch ownership
//!
//! Every problem names an explicit [`Problem::Scratch`] type — the working
//! memory its oracle needs beyond the output payload (Viterbi DP tables for
//! the chain SSVM, the `A^T x` coupling buffers for the simplex QP, nothing
//! for GFL/multiclass). The CALLER owns the scratch: a worker constructs one
//! `Scratch::default()` next to its [`BlockOracle`] slot and threads both
//! through every [`Problem::oracle_into`] call. This replaces the historical
//! hidden `thread_local!` `RefCell` scratch, which was non-reentrant and
//! resize-thrashed whenever two differently-shaped instances of the same
//! problem type shared a thread.
//! Because `Scratch: Send`, the scratch moves with its worker — batched
//! workers solving several blocks per snapshot reuse one scratch across the
//! whole batch with zero allocation (see `rust/tests/hot_path_equivalence.rs`
//! for the reentrancy property tests).
//!
//! # Oracle payload representation contract
//!
//! [`BlockOracle::s`] is an [`OraclePayload`] — either a dense vector or a
//! `(idx, val, dim)` sparse triple — because three of the four problems
//! emit structurally sparse vertices (simplex QP: a 1-hot vertex;
//! multiclass SSVM: `±psi_i(y*)/(lambda n)` on two class rows; chain SSVM:
//! the emission features of mistaken positions plus transition counts).
//! Shipping those sparse keeps the bytes per update and the server's apply
//! bandwidth proportional to the nonzeros instead of the parameter
//! dimension. The contract, pinned by `rust/tests/hot_path_equivalence.rs`:
//!
//! - **Request.** The CALLER chooses the representation by the variant of
//!   the `out.s` container it passes to [`Problem::oracle_into`] (workers
//!   resolve the `run.payload` knob — `auto | dense | sparse` — against
//!   [`Problem::preferred_payload`] once and size their slots with
//!   [`BlockOracle::empty_with`]). Recycled containers of the other
//!   variant are converted in place, reusing their buffers
//!   ([`OraclePayload::set_kind`]).
//! - **Fallback.** A problem that implements only one representation may
//!   override the request by converting the container (GFL always emits
//!   dense — its oracle is a dense ball-boundary column). Consumers must
//!   therefore accept either variant regardless of the requested mode.
//! - **Bit-identity.** A sparse payload densifies
//!   ([`OraclePayload::densify_into`]) to exactly the bits the dense
//!   emission would have produced, and every consumer (the fused SSVM
//!   gap+direction traversal, the parameter-space applies, the lock-free
//!   hogwild update) produces bit-identical results from either
//!   representation: the sparse convex-combination update is
//!   scale-by-`1-gamma`-then-scatter-axpy, which visits the same floats in
//!   the same order as the (deliberately unfused) dense `lerp_into` on the
//!   nonzero support. The one out-of-scope corner is negative-zero /
//!   negative-underflow inputs, which no problem emits.
//! - **Invariants.** Sparse `idx` is strictly ascending, in-bounds, and
//!   parallel to `val`; explicit zeros are allowed (and required where the
//!   dense accumulation writes one, e.g. cancelling chain transitions).

pub mod gfl;
pub mod simplex_qp;
pub mod ssvm;

/// Which concrete representation an [`OraclePayload`] container uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PayloadKind {
    /// Full `dim`-length vector.
    Dense,
    /// `(idx, val)` pairs over a `dim`-length implicit-zero vector.
    Sparse,
}

/// The `run.payload` knob: which representation workers request from
/// [`Problem::oracle_into`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PayloadMode {
    /// Each problem's natural representation
    /// ([`Problem::preferred_payload`]).
    #[default]
    Auto,
    /// Force dense payloads everywhere (the historical wire format).
    Dense,
    /// Request sparse payloads (problems without a sparse emitter fall
    /// back to dense — see the module docs' representation contract).
    Sparse,
}

impl PayloadMode {
    /// Resolve the knob against a problem's natural representation.
    pub fn resolve(self, natural: PayloadKind) -> PayloadKind {
        match self {
            PayloadMode::Auto => natural,
            PayloadMode::Dense => PayloadKind::Dense,
            PayloadMode::Sparse => PayloadKind::Sparse,
        }
    }

    /// Parse the config grammar (`auto | dense | sparse`).
    pub fn parse(text: &str) -> Option<Self> {
        match text.trim() {
            "auto" => Some(PayloadMode::Auto),
            "dense" => Some(PayloadMode::Dense),
            "sparse" => Some(PayloadMode::Sparse),
            _ => None,
        }
    }

    /// Canonical config spelling.
    pub fn name(self) -> &'static str {
        match self {
            PayloadMode::Auto => "auto",
            PayloadMode::Dense => "dense",
            PayloadMode::Sparse => "sparse",
        }
    }
}

/// A block-oracle solution payload: dense vector or sparse triple. See the
/// module docs' representation contract.
#[derive(Debug, Clone, PartialEq)]
pub enum OraclePayload {
    /// Full `dim`-length payload vector.
    Dense(Vec<f32>),
    /// Nonzero support of a `dim`-length vector: `val[k]` at index
    /// `idx[k]`, `idx` strictly ascending and in-bounds.
    Sparse {
        idx: Vec<u32>,
        val: Vec<f32>,
        dim: u32,
    },
}

impl Default for OraclePayload {
    fn default() -> Self {
        OraclePayload::Dense(Vec::new())
    }
}

impl OraclePayload {
    /// An empty container of the given representation (buffers allocate
    /// lazily on first fill and are reused afterwards).
    pub fn empty(kind: PayloadKind) -> Self {
        match kind {
            PayloadKind::Dense => OraclePayload::Dense(Vec::new()),
            PayloadKind::Sparse => OraclePayload::Sparse {
                idx: Vec::new(),
                val: Vec::new(),
                dim: 0,
            },
        }
    }

    /// The container's current representation.
    pub fn kind(&self) -> PayloadKind {
        match self {
            OraclePayload::Dense(_) => PayloadKind::Dense,
            OraclePayload::Sparse { .. } => PayloadKind::Sparse,
        }
    }

    /// Logical (dense) dimension of the payload.
    pub fn dim(&self) -> usize {
        match self {
            OraclePayload::Dense(s) => s.len(),
            OraclePayload::Sparse { dim, .. } => *dim as usize,
        }
    }

    /// Number of explicitly stored values (dense: the full dimension).
    /// This is the `payload_nnz` telemetry unit.
    pub fn nnz(&self) -> usize {
        match self {
            OraclePayload::Dense(s) => s.len(),
            OraclePayload::Sparse { val, .. } => val.len(),
        }
    }

    /// Wire size of the payload body in bytes (excludes the
    /// representation-independent block/ls header): dense `4*dim`, sparse
    /// `4 + 8*nnz` (dim word + u32 index + f32 value per entry). This is
    /// the `payload_bytes` telemetry unit.
    pub fn wire_bytes(&self) -> usize {
        match self {
            OraclePayload::Dense(s) => 4 * s.len(),
            OraclePayload::Sparse { val, .. } => 4 + 8 * val.len(),
        }
    }

    /// Whether the container holds no reusable buffer capacity (a fresh
    /// slot that should be topped up from a recycle pool before filling).
    pub fn is_unallocated(&self) -> bool {
        match self {
            OraclePayload::Dense(s) => s.capacity() == 0,
            OraclePayload::Sparse { idx, val, .. } => {
                val.capacity() == 0 && idx.capacity() == 0
            }
        }
    }

    /// Clear stored values, retaining buffer capacity (recycle-pool form).
    pub fn recycle(&mut self) {
        match self {
            OraclePayload::Dense(s) => s.clear(),
            OraclePayload::Sparse { idx, val, dim } => {
                idx.clear();
                val.clear();
                *dim = 0;
            }
        }
    }

    /// Convert the container to the given representation in place, reusing
    /// the f32 buffer across the variant switch; contents are cleared.
    pub fn set_kind(&mut self, kind: PayloadKind) {
        match kind {
            PayloadKind::Dense => {
                self.make_dense();
            }
            PayloadKind::Sparse => {
                self.make_sparse(0);
            }
        }
    }

    /// View the container as its dense buffer, converting (and clearing) a
    /// sparse container first. An already-dense buffer keeps its contents,
    /// so fillers that assign every element can skip the zero-fill.
    pub fn ensure_dense(&mut self) -> &mut Vec<f32> {
        if let OraclePayload::Sparse { val, .. } = self {
            let mut v = std::mem::take(val);
            v.clear();
            *self = OraclePayload::Dense(v);
        }
        match self {
            OraclePayload::Dense(s) => s,
            OraclePayload::Sparse { .. } => unreachable!(),
        }
    }

    /// Turn the container into an EMPTY dense buffer (reusing the sparse
    /// value buffer if the variant switches) and return it for filling.
    pub fn make_dense(&mut self) -> &mut Vec<f32> {
        let s = self.ensure_dense();
        s.clear();
        s
    }

    /// Turn the container into an EMPTY sparse triple with logical
    /// dimension `dim` (reusing the dense buffer as the value buffer if
    /// the variant switches) and return `(idx, val)` for filling.
    pub fn make_sparse(&mut self, dim: usize) -> (&mut Vec<u32>, &mut Vec<f32>) {
        if let OraclePayload::Dense(s) = self {
            let v = std::mem::take(s);
            *self = OraclePayload::Sparse {
                idx: Vec::new(),
                val: v,
                dim: 0,
            };
        }
        match self {
            OraclePayload::Sparse { idx, val, dim: d } => {
                idx.clear();
                val.clear();
                *d = dim as u32;
                (idx, val)
            }
            OraclePayload::Dense(_) => unreachable!(),
        }
    }

    /// The payload as a dense slice, when it is stored dense.
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            OraclePayload::Dense(s) => Some(s),
            OraclePayload::Sparse { .. } => None,
        }
    }

    /// Write the dense form into `out` (cleared + resized to `dim`). The
    /// densified bits equal what the dense emission would have produced
    /// (module-docs contract).
    pub fn densify_into(&self, out: &mut Vec<f32>) {
        out.clear();
        match self {
            OraclePayload::Dense(s) => out.extend_from_slice(s),
            OraclePayload::Sparse { idx, val, dim } => {
                out.resize(*dim as usize, 0.0);
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    out[i as usize] = v;
                }
            }
        }
    }

    /// Allocating [`OraclePayload::densify_into`].
    pub fn to_dense_vec(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.densify_into(&mut out);
        out
    }

    /// Iterate the payload as the logical `dim`-length dense sequence
    /// without materializing it — the cursor consumers (fused SSVM apply,
    /// QP gap, lock-free hogwild update) are built on this, and on a dense
    /// container it yields exactly the slice's floats in order.
    pub fn dense_iter(&self) -> PayloadDenseIter<'_> {
        match self {
            OraclePayload::Dense(s) => PayloadDenseIter::Dense(s.iter()),
            OraclePayload::Sparse { idx, val, dim } => {
                PayloadDenseIter::Sparse {
                    idx,
                    val,
                    cursor: 0,
                    pos: 0,
                    dim: *dim,
                }
            }
        }
    }

    /// Debug-check the sparse invariants (strictly ascending, in-bounds
    /// `idx`, parallel `val`). No-op for dense.
    pub fn debug_check_invariants(&self) {
        if let OraclePayload::Sparse { idx, val, dim } = self {
            debug_assert_eq!(idx.len(), val.len());
            debug_assert!(idx.windows(2).all(|w| w[0] < w[1]));
            debug_assert!(idx.last().map_or(true, |&i| i < *dim));
        }
    }
}

/// Iterator over the logical dense view of an [`OraclePayload`].
pub enum PayloadDenseIter<'a> {
    Dense(std::slice::Iter<'a, f32>),
    Sparse {
        idx: &'a [u32],
        val: &'a [f32],
        cursor: usize,
        pos: u32,
        dim: u32,
    },
}

impl Iterator for PayloadDenseIter<'_> {
    type Item = f32;

    #[inline]
    fn next(&mut self) -> Option<f32> {
        match self {
            PayloadDenseIter::Dense(it) => it.next().copied(),
            PayloadDenseIter::Sparse {
                idx,
                val,
                cursor,
                pos,
                dim,
            } => {
                if *pos >= *dim {
                    return None;
                }
                let v = if *cursor < idx.len() && idx[*cursor] == *pos {
                    let v = val[*cursor];
                    *cursor += 1;
                    v
                } else {
                    0.0
                };
                *pos += 1;
                Some(v)
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = match self {
            PayloadDenseIter::Dense(it) => it.len(),
            PayloadDenseIter::Sparse { pos, dim, .. } => {
                (*dim - *pos) as usize
            }
        };
        (n, Some(n))
    }
}

impl ExactSizeIterator for PayloadDenseIter<'_> {}

/// A linear-oracle solution for one block.
///
/// `s` is the payload the server needs to apply the update: the oracle
/// vertex itself for parameter-space problems (GFL: the s-column; simplex
/// QP: the vertex), or the derived primal direction for structural SVM
/// (`w_s = psi_i(y*)/(lambda n)`) — dense or sparse per the module docs'
/// representation contract.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockOracle {
    /// Block index in [0, n).
    pub block: usize,
    /// Solution payload (logical dimension = problem-specific block
    /// payload dim).
    pub s: OraclePayload,
    /// Scalar payload (SSVM: l_s = L_i(y*)/n; unused elsewhere).
    pub ls: f64,
}

impl BlockOracle {
    /// An empty DENSE oracle slot, ready to be filled by
    /// [`Problem::oracle_into`]. Allocation happens lazily on first fill
    /// and is reused afterwards.
    pub fn empty() -> Self {
        Self::empty_with(PayloadKind::Dense)
    }

    /// An empty oracle slot requesting the given payload representation.
    pub fn empty_with(kind: PayloadKind) -> Self {
        Self {
            block: 0,
            s: OraclePayload::empty(kind),
            ls: 0.0,
        }
    }

    /// A filled dense oracle (test/bench convenience).
    pub fn dense(block: usize, s: Vec<f32>, ls: f64) -> Self {
        Self {
            block,
            s: OraclePayload::Dense(s),
            ls,
        }
    }
}

/// Options controlling how the server applies a minibatch.
#[derive(Debug, Clone, Copy)]
pub struct ApplyOptions {
    /// Fixed step size to use when `line_search` is false.
    pub gamma: f32,
    /// Exact coordinate line search (paper's line-search variant).
    pub line_search: bool,
}

/// Result of applying a minibatch.
#[derive(Debug, Clone, Copy)]
pub struct ApplyInfo {
    /// Step size actually used.
    pub gamma: f32,
    /// Surrogate-gap mass of the applied batch, evaluated at the
    /// pre-update parameter: sum_{i in S} <x_i - s_i, grad_i f(x)>.
    pub batch_gap: f64,
}

/// Caller-owned oracle scratch for problem `P` — shorthand for the
/// associated [`Problem::Scratch`] type at worker declaration sites.
pub type OracleScratch<P> = <P as Problem>::Scratch;

/// A block-separable Frank-Wolfe problem (paper Eq. 2).
pub trait Problem: Send + Sync {
    /// Server-side bookkeeping state.
    type ServerState: Send;

    /// Caller-owned oracle working memory (see the module docs' scratch
    /// ownership contract). `()` for problems whose oracle writes straight
    /// into the payload buffer. `Default` gives an empty scratch whose
    /// buffers are sized lazily on first use and reused afterwards; `Send`
    /// lets the scratch move with its worker thread.
    type Scratch: Send + Default;

    fn name(&self) -> &'static str;

    /// Number of coordinate blocks n.
    fn num_blocks(&self) -> usize;

    /// Dimension of the shared parameter vector.
    fn param_dim(&self) -> usize;

    /// Feasible initial parameter.
    fn init_param(&self) -> Vec<f32>;

    fn init_server(&self) -> Self::ServerState;

    /// The payload representation this problem's oracle naturally emits
    /// (what `run.payload = auto` resolves to). Dense by default; problems
    /// whose vertices are structurally sparse override this — see the
    /// module docs' representation contract.
    fn preferred_payload(&self) -> PayloadKind {
        PayloadKind::Dense
    }

    /// Solve the block linear subproblem (paper Eq. 3) at `param`.
    /// Always returns a DENSE payload (the historical allocating API).
    fn oracle(&self, param: &[f32], block: usize) -> BlockOracle;

    /// Allocation-free oracle: solve the block subproblem into a
    /// caller-owned [`BlockOracle`], reusing `out.s`'s buffers and the
    /// caller-owned `scratch` for any intermediate state. Workers hold one
    /// (scratch, slot) pair and call this in their hot loop — batched
    /// workers reuse the same pair across every block of a snapshot — so a
    /// steady-state run performs no per-oracle allocation (§Perf).
    ///
    /// The variant of the incoming `out.s` container is the caller's
    /// representation request; implementations without an emitter for it
    /// convert the container (module-docs contract). The default delegates
    /// to [`Problem::oracle`] (dense); implementations MUST produce output
    /// that DENSIFIES bit-identically to `oracle`, regardless of the
    /// scratch's or container's prior contents (property-tested in
    /// `rust/tests/hot_path_equivalence.rs`).
    fn oracle_into(
        &self,
        param: &[f32],
        block: usize,
        scratch: &mut Self::Scratch,
        out: &mut BlockOracle,
    ) {
        let _ = scratch;
        *out = self.oracle(param, block);
    }

    /// Surrogate-gap contribution of `o` evaluated at the *current* param
    /// and state: `g_i = <x_i - s_i, grad_i f(x)>`.
    fn block_gap(
        &self,
        state: &Self::ServerState,
        param: &[f32],
        o: &BlockOracle,
    ) -> f64;

    /// Apply a batch of oracles for pairwise-distinct blocks.
    fn apply(
        &self,
        state: &mut Self::ServerState,
        param: &mut [f32],
        batch: &[BlockOracle],
        opts: ApplyOptions,
    ) -> ApplyInfo;

    /// Auxiliary scalar that must be averaged alongside the parameter for
    /// weighted iterate averaging (SSVM: the loss accumulator `l`; 0.0 for
    /// parameter-space problems).
    fn aux(&self, _state: &Self::ServerState) -> f64 {
        0.0
    }

    /// Objective as a function of (param, aux) — evaluable on averaged
    /// iterates without server state.
    fn objective_from(&self, param: &[f32], aux: f64) -> f64;

    /// Objective f(x) (cheap; uses cached state where possible).
    fn objective(&self, state: &Self::ServerState, param: &[f32]) -> f64 {
        self.objective_from(param, self.aux(state))
    }

    /// Parameter index ranges a batch's `apply` writes, or `None` when the
    /// whole parameter may change (e.g. SSVM, whose `w` update is dense).
    /// Lets the coordinator publish only the dirty ranges (§Perf).
    fn touched_ranges(
        &self,
        _batch: &[BlockOracle],
    ) -> Option<Vec<std::ops::Range<usize>>> {
        None
    }

    /// Exact surrogate duality gap g(x) = sum_i g_i(x) (expensive: one
    /// oracle call per block; monitoring only).
    fn full_gap(&self, state: &Self::ServerState, param: &[f32]) -> f64 {
        let mut total = 0.0;
        for i in 0..self.num_blocks() {
            let o = self.oracle(param, i);
            total += self.block_gap(state, param, &o);
        }
        total
    }

    /// Serialize the server apply state into a durable checkpoint body
    /// (crash recovery). Problems whose state is pure scratch — `()` for
    /// GFL and the simplex QP — write nothing (the default); problems
    /// with durable bookkeeping (SSVM's per-block `w_i`/`l_i`) override
    /// both this and [`Problem::restore_server_state`] so a restored
    /// serve loop applies future updates against exactly the pre-crash
    /// state bits.
    fn checkpoint_server_state(&self, _state: &Self::ServerState) -> Vec<u8> {
        Vec::new()
    }

    /// Inverse of [`Problem::checkpoint_server_state`]: rebuild the
    /// server apply state from a checkpoint body. The default (stateless
    /// problems) accepts only an empty body, so a checkpoint written by
    /// a different problem configuration fails cleanly instead of being
    /// silently ignored.
    fn restore_server_state(
        &self,
        _state: &mut Self::ServerState,
        raw: &[u8],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            raw.is_empty(),
            "checkpoint carries {} bytes of server state for a stateless \
             problem",
            raw.len()
        );
        Ok(())
    }
}

/// Problems additionally supporting block projections + block gradients,
/// needed by the parallel block-coordinate-descent baseline (paper §D.4).
pub trait ProjectableProblem: Problem {
    /// Dimension of block i's coordinates inside the parameter vector.
    fn block_range(&self, block: usize) -> std::ops::Range<usize>;

    /// grad_i f(param) as a dense block vector.
    fn block_grad(&self, param: &[f32], block: usize) -> Vec<f32>;

    /// Allocation-free block gradient into a caller-owned buffer (cleared
    /// and resized to the block dimension), using the same caller-owned
    /// [`Problem::Scratch`] as the oracle path. Default delegates to
    /// [`ProjectableProblem::block_grad`]; native implementations reuse
    /// the buffers so the PBCD hot loop stays allocation-free.
    fn block_grad_into(
        &self,
        param: &[f32],
        block: usize,
        scratch: &mut Self::Scratch,
        out: &mut Vec<f32>,
    ) {
        let _ = scratch;
        out.clear();
        out.extend_from_slice(&self.block_grad(param, block));
    }

    /// Euclidean projection of a block vector onto M_i (in place).
    fn project_block(&self, block: usize, x: &mut [f32]);

    /// Block gradient Lipschitz constant L_i (for the 1/L_i step).
    fn block_lipschitz(&self, block: usize) -> f64;
}
