//! Update-delay models (paper §2.3 / §3.4).
//!
//! The paper models the staleness of each worker update as an iid draw from
//! an unknown distribution and proves convergence depending only (mildly) on
//! the *expected* delay `kappa`, with the server dropping any update whose
//! delay exceeds `k/2` at iteration `k`. This module provides the
//! distributions used in Figure 4 (Poisson and heavy-tailed Pareto with
//! infinite variance) plus deterministic and zero-delay controls.

use crate::util::rng::Pcg64;

/// A staleness distribution over non-negative integers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DelayModel {
    /// No delay (synchronous oracle).
    None,
    /// Fixed integer delay.
    Fixed(u64),
    /// Poisson with mean kappa.
    Poisson { kappa: f64 },
    /// Pareto(shape alpha, scale x_m) rounded to the nearest integer. The
    /// paper uses alpha = 2, x_m = kappa/2 so that E = kappa, Var = inf.
    Pareto { alpha: f64, xm: f64 },
}

impl DelayModel {
    /// Paper's Fig-4 Pareto parameterization from an expected delay kappa.
    pub fn pareto_with_mean(kappa: f64) -> Self {
        DelayModel::Pareto {
            alpha: 2.0,
            xm: kappa / 2.0,
        }
    }

    /// Sample one staleness value.
    pub fn sample(&self, rng: &mut Pcg64) -> u64 {
        match *self {
            DelayModel::None => 0,
            DelayModel::Fixed(k) => k,
            DelayModel::Poisson { kappa } => rng.poisson(kappa),
            DelayModel::Pareto { alpha, xm } => {
                rng.pareto(alpha, xm).round() as u64
            }
        }
    }

    /// Expected delay kappa (exact for all supported models).
    pub fn mean(&self) -> f64 {
        match *self {
            DelayModel::None => 0.0,
            DelayModel::Fixed(k) => k as f64,
            DelayModel::Poisson { kappa } => kappa,
            DelayModel::Pareto { alpha, xm } => {
                if alpha > 1.0 {
                    alpha * xm / (alpha - 1.0)
                } else {
                    f64::INFINITY
                }
            }
        }
    }
}

/// The paper's staleness acceptance rule: at server iteration `k`, drop any
/// update computed from a parameter older than `k/2` iterations.
#[inline]
pub fn accept_delay(k: u64, delay: u64) -> bool {
    // k/2 with integer semantics, matching "delay greater than k/2 dropped".
    2 * delay <= k
}

/// Ring buffer of past parameter snapshots for delayed-oracle simulation.
///
/// `push` stores the parameter at each iteration; `get(k, delay)` fetches
/// the snapshot from iteration `k - delay` if still retained.
pub struct History {
    cap: usize,
    slots: Vec<Vec<f32>>,
    /// Iteration number of the most recent snapshot (next push is iter+1).
    latest: Option<u64>,
}

impl History {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        Self {
            cap,
            slots: Vec::with_capacity(cap),
            latest: None,
        }
    }

    /// Record the parameter at iteration `k` (must be called with strictly
    /// increasing k starting at 0).
    pub fn push(&mut self, k: u64, param: &[f32]) {
        match self.latest {
            None => assert_eq!(k, 0, "history must start at iteration 0"),
            Some(prev) => assert_eq!(k, prev + 1, "non-contiguous history"),
        }
        if self.slots.len() == self.cap {
            // overwrite oldest slot
            let idx = (k % self.cap as u64) as usize;
            self.slots[idx].clear();
            self.slots[idx].extend_from_slice(param);
        } else {
            self.slots.push(param.to_vec());
        }
        self.latest = Some(k);
    }

    /// Parameter snapshot from iteration `k - delay`; None if evicted.
    pub fn get(&self, delay: u64) -> Option<&[f32]> {
        let latest = self.latest?;
        if delay > latest && delay > 0 {
            // older than the start: clamp to iteration 0 if retained
            return None;
        }
        let want = latest.saturating_sub(delay);
        if latest - want >= self.slots.len() as u64 {
            return None;
        }
        let idx = (want % self.cap as u64) as usize;
        Some(&self.slots[idx])
    }

    pub fn latest_iter(&self) -> Option<u64> {
        self.latest
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_rule_matches_paper() {
        // at k=10, delays up to 5 accepted
        assert!(accept_delay(10, 5));
        assert!(!accept_delay(10, 6));
        assert!(accept_delay(0, 0));
        assert!(!accept_delay(1, 1));
        assert!(accept_delay(2, 1));
    }

    #[test]
    fn delay_means() {
        let mut rng = Pcg64::seeded(1);
        for model in [
            DelayModel::None,
            DelayModel::Fixed(7),
            DelayModel::Poisson { kappa: 5.0 },
            DelayModel::pareto_with_mean(10.0),
        ] {
            let n = 60_000;
            let mean = (0..n).map(|_| model.sample(&mut rng) as f64).sum::<f64>()
                / n as f64;
            let expected = model.mean();
            // Pareto rounding biases slightly; generous tolerance.
            assert!(
                (mean - expected).abs() < 0.15 * expected.max(1.0),
                "{model:?}: sample mean {mean} vs {expected}"
            );
        }
    }

    #[test]
    fn pareto_mean_parameterization() {
        assert_eq!(DelayModel::pareto_with_mean(20.0).mean(), 20.0);
    }

    #[test]
    fn history_retrieval() {
        let mut h = History::new(4);
        for k in 0..10u64 {
            h.push(k, &[k as f32]);
        }
        assert_eq!(h.get(0).unwrap(), &[9.0]);
        assert_eq!(h.get(3).unwrap(), &[6.0]);
        assert!(h.get(4).is_none()); // evicted
    }

    #[test]
    fn history_clamps_before_start() {
        let mut h = History::new(8);
        h.push(0, &[0.0]);
        h.push(1, &[1.0]);
        assert_eq!(h.get(1).unwrap(), &[0.0]);
        assert!(h.get(5).is_none());
    }

    #[test]
    #[should_panic]
    fn history_requires_contiguity() {
        let mut h = History::new(4);
        h.push(0, &[0.0]);
        h.push(2, &[2.0]);
    }
}
