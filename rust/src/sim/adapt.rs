//! Delay-adaptive control policies: step damping, drop thresholds, and
//! worker batch sizing driven by the observed-delay telemetry PR 5
//! introduced (`delay_sum` / `mean_delay()` — the empirical kappa).
//!
//! The paper's convergence constants (§2.3, §3.4) assume an *expected*
//! delay kappa; when the observed delay runs past that assumption the
//! unbounded-delay analysis of arXiv:1612.04425 still converges under a
//! *damped* step size. This module holds the pure policy math — every
//! decision function here is deterministic and side-effect free so the
//! property suite (`rust/tests/properties.rs`) can pin its invariants
//! directly:
//!
//! - [`StepPolicy`] / [`KappaEma`] / [`damping_factor`]: `run.adapt.step`
//!   scales `schedule_gamma` by `kappa_exp / (kappa_exp + kappa_obs)`,
//!   clamped to `[MIN_DAMP, 1]` — monotone nonincreasing in the observed
//!   kappa, exactly 1 when no delay has been observed.
//! - [`DropPolicy`] / [`DelayWindow`] / [`accept_delay_adjusted`]:
//!   `run.adapt.drop` re-centers the paper's k/2 verdict by the gap
//!   between a running delay quantile and the running median, so
//!   `quantile:Q` with Q > 0.5 accepts a superset of the k/2 verdicts
//!   and Q < 0.5 a subset (Q = 0.5 is *identical* for any history).
//! - [`BatchPolicy`] / [`next_batch`]: `run.adapt.batch` grows the
//!   worker fan-out tau_w when snapshot pulls are cheap and shrinks it
//!   under contention, never leaving `[MIN, min(MAX, n/workers)]`.
//!
//! The `off` / `k2` / `off` defaults are pure pass-throughs: the engines
//! keep their historical expressions on those arms, which is what the
//! bit-identity pins in `rust/tests/runner_equivalence.rs` verify.

use crate::sim::delay::accept_delay;
use crate::util::config::Config;
use anyhow::{anyhow, bail, ensure, Result};

/// Lower clamp of the damping factor: even under pathological observed
/// delays the step never collapses below a tenth of the schedule (the
/// damped regime of arXiv:1612.04425 needs gamma bounded away from 0 to
/// keep making progress).
pub const MIN_DAMP: f64 = 0.1;

/// Smoothing weight of the kappa EMA — the same 0.8/0.2 blend the apply
/// core's gap estimator uses, so both telemetry smoothers age at the
/// same rate.
pub const EMA_KEEP: f64 = 0.8;

/// Delays remembered by the running-quantile window (`run.adapt.drop`).
pub const DELAY_WINDOW: usize = 64;

/// `run.adapt.step`: how the step-size schedule reacts to observed delay.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StepPolicy {
    /// Historical behavior: `schedule_gamma` verbatim (pinned default).
    #[default]
    Off,
    /// Scale gamma by the clamped `kappa_exp / (kappa_exp + kappa_obs)`
    /// damping factor, with kappa_obs the EMA of observed delays.
    Kappa,
}

/// `run.adapt.drop`: which staleness verdict gates an incoming update.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum DropPolicy {
    /// The paper's Theorem 4 rule, `delay <= k/2`, verbatim (pinned
    /// default — delegates to [`crate::sim::delay::accept_delay`]).
    #[default]
    K2,
    /// Re-center the k/2 threshold by `T_q - T_median` over the recent
    /// delay window: permissive quantiles (q > 0.5) widen the accept
    /// set, strict ones (q < 0.5) narrow it; q = 0.5 is exactly K2.
    Quantile(f64),
}

/// `run.adapt.batch`: whether the worker fan-out tau_w self-tunes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchPolicy {
    /// Fixed `run.batch` for the whole session (pinned default).
    #[default]
    Off,
    /// Grow toward `max` while snapshot pulls stay near the best
    /// observed latency, shrink toward `min` under contention.
    Auto {
        /// Smallest batch the controller may choose (>= 1).
        min: usize,
        /// Largest batch the controller may choose (>= min).
        max: usize,
    },
}

/// The three `run.adapt.*` knobs, lowered together by
/// [`crate::run::RunSpec::from_config`] and threaded to the engines.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct AdaptSpec {
    /// `run.adapt.step = off | kappa`.
    pub step: StepPolicy,
    /// `run.adapt.drop = k2 | quantile:Q` with Q in [0, 1].
    pub drop: DropPolicy,
    /// `run.adapt.batch = off | auto:MIN:MAX` with 1 <= MIN <= MAX.
    pub batch: BatchPolicy,
}

impl AdaptSpec {
    /// True iff every policy is its pinned default — the engines take
    /// their historical code paths exactly.
    pub fn is_off(&self) -> bool {
        *self == AdaptSpec::default()
    }

    /// Parse and strictly validate the `run.adapt.*` keys. Absent keys
    /// mean the pinned defaults; malformed values are hard errors that
    /// name the offending knob (the CI rejection probes grep for it).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let step = match cfg.get_or("run.adapt.step", "off").as_str() {
            "off" => StepPolicy::Off,
            "kappa" => StepPolicy::Kappa,
            other => bail!(
                "run.adapt.step must be off|kappa, got {other:?}"
            ),
        };
        let drop = match cfg.get_or("run.adapt.drop", "k2").as_str() {
            "k2" => DropPolicy::K2,
            other => match other.strip_prefix("quantile:") {
                Some(qs) => {
                    let q: f64 = qs.parse().map_err(|_| {
                        anyhow!(
                            "run.adapt.drop: bad quantile {qs:?} \
                             (expected quantile:Q with Q in [0, 1])"
                        )
                    })?;
                    ensure!(
                        (0.0..=1.0).contains(&q),
                        "run.adapt.drop: quantile Q must lie in \
                         [0, 1], got {q}"
                    );
                    DropPolicy::Quantile(q)
                }
                None => bail!(
                    "run.adapt.drop must be k2|quantile:Q, got {other:?}"
                ),
            },
        };
        let batch = match cfg.get_or("run.adapt.batch", "off").as_str() {
            "off" => BatchPolicy::Off,
            other => match other.strip_prefix("auto:") {
                Some(rest) => {
                    let (lo, hi) = rest.split_once(':').ok_or_else(|| {
                        anyhow!(
                            "run.adapt.batch: expected auto:MIN:MAX, \
                             got {other:?}"
                        )
                    })?;
                    let parse = |s: &str| -> Result<usize> {
                        s.parse().map_err(|_| {
                            anyhow!(
                                "run.adapt.batch: bad bound {s:?} in \
                                 {other:?}"
                            )
                        })
                    };
                    let (min, max) = (parse(lo)?, parse(hi)?);
                    ensure!(
                        min >= 1,
                        "run.adapt.batch: MIN must be >= 1, got {min}"
                    );
                    ensure!(
                        min <= max,
                        "run.adapt.batch: MIN must be <= MAX, \
                         got auto:{min}:{max}"
                    );
                    BatchPolicy::Auto { min, max }
                }
                None => bail!(
                    "run.adapt.batch must be off|auto:MIN:MAX, \
                     got {other:?}"
                ),
            },
        };
        Ok(AdaptSpec { step, drop, batch })
    }
}

/// The clamped damping factor `kappa_exp / (kappa_exp + kappa_obs)`.
///
/// `kappa_exp` is the expected per-apply delay the schedule already
/// prices in — the server minibatch width tau (at the paper's stationary
/// regime a worker's snapshot is ~tau applies old by the time its update
/// lands). `kappa_obs` is the EMA of observed delays. Properties the
/// suite pins: monotone nonincreasing in `kappa_obs`, always within
/// `[MIN_DAMP, 1]`, and exactly 1 at `kappa_obs <= 0` (no observed delay
/// means no damping — including the before-first-update state where the
/// EMA reports 0).
pub fn damping_factor(kappa_exp: f64, kappa_obs: f64) -> f64 {
    if kappa_obs <= 0.0 {
        return 1.0;
    }
    (kappa_exp / (kappa_exp + kappa_obs)).clamp(MIN_DAMP, 1.0)
}

/// EMA of observed per-update delays — the smoothed empirical kappa
/// behind `run.adapt.step = kappa`. Reports 0 before the first
/// observation (never NaN: the zero-updates path is unit-tested, the
/// small-fix satellite of ISSUE 10).
#[derive(Debug, Clone, Copy, Default)]
pub struct KappaEma {
    ema: Option<f64>,
}

impl KappaEma {
    /// Fresh estimator with no observations.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observed delay in: the first observation seeds the EMA,
    /// later ones blend at the gap estimator's 0.8/0.2 rate.
    pub fn observe(&mut self, delay: u64) {
        let d = delay as f64;
        self.ema = Some(match self.ema {
            Some(e) => EMA_KEEP * e + (1.0 - EMA_KEEP) * d,
            None => d,
        });
    }

    /// The smoothed observed kappa; 0.0 before the first observation.
    pub fn value(&self) -> f64 {
        self.ema.unwrap_or(0.0)
    }
}

/// Bounded ring of recently observed delays backing the running
/// quantiles of `run.adapt.drop = quantile:Q`. Distinct from
/// [`crate::sim::delay::History`], which rings *parameter snapshots*
/// for the sequential delayed-oracle simulation.
#[derive(Debug, Clone)]
pub struct DelayWindowRing {
    buf: Vec<u64>,
    next: usize,
    cap: usize,
}

impl DelayWindowRing {
    /// Ring remembering the last `cap` delays (cap >= 1).
    pub fn new(cap: usize) -> Self {
        Self {
            buf: Vec::with_capacity(cap.max(1)),
            next: 0,
            cap: cap.max(1),
        }
    }

    /// Record one observed delay, evicting the oldest once full.
    pub fn push(&mut self, delay: u64) {
        if self.buf.len() < self.cap {
            self.buf.push(delay);
        } else {
            self.buf[self.next] = delay;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Delays currently remembered.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Nearest-rank quantile of the window (`sorted[ceil(q*m) - 1]`,
    /// clamped into range) — monotone nondecreasing in `q`. `None` on an
    /// empty window.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.buf.is_empty() {
            return None;
        }
        let mut sorted = self.buf.clone();
        sorted.sort_unstable();
        let m = sorted.len();
        let rank = (q * m as f64).ceil() as usize;
        Some(sorted[rank.clamp(1, m) - 1])
    }

    /// The k/2 re-centering term of `quantile:Q`: `T_q - T_median` over
    /// the window. Zero on an empty window (the rule degrades to exact
    /// k/2), zero for any window at q = 0.5, nonnegative for q > 0.5,
    /// nonpositive for q < 0.5 — quantile monotonicity makes the
    /// superset/subset property structural.
    pub fn adjustment(&self, q: f64) -> i64 {
        match (self.quantile(q), self.quantile(0.5)) {
            (Some(tq), Some(tm)) => tq as i64 - tm as i64,
            _ => 0,
        }
    }
}

/// The generalized staleness verdict: accept iff
/// `delay - adjustment <= k/2` (exact integer arithmetic, no rounding
/// drift from the historical rule). `adjustment = 0` reproduces
/// [`accept_delay`] verbatim; positive adjustments accept a superset,
/// negative ones a subset.
pub fn accept_delay_adjusted(k: u64, delay: u64, adjustment: i64) -> bool {
    if adjustment == 0 {
        return accept_delay(k, delay);
    }
    2 * (delay as i128 - adjustment as i128) <= k as i128
}

/// One step of the worker-side adaptive batch controller
/// (`run.adapt.batch = auto:MIN:MAX`): pure so the property suite can
/// drive it with arbitrary latencies.
///
/// `cap` is the session ceiling `min(MAX, n / workers)` (so the fleet's
/// combined fan-out can never exceed n); `pull_ema` is the smoothed
/// snapshot-pull latency and `best_pull` the cheapest pull seen.
/// Contention (pulls > 2x the best) halves toward MIN; cheap pulls
/// (< 1.25x the best) grow by one toward the cap; in between holds.
/// The result always lies in `[min(MIN, cap), cap]`.
pub fn next_batch(
    current: usize,
    min: usize,
    cap: usize,
    pull_ema: f64,
    best_pull: f64,
) -> usize {
    let floor = min.min(cap).max(1);
    let cur = current.clamp(floor, cap.max(1));
    let proposed = if best_pull > 0.0 && pull_ema > 2.0 * best_pull {
        cur / 2
    } else if best_pull <= 0.0 || pull_ema < 1.25 * best_pull {
        cur + 1
    } else {
        cur
    };
    proposed.clamp(floor, cap.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(pairs: &[(&str, &str)]) -> Config {
        let mut c = Config::new();
        for (k, v) in pairs {
            c.set(k, v);
        }
        c
    }

    #[test]
    fn defaults_are_all_off() {
        let a = AdaptSpec::from_config(&Config::new()).unwrap();
        assert!(a.is_off());
        assert_eq!(a.step, StepPolicy::Off);
        assert_eq!(a.drop, DropPolicy::K2);
        assert_eq!(a.batch, BatchPolicy::Off);
    }

    #[test]
    fn parses_every_policy() {
        let a = AdaptSpec::from_config(&cfg(&[
            ("run.adapt.step", "kappa"),
            ("run.adapt.drop", "quantile:0.9"),
            ("run.adapt.batch", "auto:2:16"),
        ]))
        .unwrap();
        assert_eq!(a.step, StepPolicy::Kappa);
        assert_eq!(a.drop, DropPolicy::Quantile(0.9));
        assert_eq!(a.batch, BatchPolicy::Auto { min: 2, max: 16 });
        assert!(!a.is_off());
    }

    #[test]
    fn rejects_malformed_knobs() {
        for (key, bad) in [
            ("run.adapt.step", "loud"),
            ("run.adapt.drop", "quantile:1.5"),
            ("run.adapt.drop", "quantile:-0.1"),
            ("run.adapt.drop", "median"),
            ("run.adapt.batch", "auto:8:2"),
            ("run.adapt.batch", "auto:0:4"),
            ("run.adapt.batch", "auto:3"),
            ("run.adapt.batch", "always"),
        ] {
            let err = AdaptSpec::from_config(&cfg(&[(key, bad)]))
                .unwrap_err()
                .to_string();
            assert!(err.contains(key), "{key}={bad}: {err}");
        }
    }

    #[test]
    fn kappa_ema_zero_before_first_observation() {
        let e = KappaEma::new();
        assert_eq!(e.value(), 0.0);
        assert!(!e.value().is_nan());
        // And the damping factor at that state is exactly 1 — the
        // zero-updates path never perturbs gamma.
        assert_eq!(damping_factor(4.0, e.value()), 1.0);
    }

    #[test]
    fn kappa_ema_seeds_then_blends() {
        let mut e = KappaEma::new();
        e.observe(10);
        assert_eq!(e.value(), 10.0);
        e.observe(0);
        assert!((e.value() - 8.0).abs() < 1e-12);
        e.observe(8);
        assert!((e.value() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn damping_monotone_and_clamped() {
        let tau = 4.0;
        let mut prev = damping_factor(tau, 0.0);
        assert_eq!(prev, 1.0);
        for obs in 1..200 {
            let d = damping_factor(tau, obs as f64);
            assert!(d <= prev + 1e-15, "not nonincreasing at {obs}");
            assert!((MIN_DAMP..=1.0).contains(&d));
            prev = d;
        }
        assert_eq!(damping_factor(tau, 1e12), MIN_DAMP);
    }

    #[test]
    fn ring_evicts_and_quantiles_are_monotone() {
        let mut r = DelayWindowRing::new(4);
        assert!(r.is_empty());
        assert_eq!(r.quantile(0.5), None);
        assert_eq!(r.adjustment(0.9), 0);
        for d in [5u64, 1, 9, 3] {
            r.push(d);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.quantile(0.0), Some(1));
        assert_eq!(r.quantile(0.5), Some(3));
        assert_eq!(r.quantile(1.0), Some(9));
        // Eviction: 5 (oldest) replaced by 7 -> window {1, 9, 3, 7}.
        r.push(7);
        assert_eq!(r.len(), 4);
        assert_eq!(r.quantile(1.0), Some(9));
        assert_eq!(r.quantile(0.0), Some(1));
        // Monotone in q.
        let mut prev = 0u64;
        for i in 0..=10 {
            let q = i as f64 / 10.0;
            let v = r.quantile(q).unwrap();
            assert!(v >= prev, "quantile not monotone at q={q}");
            prev = v;
        }
    }

    #[test]
    fn adjusted_verdict_recenters_k2() {
        // adjustment = 0 is the historical rule bit-for-bit.
        for k in 0..32u64 {
            for d in 0..32u64 {
                assert_eq!(
                    accept_delay_adjusted(k, d, 0),
                    accept_delay(k, d)
                );
            }
        }
        // Positive adjustment accepts strictly more at the boundary…
        assert!(!accept_delay(8, 5));
        assert!(accept_delay_adjusted(8, 5, 1));
        // …negative strictly less.
        assert!(accept_delay(8, 4));
        assert!(!accept_delay_adjusted(8, 4, -1));
    }

    #[test]
    fn median_adjustment_is_identically_zero() {
        let mut r = DelayWindowRing::new(16);
        for d in [3u64, 1, 4, 1, 5, 9, 2, 6] {
            r.push(d);
            assert_eq!(r.adjustment(0.5), 0);
            assert!(r.adjustment(0.9) >= 0);
            assert!(r.adjustment(0.1) <= 0);
        }
    }

    #[test]
    fn batch_controller_bounds_and_directions() {
        // Contention halves toward the floor.
        assert_eq!(next_batch(8, 1, 16, 10.0, 1.0), 4);
        // Cheap pulls grow by one toward the cap.
        assert_eq!(next_batch(8, 1, 16, 1.0, 1.0), 9);
        // Hysteresis band holds.
        assert_eq!(next_batch(8, 1, 16, 1.5, 1.0), 8);
        // Never below MIN, never above cap.
        assert_eq!(next_batch(2, 2, 16, 100.0, 1.0), 2);
        assert_eq!(next_batch(16, 1, 16, 1.0, 1.0), 16);
        // A cap below MIN still yields a legal (>= 1) batch.
        assert_eq!(next_batch(8, 4, 2, 1.0, 1.0), 2);
        // No best-pull yet (cold start) grows optimistically.
        assert_eq!(next_batch(1, 1, 8, 0.0, 0.0), 2);
    }
}
