//! Straggler / heterogeneous-worker models (paper §3.3).
//!
//! The paper simulates slow workers by giving worker `w_i` a *return
//! probability* `p_i`: after solving each subproblem the worker reports the
//! solution with probability `p_i` and silently drops it otherwise, so a
//! worker with p = 0.8 is effectively 20% slower. Two scenarios are studied:
//! a single straggler among full-speed workers (Fig 3a) and a heterogeneous
//! fleet with `p_i = theta + i/T` (Fig 3b).

use crate::util::rng::Pcg64;

/// Per-worker return probabilities.
#[derive(Debug, Clone, PartialEq)]
pub struct StragglerModel {
    pub probs: Vec<f64>,
}

impl StragglerModel {
    /// All workers at full speed.
    pub fn none(workers: usize) -> Self {
        Self {
            probs: vec![1.0; workers],
        }
    }

    /// One straggler with return probability `p`, the rest at full speed
    /// (paper Fig 3a).
    pub fn single(workers: usize, p: f64) -> Self {
        assert!(workers >= 1);
        let mut probs = vec![1.0; workers];
        probs[0] = p.clamp(0.0, 1.0);
        Self { probs }
    }

    /// Heterogeneous fleet: p_i = theta + i/T for i = 1..T, clamped to 1
    /// (paper Fig 3b).
    pub fn heterogeneous(workers: usize, theta: f64) -> Self {
        let t = workers as f64;
        let probs = (1..=workers)
            .map(|i| (theta + i as f64 / t).clamp(0.0, 1.0))
            .collect();
        Self { probs }
    }

    /// Should worker `w`'s latest solution be reported?
    #[inline]
    pub fn reports(&self, worker: usize, rng: &mut Pcg64) -> bool {
        rng.bernoulli(self.probs[worker])
    }

    /// Average worker speed (effective fraction of solves that land).
    pub fn mean_speed(&self) -> f64 {
        self.probs.iter().sum::<f64>() / self.probs.len() as f64
    }

    /// Speed of the slowest worker (what a synchronous scheme is gated on).
    pub fn min_speed(&self) -> f64 {
        self.probs.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_straggler_shape() {
        let m = StragglerModel::single(14, 0.25);
        assert_eq!(m.probs.len(), 14);
        assert_eq!(m.probs[0], 0.25);
        assert!(m.probs[1..].iter().all(|&p| p == 1.0));
        assert!((m.mean_speed() - (0.25 + 13.0) / 14.0).abs() < 1e-12);
        assert_eq!(m.min_speed(), 0.25);
    }

    #[test]
    fn heterogeneous_matches_paper_formula() {
        let t = 14usize;
        let theta = 0.3;
        let m = StragglerModel::heterogeneous(t, theta);
        for (idx, &p) in m.probs.iter().enumerate() {
            let i = idx + 1;
            let expect = (theta + i as f64 / t as f64).min(1.0);
            assert!((p - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn reports_frequency_tracks_probability() {
        let m = StragglerModel::single(3, 0.4);
        let mut rng = Pcg64::seeded(9);
        let n = 50_000;
        let hits = (0..n).filter(|_| m.reports(0, &mut rng)).count();
        assert!((hits as f64 / n as f64 - 0.4).abs() < 0.01);
        let hits1 = (0..1000).filter(|_| m.reports(1, &mut rng)).count();
        assert_eq!(hits1, 1000);
    }

    #[test]
    fn none_is_full_speed() {
        let m = StragglerModel::none(5);
        assert_eq!(m.mean_speed(), 1.0);
        assert_eq!(m.min_speed(), 1.0);
    }
}
