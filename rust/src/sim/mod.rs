//! Simulation substrates: update-delay models (paper §2.3, §3.4),
//! straggler/heterogeneous-worker models (paper §3.3), and the
//! delay-adaptive control policies (`run.adapt.*`) that feed the
//! observed-delay telemetry back into the solve loops.

pub mod adapt;
pub mod delay;
pub mod straggler;
