//! Simulation substrates: update-delay models (paper §2.3, §3.4) and
//! straggler/heterogeneous-worker models (paper §3.3).

pub mod delay;
pub mod straggler;
