//! Piecewise-constant multivariate signal generator (paper §3.1, Fig 5).
//!
//! Generates a d-dimensional signal over n time points with `segments`
//! change points *shared across dimensions* (the group structure the Group
//! Fused Lasso exploits), plus iid Gaussian observation noise.

use crate::util::rng::Pcg64;

/// A generated signal instance.
#[derive(Debug, Clone)]
pub struct Signal {
    pub d: usize,
    pub n: usize,
    /// Noise-free signal, (d x n) column-major.
    pub clean: Vec<f32>,
    /// Observed noisy signal, (d x n) column-major.
    pub noisy: Vec<f32>,
    /// Change-point positions (start indices of segments after the first).
    pub change_points: Vec<usize>,
}

/// Generate a piecewise-constant signal.
///
/// * `d`, `n` — dimensions.
/// * `segments` — number of constant segments (>= 1).
/// * `level_scale` — levels are drawn N(0, level_scale^2).
/// * `noise_sigma` — observation noise stddev.
pub fn piecewise_constant(
    d: usize,
    n: usize,
    segments: usize,
    level_scale: f64,
    noise_sigma: f64,
    seed: u64,
) -> Signal {
    assert!(segments >= 1 && segments <= n);
    let mut rng = Pcg64::new(seed, 100);
    // Choose segments-1 distinct interior change points.
    let mut cps = if segments > 1 {
        rng.subset(n - 1, segments - 1)
            .into_iter()
            .map(|i| i + 1)
            .collect::<Vec<_>>()
    } else {
        vec![]
    };
    cps.sort_unstable();

    let mut clean = vec![0.0f32; d * n];
    let mut start = 0usize;
    let mut bounds = cps.clone();
    bounds.push(n);
    for &end in &bounds {
        let level: Vec<f32> = (0..d)
            .map(|_| (rng.gaussian() * level_scale) as f32)
            .collect();
        for t in start..end {
            clean[t * d..(t + 1) * d].copy_from_slice(&level);
        }
        start = end;
    }
    let mut noisy = clean.clone();
    for v in noisy.iter_mut() {
        *v += (rng.gaussian() * noise_sigma) as f32;
    }
    Signal {
        d,
        n,
        clean,
        noisy,
        change_points: cps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = piecewise_constant(10, 100, 5, 2.0, 0.5, 7);
        let b = piecewise_constant(10, 100, 5, 2.0, 0.5, 7);
        assert_eq!(a.clean.len(), 1000);
        assert_eq!(a.noisy.len(), 1000);
        assert_eq!(a.clean, b.clean);
        assert_eq!(a.change_points.len(), 4);
    }

    #[test]
    fn clean_signal_is_piecewise_constant() {
        let s = piecewise_constant(3, 50, 4, 1.0, 0.1, 9);
        let mut jumps = 0;
        for t in 1..s.n {
            let same = (0..s.d)
                .all(|r| s.clean[t * s.d + r] == s.clean[(t - 1) * s.d + r]);
            if !same {
                jumps += 1;
                assert!(s.change_points.contains(&t), "unexpected jump at {t}");
            }
        }
        assert!(jumps <= s.change_points.len());
    }

    #[test]
    fn noise_has_expected_magnitude() {
        let s = piecewise_constant(10, 500, 3, 2.0, 0.5, 11);
        let mse: f64 = s
            .clean
            .iter()
            .zip(&s.noisy)
            .map(|(c, x)| ((c - x) as f64).powi(2))
            .sum::<f64>()
            / (s.d * s.n) as f64;
        assert!((mse.sqrt() - 0.5).abs() < 0.05, "rmse={}", mse.sqrt());
    }

    #[test]
    fn single_segment_has_no_change_points() {
        let s = piecewise_constant(2, 30, 1, 1.0, 0.0, 13);
        assert!(s.change_points.is_empty());
        assert_eq!(s.clean, s.noisy);
    }
}
