//! OCR-like synthetic sequence dataset (substitution for Taskar et al.'s
//! OCR corpus; see DESIGN.md §Substitutions).
//!
//! Each datapoint is a fixed-length sequence of "letter images": the label
//! sequence is drawn from a first-order Markov chain with a sparse, skewed
//! transition structure (mimicking English letter statistics), and each
//! letter's feature vector is its class template (a random binary pattern)
//! with salt-and-pepper pixel noise. Chain-structured dependencies make the
//! Viterbi oracle genuinely necessary, as in the paper's experiments.

use crate::util::rng::Pcg64;

/// Chain-structured sequence dataset.
#[derive(Debug, Clone)]
pub struct ChainDataset {
    /// Number of sequences n.
    pub n: usize,
    /// Number of labels K.
    pub k: usize,
    /// Feature dimension per position d.
    pub d: usize,
    /// Sequence length L (fixed).
    pub ell: usize,
    /// Features, (n x L x d) row-major.
    pub features: Vec<f32>,
    /// Labels, (n x L) row-major, values in [0, K).
    pub labels: Vec<u16>,
}

impl ChainDataset {
    #[inline]
    pub fn feature(&self, i: usize, t: usize) -> &[f32] {
        let base = (i * self.ell + t) * self.d;
        &self.features[base..base + self.d]
    }

    #[inline]
    pub fn label(&self, i: usize, t: usize) -> usize {
        self.labels[i * self.ell + t] as usize
    }

    /// Labels of sequence i as a slice.
    #[inline]
    pub fn label_seq(&self, i: usize) -> &[u16] {
        &self.labels[i * self.ell..(i + 1) * self.ell]
    }
}

/// Generate an OCR-like dataset.
///
/// * `flip_prob` — per-pixel noise probability (higher = harder problem).
pub fn generate(
    n: usize,
    k: usize,
    d: usize,
    ell: usize,
    flip_prob: f64,
    seed: u64,
) -> ChainDataset {
    let mut rng = Pcg64::new(seed, 200);
    // Class templates: random +-1 patterns, normalized to unit norm.
    let norm = (d as f64).sqrt() as f32;
    let templates: Vec<f32> = (0..k * d)
        .map(|_| if rng.bernoulli(0.5) { 1.0 / norm } else { -1.0 / norm })
        .collect();
    // Skewed Markov transition: each label strongly prefers 3 successors.
    let mut trans_pref = vec![0usize; k * 3];
    for j in 0..k {
        let succ = rng.subset(k, 3.min(k));
        for (a, &s) in trans_pref[j * 3..].iter_mut().zip(succ.iter()) {
            *a = s;
        }
    }
    let mut features = vec![0.0f32; n * ell * d];
    let mut labels = vec![0u16; n * ell];
    for i in 0..n {
        let mut y = rng.below(k);
        for t in 0..ell {
            if t > 0 {
                // 85%: one of the preferred successors; 15%: uniform.
                y = if rng.bernoulli(0.85) {
                    trans_pref[y * 3 + rng.below(3.min(k))]
                } else {
                    rng.below(k)
                };
            }
            labels[i * ell + t] = y as u16;
            let base = (i * ell + t) * d;
            for r in 0..d {
                let mut v = templates[y * d + r];
                if rng.bernoulli(flip_prob) {
                    v = -v;
                }
                features[base + r] = v;
            }
        }
    }
    ChainDataset {
        n,
        k,
        d,
        ell,
        features,
        labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let ds = generate(20, 5, 16, 7, 0.1, 1);
        assert_eq!(ds.features.len(), 20 * 7 * 16);
        assert_eq!(ds.labels.len(), 20 * 7);
        assert!(ds.labels.iter().all(|&y| (y as usize) < 5));
        assert_eq!(ds.feature(3, 2).len(), 16);
    }

    #[test]
    fn determinism() {
        let a = generate(10, 4, 8, 5, 0.2, 42);
        let b = generate(10, 4, 8, 5, 0.2, 42);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn features_are_unit_scale() {
        let ds = generate(5, 3, 64, 4, 0.0, 3);
        for i in 0..5 {
            for t in 0..4 {
                let norm: f64 = ds
                    .feature(i, t)
                    .iter()
                    .map(|&v| (v as f64) * (v as f64))
                    .sum();
                assert!((norm - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn noiseless_features_match_templates_by_label() {
        let ds = generate(8, 4, 32, 6, 0.0, 5);
        // Same label -> identical feature vector when noiseless.
        let mut seen: std::collections::HashMap<usize, Vec<f32>> =
            Default::default();
        for i in 0..8 {
            for t in 0..6 {
                let y = ds.label(i, t);
                let f = ds.feature(i, t).to_vec();
                if let Some(prev) = seen.get(&y) {
                    assert_eq!(prev, &f);
                } else {
                    seen.insert(y, f);
                }
            }
        }
    }

    #[test]
    fn markov_structure_is_skewed() {
        let ds = generate(500, 10, 4, 9, 0.0, 7);
        // Count transition distribution from label 0; should concentrate on
        // few successors rather than uniform.
        let mut counts = vec![0usize; 10];
        let mut total = 0usize;
        for i in 0..ds.n {
            for t in 1..ds.ell {
                if ds.label(i, t - 1) == 0 {
                    counts[ds.label(i, t)] += 1;
                    total += 1;
                }
            }
        }
        if total > 100 {
            let mut sorted = counts.clone();
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            let top3: usize = sorted[..3].iter().sum();
            assert!(
                top3 as f64 > 0.6 * total as f64,
                "top3={top3} total={total}"
            );
        }
    }
}
