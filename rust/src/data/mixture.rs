//! Multiclass mixture dataset on the unit sphere (paper Example 1: each
//! class has a feature vector drawn from the unit sphere; data points are
//! noisy copies, renormalized).

use crate::util::rng::Pcg64;

/// Multiclass classification dataset.
#[derive(Debug, Clone)]
pub struct MulticlassDataset {
    pub n: usize,
    pub k: usize,
    pub d: usize,
    /// Features, (n x d) row-major, each row unit-norm.
    pub features: Vec<f32>,
    /// Labels in [0, K).
    pub labels: Vec<u16>,
}

impl MulticlassDataset {
    #[inline]
    pub fn feature(&self, i: usize) -> &[f32] {
        &self.features[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn label(&self, i: usize) -> usize {
        self.labels[i] as usize
    }
}

/// Generate: K class centers uniform on the sphere; each point is its class
/// center plus isotropic noise of scale `noise`, renormalized to the sphere.
pub fn generate(
    n: usize,
    k: usize,
    d: usize,
    noise: f64,
    seed: u64,
) -> MulticlassDataset {
    let mut rng = Pcg64::new(seed, 300);
    let mut centers = vec![0.0f32; k * d];
    for c in 0..k {
        let v = rng.gaussian_vec(d);
        let nrm = crate::util::la::norm2(&v) as f32;
        for r in 0..d {
            centers[c * d + r] = v[r] / nrm;
        }
    }
    let mut features = vec![0.0f32; n * d];
    let mut labels = vec![0u16; n];
    for i in 0..n {
        let y = rng.below(k);
        labels[i] = y as u16;
        let row = &mut features[i * d..(i + 1) * d];
        for r in 0..d {
            row[r] = centers[y * d + r] + (rng.gaussian() * noise) as f32;
        }
        let nrm = crate::util::la::norm2(row) as f32;
        for v in row.iter_mut() {
            *v /= nrm;
        }
    }
    MulticlassDataset {
        n,
        k,
        d,
        features,
        labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::la;

    #[test]
    fn rows_unit_norm() {
        let ds = generate(50, 5, 20, 0.3, 1);
        for i in 0..50 {
            assert!((la::norm2(ds.feature(i)) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_noise_points_equal_centers() {
        let ds = generate(40, 4, 10, 0.0, 2);
        // All points of a class identical.
        let mut by_class: std::collections::HashMap<usize, Vec<f32>> =
            Default::default();
        for i in 0..40 {
            let y = ds.label(i);
            let f = ds.feature(i).to_vec();
            if let Some(prev) = by_class.get(&y) {
                assert_eq!(prev, &f);
            } else {
                by_class.insert(y, f);
            }
        }
    }

    #[test]
    fn labels_cover_classes() {
        let ds = generate(200, 6, 8, 0.1, 3);
        let mut seen = vec![false; 6];
        for &y in &ds.labels {
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn determinism() {
        let a = generate(30, 3, 12, 0.2, 9);
        let b = generate(30, 3, 12, 0.2, 9);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }
}
