//! Synthetic dataset generators (DESIGN.md substitutions for the paper's
//! OCR dataset and synthetic signals).

pub mod mixture;
pub mod ocr_like;
pub mod signal;
