//! AP-BCFW — Asynchronous Parallel Block-Coordinate Frank-Wolfe.
//!
//! Reproduction of Wang, Sadhanala, Dai, Neiswanger, Sra & Xing, "Parallel
//! and Distributed Block-Coordinate Frank-Wolfe Algorithms" (ICML 2016), as
//! a three-layer rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the paper's contribution: the asynchronous
//!   minibatch coordinator ([`coordinator`]), baselines ([`solver`]),
//!   delay/straggler simulation ([`sim`]), problems ([`problems`]) and the
//!   curvature analysis toolkit ([`analysis`]). The [`run`] module is the
//!   public API over all of it: `RunSpec` -> `Runner` -> `Report` with a
//!   live `Observer` stream, spanning every execution engine.
//! - **Layer 2/1 (python/, build time only)** — JAX models and Pallas
//!   kernels AOT-lowered to HLO text artifacts, executed through the PJRT
//!   CPU client by [`runtime`]. Python never runs on the solve path.
//!
//! The [`net`] module takes the delayed-update framework onto a real
//! transport: a binary wire codec (`docs/WIRE.md`) plus TCP serve/worker
//! roles, surfaced as `apbcfw serve` / `apbcfw worker`. See
//! ARCHITECTURE.md for the module map and an oracle's life from LMO to
//! wire to apply.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod analysis;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod net;
pub mod problems;
pub mod run;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod util;

/// True when a PJRT CPU client can be constructed (sanity probe).
pub fn xla_available() -> bool {
    xla::PjRtClient::cpu().is_ok()
}
