//! The unified run specification: one builder covering every execution
//! engine.
//!
//! [`RunSpec`] carries the knobs shared by all engines (tau, line search,
//! averaging, sampling, stop conditions, seed); engine-specific knobs live
//! inside the [`Engine`] variant they belong to, so a spec can never carry
//! a knob its engine would silently ignore. `RunSpec::from_config` is the
//! single path by which `--config` / `--set` layering reaches every knob.
//!
//! A spec *lowers* to the legacy per-family option structs through
//! [`RunSpec::solve_options`] / [`RunSpec::delay_options`] /
//! [`RunSpec::run_config`]; the [`Runner`](crate::run::Runner) is the only
//! production caller of those, which is what makes the lowering (and thus
//! the equivalence tests in `rust/tests/runner_equivalence.rs`) exhaustive.

use crate::coordinator::shared::SnapshotMode;
use crate::coordinator::RunConfig;
use crate::problems::PayloadMode;
use crate::sim::adapt::{AdaptSpec, BatchPolicy, DropPolicy, StepPolicy};
use crate::sim::delay::DelayModel;
use crate::sim::straggler::StragglerModel;
use crate::solver::delayed::DelayOptions;
use crate::solver::{SolveOptions, StopCond};
use crate::util::config::Config;
use anyhow::{anyhow, bail, ensure, Result};

/// Canonical engine names in registry order — the CLI `--mode` vocabulary.
pub const ENGINE_NAMES: &[&str] =
    &["seq", "batch", "delayed", "pbcd", "async", "sync", "lockfree"];

/// Worker straggler behaviour, sized at lowering time from the engine's
/// worker count — the spec can never carry a model whose arity disagrees
/// with `workers` (the historical `RunConfig::default()` footgun).
#[derive(Debug, Clone, PartialEq)]
pub enum StragglerSpec {
    /// All workers at full speed.
    None,
    /// One straggler (worker 0) with return probability `p` (Fig 3a).
    Single {
        /// Worker 0's per-round return probability.
        p: f64,
    },
    /// Heterogeneous fleet `p_i = theta + i/T` (Fig 3b).
    Heterogeneous {
        /// Base return probability theta.
        theta: f64,
    },
    /// Explicit per-worker probabilities; the arity is validated against
    /// the engine's worker count when the spec is lowered.
    Explicit(StragglerModel),
}

impl StragglerSpec {
    /// Materialize a model for `workers` workers.
    pub fn resolve(&self, workers: usize) -> Result<StragglerModel> {
        match self {
            StragglerSpec::None => Ok(StragglerModel::none(workers)),
            StragglerSpec::Single { p } => {
                Ok(StragglerModel::single(workers, *p))
            }
            StragglerSpec::Heterogeneous { theta } => {
                Ok(StragglerModel::heterogeneous(workers, *theta))
            }
            StragglerSpec::Explicit(m) => {
                ensure!(
                    m.probs.len() == workers,
                    "straggler model lists {} return probabilities but the \
                     engine runs {} workers",
                    m.probs.len(),
                    workers
                );
                Ok(m.clone())
            }
        }
    }

    /// Parse the CLI/config grammar: `none`, `single:P`, `hetero:THETA`,
    /// or an explicit comma-separated probability list `p1,p2,...`.
    pub fn parse(text: &str) -> Result<Self> {
        let text = text.trim();
        if text.is_empty() || text == "none" {
            return Ok(StragglerSpec::None);
        }
        if let Some(p) = text.strip_prefix("single:") {
            let p: f64 = p
                .trim()
                .parse()
                .map_err(|_| anyhow!("straggler single:{p:?}: bad probability"))?;
            return Ok(StragglerSpec::Single { p });
        }
        if let Some(theta) = text.strip_prefix("hetero:") {
            let theta: f64 = theta
                .trim()
                .parse()
                .map_err(|_| anyhow!("straggler hetero:{theta:?}: bad theta"))?;
            return Ok(StragglerSpec::Heterogeneous { theta });
        }
        if text.contains(',') || text.parse::<f64>().is_ok() {
            let probs = text
                .split(',')
                .map(|p| {
                    p.trim().parse::<f64>().map_err(|_| {
                        anyhow!("straggler list: bad probability {p:?}")
                    })
                })
                .collect::<Result<Vec<f64>>>()?;
            return Ok(StragglerSpec::Explicit(StragglerModel { probs }));
        }
        bail!(
            "unknown straggler spec {text:?} \
             (expected none | single:P | hetero:THETA | p1,p2,...)"
        )
    }
}

/// One of the seven execution engines, with its engine-specific knobs
/// scoped under the variant. Defaults (via the constructors below) mirror
/// the historical `SolveOptions`/`RunConfig`/`DelayOptions` defaults so
/// lowering a fresh spec reproduces legacy behaviour exactly.
#[derive(Debug, Clone, PartialEq)]
pub enum Engine {
    /// Sequential minibatch BCFW (tau = 1 is exactly BCFW) — the paper's
    /// Algorithm 1 semantics with a perfect server.
    Seq,
    /// Classical batch Frank-Wolfe (tau = n; the spec's `tau` is ignored).
    Batch,
    /// Sequential BCFW with iid oracle staleness (paper §2.3/§3.4, Fig 4).
    Delayed {
        /// The iid staleness distribution.
        model: DelayModel,
        /// Snapshot-history capacity (delays beyond it are dropped).
        history: usize,
        /// Enforce the paper's k/2 staleness acceptance rule.
        enforce_drop_rule: bool,
    },
    /// Parallel block-coordinate descent baseline (§D.4); requires a
    /// parameter-space (projectable) problem.
    Pbcd,
    /// AP-BCFW: asynchronous workers + minibatch server (Algorithms 1-2).
    Async {
        /// Worker-thread count T.
        workers: usize,
        /// Simulated straggler behaviour.
        straggler: StragglerSpec,
        /// Drop updates staler than k/2 (paper Thm 4).
        staleness_rule: bool,
        /// Harder-subproblem simulation: redo each solve m ~ U(lo, hi)
        /// times (Fig 2d).
        work_multiplier: (u32, u32),
        /// Overwrite colliding pending updates with fresher ones (paper
        /// Algorithm 1 step 1); `false` keeps the old one (ablation).
        collision_overwrite: bool,
        /// Worker->server queue capacity as a multiple of tau.
        queue_factor: usize,
        /// Shared-parameter snapshot consistency contract.
        snapshot_mode: SnapshotMode,
    },
    /// SP-BCFW: the synchronous minibatch comparator (§3.3).
    Sync {
        /// Worker-thread count T.
        workers: usize,
        /// Simulated straggler behaviour.
        straggler: StragglerSpec,
        /// Shared-parameter snapshot consistency contract.
        snapshot_mode: SnapshotMode,
    },
    /// Serverless lock-free tau = 1 variant (Algorithm 3); requires a
    /// parameter-space problem and always uses torn snapshots.
    Lockfree {
        /// Worker-thread count T.
        workers: usize,
    },
}

impl Engine {
    /// Sequential minibatch BCFW.
    pub fn sequential() -> Self {
        Engine::Seq
    }

    /// Classical batch Frank-Wolfe.
    pub fn batch() -> Self {
        Engine::Batch
    }

    /// Delayed-oracle BCFW with the default history/drop-rule knobs
    /// (matches `DelayOptions::default()`).
    pub fn delayed(model: DelayModel) -> Self {
        Engine::Delayed {
            model,
            history: 512,
            enforce_drop_rule: true,
        }
    }

    /// Parallel BCD baseline.
    pub fn pbcd() -> Self {
        Engine::Pbcd
    }

    /// Asynchronous AP-BCFW with the historical `RunConfig` defaults.
    pub fn asynchronous(workers: usize) -> Self {
        Engine::Async {
            workers,
            straggler: StragglerSpec::None,
            staleness_rule: true,
            work_multiplier: (1, 1),
            collision_overwrite: true,
            queue_factor: 4,
            snapshot_mode: SnapshotMode::Torn,
        }
    }

    /// Synchronous SP-BCFW.
    pub fn synchronous(workers: usize) -> Self {
        Engine::Sync {
            workers,
            straggler: StragglerSpec::None,
            snapshot_mode: SnapshotMode::Torn,
        }
    }

    /// Lock-free serverless variant.
    pub fn lockfree(workers: usize) -> Self {
        Engine::Lockfree { workers }
    }

    /// Canonical name (the CLI `--mode` value).
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Seq => "seq",
            Engine::Batch => "batch",
            Engine::Delayed { .. } => "delayed",
            Engine::Pbcd => "pbcd",
            Engine::Async { .. } => "async",
            Engine::Sync { .. } => "sync",
            Engine::Lockfree { .. } => "lockfree",
        }
    }

    /// Worker-thread count (1 for the sequential engines).
    pub fn workers(&self) -> usize {
        match self {
            Engine::Async { workers, .. }
            | Engine::Sync { workers, .. }
            | Engine::Lockfree { workers } => *workers,
            _ => 1,
        }
    }

    /// Whether the engine spawns worker threads.
    pub fn is_threaded(&self) -> bool {
        matches!(
            self,
            Engine::Async { .. } | Engine::Sync { .. } | Engine::Lockfree { .. }
        )
    }

    /// Whether the engine needs a parameter-space (projectable, stateless
    /// server) problem — the registry turns this into the single
    /// "parameter-space problems only" error.
    pub fn requires_parameter_space(&self) -> bool {
        matches!(self, Engine::Pbcd | Engine::Lockfree { .. })
    }

    /// Set the straggler model (async/sync engines).
    pub fn with_straggler(mut self, spec: StragglerSpec) -> Self {
        match &mut self {
            Engine::Async { straggler, .. } | Engine::Sync { straggler, .. } => {
                *straggler = spec;
            }
            _ => panic!("engine `{}` has no `straggler` knob", self.name()),
        }
        self
    }

    /// Toggle the k/2 staleness rule (async engine).
    pub fn with_staleness_rule(mut self, on: bool) -> Self {
        if let Engine::Async { staleness_rule, .. } = &mut self {
            *staleness_rule = on;
        } else {
            panic!("engine `{}` has no `staleness_rule` knob", self.name());
        }
        self
    }

    /// Set the harder-subproblem work multiplier range (async engine).
    pub fn with_work_multiplier(mut self, lo: u32, hi: u32) -> Self {
        if let Engine::Async {
            work_multiplier, ..
        } = &mut self
        {
            *work_multiplier = (lo, hi);
        } else {
            panic!("engine `{}` has no `work_multiplier` knob", self.name());
        }
        self
    }

    /// Set the collision policy (async engine).
    pub fn with_collision_overwrite(mut self, on: bool) -> Self {
        if let Engine::Async {
            collision_overwrite,
            ..
        } = &mut self
        {
            *collision_overwrite = on;
        } else {
            panic!(
                "engine `{}` has no `collision_overwrite` knob",
                self.name()
            );
        }
        self
    }

    /// Set the backpressure queue depth in multiples of tau (async engine).
    pub fn with_queue_factor(mut self, qf: usize) -> Self {
        if let Engine::Async { queue_factor, .. } = &mut self {
            *queue_factor = qf;
        } else {
            panic!("engine `{}` has no `queue_factor` knob", self.name());
        }
        self
    }

    /// Set the shared-parameter snapshot contract (async/sync engines; the
    /// lock-free engine is torn by construction).
    pub fn with_snapshot_mode(mut self, mode: SnapshotMode) -> Self {
        match &mut self {
            Engine::Async { snapshot_mode, .. }
            | Engine::Sync { snapshot_mode, .. } => {
                *snapshot_mode = mode;
            }
            _ => panic!("engine `{}` has no `snapshot_mode` knob", self.name()),
        }
        self
    }

    /// Set the delay-history capacity (delayed engine).
    pub fn with_delay_history(mut self, cap: usize) -> Self {
        if let Engine::Delayed { history, .. } = &mut self {
            *history = cap;
        } else {
            panic!("engine `{}` has no `delay_history` knob", self.name());
        }
        self
    }

    /// Toggle the delayed engine's k/2 drop rule (ablation).
    pub fn with_drop_rule(mut self, on: bool) -> Self {
        if let Engine::Delayed {
            enforce_drop_rule, ..
        } = &mut self
        {
            *enforce_drop_rule = on;
        } else {
            panic!("engine `{}` has no `drop_rule` knob", self.name());
        }
        self
    }
}

/// The unified run specification: engine + every cross-engine knob.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSpec {
    /// The execution engine, carrying its engine-scoped knobs.
    pub engine: Engine,
    /// Minibatch size tau (clamped to [1, n] by the engines; ignored by
    /// `batch`, which always uses tau = n, and `lockfree`, always 1).
    pub tau: usize,
    /// Worker fan-out batch tau_w: distinct blocks each worker solves per
    /// shared-parameter snapshot, submitted as one multi-block payload.
    /// Threaded engines only (`validate` rejects `batch > 1` elsewhere);
    /// the `Runner` additionally checks `batch * workers <= n` against the
    /// problem. 1 (the default) reproduces the historical single-block
    /// worker loop exactly. The async/lockfree workers sample their own
    /// blocks, so they realize tau_w exactly; the sync server samples only
    /// tau blocks per round, so there `batch` acts as a CAP on the
    /// per-worker chunk — the effective chunk is
    /// `min(batch, tau / workers).max(1)`, keeping every worker assigned
    /// (raise tau to at least `batch * workers` to realize the full
    /// fan-out).
    pub batch: usize,
    /// Oracle payload representation (`run.payload = auto|dense|sparse`):
    /// what workers request from `oracle_into`. `auto` (the default)
    /// resolves to each problem's natural representation; every
    /// combination is pinned bit-identical to `dense`, so this is purely a
    /// bytes/bandwidth knob — see the payload representation contract in
    /// [`crate::problems`]. Valid on every engine.
    pub payload: PayloadMode,
    /// Exact coordinate line search instead of the schedule. Not defined
    /// for `pbcd` (1/L_i steps) or `lockfree` (fixed schedule); `validate`
    /// rejects it there rather than silently ignoring it.
    pub line_search: bool,
    /// Weighted iterate averaging x-bar_k (rho_k prop. to k); the trace
    /// and `Report::param` then report the averaged iterate. Implemented
    /// by the seq/batch/delayed/async engines; `validate` rejects it for
    /// the others rather than silently ignoring it.
    pub weighted_averaging: bool,
    /// Trace sample cadence in server iterations.
    pub sample_every: usize,
    /// Compute the exact duality gap at sample points (expensive) instead
    /// of the n/tau-scaled batch-gap estimate.
    pub exact_gap: bool,
    /// Delay-adaptive control (`run.adapt.step` / `run.adapt.drop` /
    /// `run.adapt.batch`): reactive step damping, quantile-tracking drop
    /// thresholds, and self-tuning worker fan-out. The all-off default is
    /// pinned bit-identical to the non-adaptive engines; `validate`
    /// rejects a policy on an engine that could not honor it (step needs
    /// a delay-aware engine, drop needs a staleness verdict, batch acts
    /// only in the net worker loop hosted by the async engine).
    pub adapt: AdaptSpec,
    /// Stop conditions (any satisfied condition ends the solve).
    pub stop: StopCond,
    /// Seed for block sampling (and, via `run.seed`, data generation).
    pub seed: u64,
}

impl RunSpec {
    /// A spec with the shared-knob defaults (tau 1, no line search, no
    /// averaging, sample every 64 iterations, estimated gap, default stop
    /// conditions, seed 0).
    pub fn new(engine: Engine) -> Self {
        Self {
            engine,
            tau: 1,
            batch: 1,
            payload: PayloadMode::Auto,
            line_search: false,
            weighted_averaging: false,
            sample_every: 64,
            exact_gap: false,
            adapt: AdaptSpec::default(),
            stop: StopCond::default(),
            seed: 0,
        }
    }

    /// Set the delay-adaptive control policies (see the field docs).
    pub fn adapt(mut self, adapt: AdaptSpec) -> Self {
        self.adapt = adapt;
        self
    }

    /// Set the minibatch size tau.
    pub fn tau(mut self, tau: usize) -> Self {
        self.tau = tau;
        self
    }

    /// Worker fan-out batch (threaded engines only; see the field docs).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Oracle payload representation (see the field docs).
    pub fn payload(mut self, mode: PayloadMode) -> Self {
        self.payload = mode;
        self
    }

    /// Toggle exact coordinate line search.
    pub fn line_search(mut self, on: bool) -> Self {
        self.line_search = on;
        self
    }

    /// Toggle weighted iterate averaging.
    pub fn weighted_averaging(mut self, on: bool) -> Self {
        self.weighted_averaging = on;
        self
    }

    /// Set the trace sample cadence in server iterations.
    pub fn sample_every(mut self, every: usize) -> Self {
        self.sample_every = every;
        self
    }

    /// Toggle exact duality-gap evaluation at sample points.
    pub fn exact_gap(mut self, on: bool) -> Self {
        self.exact_gap = on;
        self
    }

    /// Replace the stop conditions wholesale.
    pub fn stop(mut self, stop: StopCond) -> Self {
        self.stop = stop;
        self
    }

    /// Cap the effective data passes (oracle calls / n).
    pub fn max_epochs(mut self, epochs: f64) -> Self {
        self.stop.max_epochs = epochs;
        self
    }

    /// Cap the wall-clock seconds.
    pub fn max_secs(mut self, secs: f64) -> Self {
        self.stop.max_secs = secs;
        self
    }

    /// Stop at surrogate gap <= `eps`.
    pub fn eps_gap(mut self, eps: f64) -> Self {
        self.stop.eps_gap = Some(eps);
        self
    }

    /// Stop at `f - f_star <= eps_primal`.
    pub fn target(mut self, f_star: f64, eps_primal: f64) -> Self {
        self.stop.f_star = Some(f_star);
        self.stop.eps_primal = Some(eps_primal);
        self
    }

    /// Set the solve seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Check the spec is self-consistent (worker counts, straggler arity,
    /// sample cadence, work-multiplier range). `Runner::new` calls this.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.tau >= 1, "tau must be >= 1");
        ensure!(self.batch >= 1, "batch must be >= 1");
        ensure!(
            self.batch == 1 || self.engine.is_threaded(),
            "run.batch > 1 requires a threaded engine (async, sync, \
             lockfree); engine `{}` has no worker fan-out to batch",
            self.engine.name()
        );
        ensure!(self.sample_every >= 1, "sample_every must be >= 1");
        if self.weighted_averaging {
            ensure!(
                !matches!(
                    self.engine,
                    Engine::Pbcd | Engine::Sync { .. } | Engine::Lockfree { .. }
                ),
                "engine `{}` does not implement weighted iterate averaging \
                 (supported: seq, batch, delayed, async)",
                self.engine.name()
            );
        }
        if self.line_search {
            ensure!(
                !matches!(
                    self.engine,
                    Engine::Pbcd | Engine::Lockfree { .. }
                ),
                "engine `{}` has no line search (pbcd takes 1/L_i gradient \
                 steps; lockfree uses the fixed schedule)",
                self.engine.name()
            );
        }
        if self.engine.is_threaded() {
            ensure!(
                self.engine.workers() >= 1,
                "engine `{}` needs at least one worker",
                self.engine.name()
            );
        }
        // Builder-constructed adapt policies get the same strict checks
        // `AdaptSpec::from_config` applies to config text, plus the
        // engine scoping the SCOPED_KEYS table enforces for config runs.
        if let DropPolicy::Quantile(q) = self.adapt.drop {
            ensure!(
                (0.0..=1.0).contains(&q),
                "run.adapt.drop: quantile Q must lie in [0, 1], got {q}"
            );
        }
        if let BatchPolicy::Auto { min, max } = self.adapt.batch {
            ensure!(
                min >= 1 && min <= max,
                "run.adapt.batch: auto bounds need 1 <= MIN <= MAX, \
                 got {min}:{max}"
            );
        }
        if self.adapt.step != StepPolicy::Off {
            ensure!(
                matches!(
                    self.engine,
                    Engine::Delayed { .. }
                        | Engine::Async { .. }
                        | Engine::Sync { .. }
                        | Engine::Lockfree { .. }
                ),
                "run.adapt.step has no delay signal on engine `{}` \
                 (applies to delayed, async, sync, lockfree)",
                self.engine.name()
            );
        }
        if self.adapt.drop != DropPolicy::K2 {
            ensure!(
                matches!(
                    self.engine,
                    Engine::Delayed { .. } | Engine::Async { .. }
                ),
                "run.adapt.drop needs a staleness verdict to adapt; \
                 engine `{}` has none (applies to delayed, async)",
                self.engine.name()
            );
        }
        if self.adapt.batch != BatchPolicy::Off {
            ensure!(
                matches!(self.engine, Engine::Async { .. }),
                "run.adapt.batch acts in the net worker loop hosted by \
                 the async engine; engine `{}` has no such loop",
                self.engine.name()
            );
        }
        match &self.engine {
            Engine::Async {
                workers,
                straggler,
                work_multiplier: (lo, hi),
                ..
            } => {
                straggler.resolve(*workers)?;
                ensure!(
                    *lo >= 1 && lo <= hi,
                    "work_multiplier range ({lo}, {hi}) must satisfy 1 <= lo <= hi"
                );
            }
            Engine::Sync {
                workers, straggler, ..
            } => {
                straggler.resolve(*workers)?;
            }
            Engine::Delayed { history, .. } => {
                ensure!(*history >= 1, "delay history must be >= 1");
            }
            _ => {}
        }
        Ok(())
    }

    /// Build a spec from layered config (`[run]` section). This is the one
    /// path by which `--config` files and `--set` overrides reach every
    /// knob; the CLI's convenience flags lower to the same keys.
    ///
    /// Recognized keys (all under `run.`): `mode`, `tau`, `batch`,
    /// `payload`, `workers`, `epochs`/`max_epochs`, `max_secs`, `eps_gap`,
    /// `eps_primal`, `f_star`, `line_search`, `weighted_averaging`,
    /// `sample_every`, `exact_gap`, `seed`, `straggler`, `snapshot_mode`,
    /// `queue_factor`, `staleness_rule`, `collision_overwrite`,
    /// `work_multiplier`, `delay`, `delay_history`, `drop_rule`, the
    /// delay-adaptive knobs `adapt.step`, `adapt.drop`, `adapt.batch`,
    /// and the
    /// net-transport fleet knobs `accept_timeout_secs`, `liveness_ms`,
    /// `chaos`, `shards`, `shard_id`, `wire`, `checkpoint_every`,
    /// `checkpoint_dir`, `restore` (parsed and validated by the
    /// serve role — `crate::net::NetOptions` — but scoped here so a
    /// typo'd mode fails fast).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let mode = cfg.get_or("run.mode", "seq");
        let payload_text = cfg.get_or("run.payload", "auto");
        let payload = PayloadMode::parse(&payload_text).ok_or_else(|| {
            anyhow!(
                "unknown run.payload {payload_text:?} \
                 (expected auto | dense | sparse)"
            )
        })?;
        // `run.wire` (the v4 wire-encoding knob) lives on NetOptions, not
        // the spec, but a typo'd value must fail here — the one strict
        // validation path every launcher goes through — not deep in the
        // serve role.
        crate::net::WireMode::parse(&cfg.get_or("run.wire", "exact"))?;
        // The `run.adapt.*` trio parses strictly here for the same
        // reason: a malformed quantile or batch range must fail at launch
        // on every mode, before the SCOPED_KEYS table decides whether the
        // mode can honor it at all.
        let adapt = AdaptSpec::from_config(cfg)?;
        let workers = cfg.get_usize("run.workers", 2);
        let straggler =
            StragglerSpec::parse(&cfg.get_or("run.straggler", "none"))?;
        let snapshot_mode = match cfg.get_or("run.snapshot_mode", "torn").as_str()
        {
            "torn" => SnapshotMode::Torn,
            "consistent" => SnapshotMode::Consistent,
            other => bail!(
                "unknown run.snapshot_mode {other:?} (torn | consistent)"
            ),
        };
        let engine = match mode.as_str() {
            "seq" => Engine::Seq,
            "batch" => Engine::Batch,
            "delayed" => Engine::Delayed {
                model: parse_delay(&cfg.get_or("run.delay", "none"))?,
                history: cfg.get_usize("run.delay_history", 512),
                enforce_drop_rule: cfg.get_bool("run.drop_rule", true),
            },
            "pbcd" => Engine::Pbcd,
            "async" => {
                let wm = cfg.get_usize_list("run.work_multiplier", &[1, 1]);
                ensure!(
                    matches!(wm.len(), 1 | 2),
                    "run.work_multiplier expects `m` or `lo,hi`"
                );
                let lo = wm[0] as u32;
                let hi = *wm.last().unwrap() as u32;
                Engine::Async {
                    workers,
                    straggler,
                    staleness_rule: cfg.get_bool("run.staleness_rule", true),
                    work_multiplier: (lo, hi),
                    collision_overwrite: cfg
                        .get_bool("run.collision_overwrite", true),
                    queue_factor: cfg.get_usize("run.queue_factor", 4),
                    snapshot_mode,
                }
            }
            "sync" => Engine::Sync {
                workers,
                straggler,
                snapshot_mode,
            },
            "lockfree" => {
                // The engine's own contract (coordinator/lockfree.rs) is
                // to reject consistent snapshots loudly — hogwild updates
                // are inherently torn — so an explicit request must not be
                // silently downgraded here.
                ensure!(
                    snapshot_mode == SnapshotMode::Torn,
                    "run.snapshot_mode=consistent is not available for the \
                     lockfree engine (hogwild updates are inherently torn)"
                );
                Engine::Lockfree { workers }
            }
            other => bail!(
                "unknown run.mode {other:?}; known engines: {ENGINE_NAMES:?}"
            ),
        };
        // Engine-scoped keys must not be silently ignored (the builder
        // methods panic for the same misuse): reject any that were set but
        // have no knob on the selected engine. `run.workers` and `run.tau`
        // are exempt — shared across the threaded/sequential families and
        // documented as ignored where not applicable.
        const SCOPED_KEYS: &[(&str, &[&str])] = &[
            // Worker fan-out exists only on the threaded engines.
            ("run.batch", &["async", "sync", "lockfree"]),
            ("run.straggler", &["async", "sync"]),
            // lockfree accepts only the torn default (checked above).
            ("run.snapshot_mode", &["async", "sync", "lockfree"]),
            ("run.queue_factor", &["async"]),
            ("run.staleness_rule", &["async"]),
            ("run.collision_overwrite", &["async"]),
            ("run.work_multiplier", &["async"]),
            ("run.delay", &["delayed"]),
            ("run.delay_history", &["delayed"]),
            ("run.drop_rule", &["delayed"]),
            // Delay-adaptive control: step damping needs an engine with a
            // delay signal, the drop policy needs a staleness verdict to
            // re-center, and the batch controller lives in the net worker
            // loop (hosted by the async engine, like the fleet knobs).
            ("run.adapt.step", &["delayed", "async", "sync", "lockfree"]),
            ("run.adapt.drop", &["delayed", "async"]),
            ("run.adapt.batch", &["async"]),
            // Net-transport fleet knobs: the serve role hosts the async
            // engine, so they ride on run.mode=async (ignored by the
            // in-process async engine itself; `serve` validates and
            // enforces them via `crate::net::NetOptions`).
            ("run.accept_timeout_secs", &["async"]),
            ("run.liveness_ms", &["async"]),
            ("run.chaos", &["async"]),
            ("run.shards", &["async"]),
            ("run.shard_id", &["async"]),
            ("run.wire", &["async"]),
            ("run.checkpoint_every", &["async"]),
            ("run.checkpoint_dir", &["async"]),
            ("run.restore", &["async"]),
        ];
        let mode_name = engine.name();
        for (key, modes) in SCOPED_KEYS {
            if cfg.get(key).is_some() && !modes.contains(&mode_name) {
                bail!(
                    "{key} has no effect with run.mode={mode_name} \
                     (applies to {modes:?}); remove it or change the mode"
                );
            }
        }
        let defaults = StopCond::default();
        let stop = StopCond {
            f_star: cfg
                .get("run.f_star")
                .map(|_| cfg.get_f64("run.f_star", 0.0)),
            eps_primal: cfg
                .get("run.eps_primal")
                .map(|_| cfg.get_f64("run.eps_primal", 0.0)),
            eps_gap: cfg
                .get("run.eps_gap")
                .map(|_| cfg.get_f64("run.eps_gap", 0.0)),
            max_epochs: cfg.get_f64(
                "run.epochs",
                cfg.get_f64("run.max_epochs", defaults.max_epochs),
            ),
            max_secs: cfg.get_f64("run.max_secs", defaults.max_secs),
        };
        Ok(RunSpec {
            engine,
            tau: cfg.get_usize("run.tau", 1),
            batch: cfg.get_usize("run.batch", 1),
            payload,
            line_search: cfg.get_bool("run.line_search", false),
            weighted_averaging: cfg.get_bool("run.weighted_averaging", false),
            sample_every: cfg.get_usize("run.sample_every", 64),
            exact_gap: cfg.get_bool("run.exact_gap", false),
            adapt,
            stop,
            // The historical launcher default; ProblemInstance::from_config
            // seeds data generation from the same key and default, so one
            // un-seeded `apbcfw solve` stays internally consistent and
            // reproducible against pre-Runner output.
            seed: cfg.get_u64("run.seed", 1),
        })
    }

    /// Lower the shared knobs to the sequential-solver options struct.
    pub fn solve_options(&self) -> SolveOptions {
        SolveOptions {
            tau: self.tau,
            payload: self.payload,
            line_search: self.line_search,
            weighted_averaging: self.weighted_averaging,
            sample_every: self.sample_every,
            exact_gap: self.exact_gap,
            stop: self.stop,
            seed: self.seed,
        }
    }

    /// Lower the delayed engine's knobs; `None` for other engines.
    pub fn delay_options(&self) -> Option<DelayOptions> {
        match &self.engine {
            Engine::Delayed {
                model,
                history,
                enforce_drop_rule,
            } => Some(DelayOptions {
                model: *model,
                history: *history,
                enforce_drop_rule: *enforce_drop_rule,
                adapt: self.adapt,
            }),
            _ => None,
        }
    }

    /// Lower to the threaded coordinator config. The straggler model's
    /// arity is derived from the engine's worker count here (and an
    /// explicit mismatched model is rejected). Errors for sequential
    /// engines.
    pub fn run_config(&self) -> Result<RunConfig> {
        let cfg = match &self.engine {
            Engine::Async {
                workers,
                straggler,
                staleness_rule,
                work_multiplier,
                collision_overwrite,
                queue_factor,
                snapshot_mode,
            } => RunConfig {
                workers: *workers,
                tau: self.tau,
                batch: self.batch,
                payload: self.payload,
                line_search: self.line_search,
                staleness_rule: *staleness_rule,
                straggler: straggler.resolve(*workers)?,
                work_multiplier: *work_multiplier,
                sample_every: self.sample_every,
                exact_gap: self.exact_gap,
                collision_overwrite: *collision_overwrite,
                queue_factor: *queue_factor,
                weighted_averaging: self.weighted_averaging,
                snapshot_mode: *snapshot_mode,
                adapt: self.adapt,
                stop: self.stop,
                seed: self.seed,
            },
            Engine::Sync {
                workers,
                straggler,
                snapshot_mode,
            } => RunConfig {
                workers: *workers,
                tau: self.tau,
                batch: self.batch,
                payload: self.payload,
                line_search: self.line_search,
                straggler: straggler.resolve(*workers)?,
                sample_every: self.sample_every,
                exact_gap: self.exact_gap,
                snapshot_mode: *snapshot_mode,
                adapt: self.adapt,
                stop: self.stop,
                seed: self.seed,
                ..RunConfig::default()
            },
            Engine::Lockfree { workers } => RunConfig {
                workers: *workers,
                tau: 1,
                batch: self.batch,
                payload: self.payload,
                straggler: StragglerModel::none(*workers),
                sample_every: self.sample_every,
                exact_gap: self.exact_gap,
                // The lock-free engine asserts torn snapshots (hogwild).
                snapshot_mode: SnapshotMode::Torn,
                adapt: self.adapt,
                stop: self.stop,
                seed: self.seed,
                ..RunConfig::default()
            },
            other => bail!(
                "engine `{}` is sequential; it lowers to SolveOptions, \
                 not RunConfig",
                other.name()
            ),
        };
        Ok(cfg)
    }
}

fn parse_delay(text: &str) -> Result<DelayModel> {
    let text = text.trim();
    if text.is_empty() || text == "none" {
        return Ok(DelayModel::None);
    }
    if let Some(k) = text.strip_prefix("fixed:") {
        let k: u64 = k
            .trim()
            .parse()
            .map_err(|_| anyhow!("delay fixed:{k:?}: bad integer"))?;
        return Ok(DelayModel::Fixed(k));
    }
    if let Some(kappa) = text.strip_prefix("poisson:") {
        let kappa: f64 = kappa
            .trim()
            .parse()
            .map_err(|_| anyhow!("delay poisson:{kappa:?}: bad kappa"))?;
        return Ok(DelayModel::Poisson { kappa });
    }
    if let Some(kappa) = text.strip_prefix("pareto:") {
        let kappa: f64 = kappa
            .trim()
            .parse()
            .map_err(|_| anyhow!("delay pareto:{kappa:?}: bad kappa"))?;
        return Ok(DelayModel::pareto_with_mean(kappa));
    }
    bail!(
        "unknown delay model {text:?} \
         (expected none | fixed:K | poisson:KAPPA | pareto:KAPPA)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_names_cover_all_variants() {
        let engines = [
            Engine::sequential(),
            Engine::batch(),
            Engine::delayed(DelayModel::None),
            Engine::pbcd(),
            Engine::asynchronous(2),
            Engine::synchronous(2),
            Engine::lockfree(2),
        ];
        let names: Vec<&str> = engines.iter().map(|e| e.name()).collect();
        assert_eq!(names, ENGINE_NAMES);
    }

    #[test]
    fn async_defaults_lower_to_legacy_run_config_defaults() {
        let spec = RunSpec::new(Engine::asynchronous(2)).tau(2);
        let lowered = spec.run_config().unwrap();
        let legacy = RunConfig::default();
        assert_eq!(lowered, legacy);
    }

    #[test]
    fn seq_defaults_lower_to_solve_options_fields() {
        let spec = RunSpec::new(Engine::Seq)
            .tau(3)
            .line_search(true)
            .sample_every(7)
            .exact_gap(true)
            .seed(9);
        let o = spec.solve_options();
        assert_eq!(o.tau, 3);
        assert!(o.line_search);
        assert_eq!(o.sample_every, 7);
        assert!(o.exact_gap);
        assert_eq!(o.seed, 9);
        assert!(!o.weighted_averaging);
    }

    #[test]
    fn straggler_arity_derived_from_workers() {
        for workers in [1usize, 3, 14] {
            let m = StragglerSpec::Single { p: 0.25 }
                .resolve(workers)
                .unwrap();
            assert_eq!(m.probs.len(), workers);
            assert_eq!(m.probs[0], 0.25);
        }
    }

    #[test]
    fn explicit_straggler_arity_mismatch_is_rejected() {
        let spec = RunSpec::new(
            Engine::asynchronous(3).with_straggler(StragglerSpec::Explicit(
                StragglerModel::none(2),
            )),
        );
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("straggler"), "{err}");
        assert!(spec.run_config().is_err());
    }

    #[test]
    fn straggler_spec_parse_grammar() {
        assert_eq!(StragglerSpec::parse("none").unwrap(), StragglerSpec::None);
        assert_eq!(
            StragglerSpec::parse("single:0.2").unwrap(),
            StragglerSpec::Single { p: 0.2 }
        );
        assert_eq!(
            StragglerSpec::parse("hetero:0.5").unwrap(),
            StragglerSpec::Heterogeneous { theta: 0.5 }
        );
        match StragglerSpec::parse("0.5,1.0,1.0").unwrap() {
            StragglerSpec::Explicit(m) => {
                assert_eq!(m.probs, vec![0.5, 1.0, 1.0])
            }
            other => panic!("{other:?}"),
        }
        assert!(StragglerSpec::parse("warp").is_err());
    }

    #[test]
    fn delay_parse_grammar() {
        assert_eq!(parse_delay("none").unwrap(), DelayModel::None);
        assert_eq!(parse_delay("fixed:4").unwrap(), DelayModel::Fixed(4));
        assert_eq!(
            parse_delay("poisson:10").unwrap(),
            DelayModel::Poisson { kappa: 10.0 }
        );
        assert_eq!(
            parse_delay("pareto:20").unwrap(),
            DelayModel::pareto_with_mean(20.0)
        );
        assert!(parse_delay("bogus").is_err());
    }

    #[test]
    fn from_config_reaches_every_knob() {
        let cfg = Config::parse(
            "[run]\n\
             mode = async\n\
             workers = 5\n\
             tau = 10\n\
             batch = 3\n\
             line_search = true\n\
             weighted_averaging = true\n\
             sample_every = 8\n\
             exact_gap = true\n\
             seed = 42\n\
             epochs = 12.5\n\
             max_secs = 30\n\
             eps_gap = 0.01\n\
             straggler = single:0.5\n\
             snapshot_mode = consistent\n\
             queue_factor = 16\n\
             staleness_rule = false\n\
             collision_overwrite = false\n\
             work_multiplier = 5, 15\n",
        )
        .unwrap();
        let spec = RunSpec::from_config(&cfg).unwrap();
        let expect = RunSpec::new(
            Engine::asynchronous(5)
                .with_straggler(StragglerSpec::Single { p: 0.5 })
                .with_staleness_rule(false)
                .with_work_multiplier(5, 15)
                .with_collision_overwrite(false)
                .with_queue_factor(16)
                .with_snapshot_mode(SnapshotMode::Consistent),
        )
        .tau(10)
        .batch(3)
        .line_search(true)
        .weighted_averaging(true)
        .sample_every(8)
        .exact_gap(true)
        .seed(42)
        .max_epochs(12.5)
        .max_secs(30.0)
        .eps_gap(0.01);
        assert_eq!(spec, expect);
    }

    #[test]
    fn from_config_delayed_engine() {
        let cfg = Config::parse(
            "[run]\nmode = delayed\ndelay = poisson:10\ndelay_history = 4096\n",
        )
        .unwrap();
        let spec = RunSpec::from_config(&cfg).unwrap();
        assert_eq!(
            spec.engine,
            Engine::delayed(DelayModel::Poisson { kappa: 10.0 })
                .with_delay_history(4096)
        );
        assert!(spec.delay_options().unwrap().enforce_drop_rule);
    }

    #[test]
    fn payload_mode_parses_and_lowers_everywhere() {
        for (text, mode) in [
            ("auto", PayloadMode::Auto),
            ("dense", PayloadMode::Dense),
            ("sparse", PayloadMode::Sparse),
        ] {
            let cfg = Config::parse(&format!(
                "[run]\nmode = async\nworkers = 2\npayload = {text}\n"
            ))
            .unwrap();
            let spec = RunSpec::from_config(&cfg).unwrap();
            assert_eq!(spec.payload, mode, "{text}");
            assert_eq!(spec.run_config().unwrap().payload, mode, "{text}");
            assert_eq!(spec.solve_options().payload, mode, "{text}");
        }
        // The knob is engine-agnostic: accepted on sequential modes too.
        for mode in ["seq", "batch", "delayed", "pbcd", "sync", "lockfree"] {
            let cfg = Config::parse(&format!(
                "[run]\nmode = {mode}\npayload = sparse\n{}",
                if mode == "delayed" { "delay = none\n" } else { "" }
            ))
            .unwrap();
            let spec = RunSpec::from_config(&cfg).unwrap();
            assert_eq!(spec.payload, PayloadMode::Sparse, "{mode}");
            assert!(spec.validate().is_ok(), "{mode}");
        }
        // Default stays auto (the problem's natural representation).
        let spec =
            RunSpec::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(spec.payload, PayloadMode::Auto);
    }

    #[test]
    fn from_config_rejects_invalid_payload_mode() {
        for bad in ["bogus", "Sparse", "dense,sparse", "csr"] {
            let cfg =
                Config::parse(&format!("[run]\nmode = seq\npayload = {bad}\n"))
                    .unwrap();
            let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
            assert!(err.contains("run.payload"), "{bad}: {err}");
            assert!(err.contains("auto | dense | sparse"), "{bad}: {err}");
        }
    }

    #[test]
    fn from_config_rejects_invalid_wire_mode() {
        for bad in ["bogus", "F16", "int8", "exact,q8"] {
            let cfg = Config::parse(&format!(
                "[run]\nmode = async\nwire = {bad}\n"
            ))
            .unwrap();
            let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
            assert!(err.contains("run.wire"), "{bad}: {err}");
            assert!(err.contains("exact | f16 | q8"), "{bad}: {err}");
        }
        // The valid vocabulary parses (the knob itself lives on
        // NetOptions; the spec only validates it).
        for good in ["exact", "f16", "q8"] {
            let cfg = Config::parse(&format!(
                "[run]\nmode = async\nwire = {good}\n"
            ))
            .unwrap();
            assert!(RunSpec::from_config(&cfg).is_ok(), "{good}");
        }
        // And like every net-transport knob it is scoped to async mode.
        let cfg =
            Config::parse("[run]\nmode = seq\nwire = f16\n").unwrap();
        let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("run.wire"), "{err}");
        assert!(err.contains("no effect"), "{err}");
    }

    #[test]
    fn from_config_rejects_unknown_mode() {
        let cfg = Config::parse("[run]\nmode = warp\n").unwrap();
        assert!(RunSpec::from_config(&cfg).is_err());
    }

    #[test]
    fn from_config_rejects_consistent_snapshots_for_lockfree() {
        let cfg = Config::parse(
            "[run]\nmode = lockfree\nsnapshot_mode = consistent\n",
        )
        .unwrap();
        let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("lockfree"), "{err}");
        // The torn default still parses.
        let cfg = Config::parse("[run]\nmode = lockfree\n").unwrap();
        assert!(RunSpec::from_config(&cfg).is_ok());
    }

    #[test]
    fn batch_rejected_for_sequential_engines() {
        for engine in [
            Engine::sequential(),
            Engine::batch(),
            Engine::delayed(DelayModel::None),
            Engine::pbcd(),
        ] {
            let name = engine.name();
            let err = RunSpec::new(engine)
                .batch(4)
                .validate()
                .unwrap_err()
                .to_string();
            assert!(err.contains("threaded"), "{name}: {err}");
        }
        for engine in
            [Engine::asynchronous(2), Engine::synchronous(2), Engine::lockfree(2)]
        {
            assert!(RunSpec::new(engine).batch(4).validate().is_ok());
        }
        assert!(RunSpec::new(Engine::Seq).batch(0).validate().is_err());
        // The default batch = 1 stays valid everywhere.
        assert!(RunSpec::new(Engine::Seq).validate().is_ok());
    }

    #[test]
    fn batch_lowers_into_run_config() {
        for engine in
            [Engine::asynchronous(2), Engine::synchronous(2), Engine::lockfree(2)]
        {
            let cfg = RunSpec::new(engine).batch(4).run_config().unwrap();
            assert_eq!(cfg.batch, 4);
        }
        // Default lowering carries batch = 1 (the legacy single-block
        // worker), matching RunConfig::default().
        let cfg = RunSpec::new(Engine::asynchronous(2))
            .tau(2)
            .run_config()
            .unwrap();
        assert_eq!(cfg.batch, RunConfig::default().batch);
    }

    #[test]
    fn from_config_rejects_batch_on_sequential_modes() {
        let cfg = Config::parse("[run]\nmode = seq\nbatch = 4\n").unwrap();
        let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("run.batch"), "{err}");
        // Accepted on every threaded mode.
        for mode in ["async", "sync", "lockfree"] {
            let cfg =
                Config::parse(&format!("[run]\nmode = {mode}\nbatch = 4\n"))
                    .unwrap();
            let spec = RunSpec::from_config(&cfg).unwrap();
            assert_eq!(spec.batch, 4, "{mode}");
            assert!(spec.validate().is_ok(), "{mode}");
        }
    }

    #[test]
    fn line_search_rejected_for_engines_without_it() {
        for engine in [Engine::pbcd(), Engine::lockfree(2)] {
            let name = engine.name();
            let err = RunSpec::new(engine)
                .line_search(true)
                .validate()
                .unwrap_err()
                .to_string();
            assert!(err.contains("line search"), "{name}: {err}");
        }
        assert!(RunSpec::new(Engine::synchronous(2))
            .line_search(true)
            .validate()
            .is_ok());
    }

    #[test]
    fn weighted_averaging_rejected_for_engines_without_it() {
        for engine in [Engine::pbcd(), Engine::synchronous(2), Engine::lockfree(2)]
        {
            let name = engine.name();
            let err = RunSpec::new(engine)
                .weighted_averaging(true)
                .validate()
                .unwrap_err()
                .to_string();
            assert!(err.contains("averaging"), "{name}: {err}");
        }
        for engine in [
            Engine::sequential(),
            Engine::batch(),
            Engine::delayed(DelayModel::None),
            Engine::asynchronous(2),
        ] {
            assert!(RunSpec::new(engine)
                .weighted_averaging(true)
                .validate()
                .is_ok());
        }
    }

    #[test]
    fn from_config_rejects_engine_scoped_keys_on_wrong_mode() {
        for (text, needle) in [
            ("[run]\nmode = seq\nstraggler = single:0.1\n", "straggler"),
            ("[run]\nmode = sync\nqueue_factor = 64\n", "queue_factor"),
            ("[run]\nmode = async\ndelay = poisson:5\n", "delay"),
            ("[run]\nmode = delayed\nwork_multiplier = 5, 15\n", "work"),
            // Crash-recovery knobs ride the serve role (async engine).
            ("[run]\nmode = seq\ncheckpoint_every = 50\n", "checkpoint"),
            ("[run]\nmode = sync\ncheckpoint_dir = /tmp/ck\n", "checkpoint"),
            ("[run]\nmode = delayed\nrestore = true\n", "restore"),
        ] {
            let cfg = Config::parse(text).unwrap();
            let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
            assert!(err.contains(needle), "{text}: {err}");
        }
        // Shared knobs stay accepted everywhere.
        let cfg =
            Config::parse("[run]\nmode = seq\nworkers = 4\ntau = 2\n").unwrap();
        assert!(RunSpec::from_config(&cfg).is_ok());
    }

    #[test]
    fn from_config_parses_and_lowers_adapt_knobs() {
        let cfg = Config::parse(
            "[run]\nmode = async\nadapt.step = kappa\n\
             adapt.drop = quantile:0.75\nadapt.batch = auto:2:8\n",
        )
        .unwrap();
        let spec = RunSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.adapt.step, StepPolicy::Kappa);
        assert_eq!(spec.adapt.drop, DropPolicy::Quantile(0.75));
        assert_eq!(spec.adapt.batch, BatchPolicy::Auto { min: 2, max: 8 });
        assert!(spec.validate().is_ok());
        assert_eq!(spec.run_config().unwrap().adapt, spec.adapt);
        // The delayed engine lowers step+drop into DelayOptions.
        let cfg = Config::parse(
            "[run]\nmode = delayed\ndelay = fixed:3\nadapt.step = kappa\n\
             adapt.drop = quantile:0.5\n",
        )
        .unwrap();
        let spec = RunSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.delay_options().unwrap().adapt, spec.adapt);
        // The unset default stays all-off — the bit-identity pin.
        let spec = RunSpec::from_config(&Config::parse("").unwrap()).unwrap();
        assert!(spec.adapt.is_off());
        assert_eq!(spec.adapt, AdaptSpec::default());
    }

    #[test]
    fn from_config_rejects_malformed_adapt_on_any_mode() {
        // Strict parse runs before mode scoping (the run.wire precedent):
        // a malformed value fails even on engines that ignore the knob.
        for (key, bad) in [
            ("adapt.step", "loud"),
            ("adapt.drop", "quantile:1.5"),
            ("adapt.batch", "auto:8:2"),
        ] {
            let cfg =
                Config::parse(&format!("[run]\nmode = seq\n{key} = {bad}\n"))
                    .unwrap();
            let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
            assert!(err.contains(&format!("run.{key}")), "{key}: {err}");
        }
    }

    #[test]
    fn adapt_keys_scoped_to_capable_engines() {
        for (text, needle) in [
            ("[run]\nmode = seq\nadapt.step = kappa\n", "run.adapt.step"),
            (
                "[run]\nmode = sync\nadapt.drop = quantile:0.9\n",
                "run.adapt.drop",
            ),
            (
                "[run]\nmode = lockfree\nadapt.batch = auto:1:8\n",
                "run.adapt.batch",
            ),
        ] {
            let cfg = Config::parse(text).unwrap();
            let err = RunSpec::from_config(&cfg).unwrap_err().to_string();
            assert!(err.contains(needle), "{text}: {err}");
            assert!(err.contains("no effect"), "{text}: {err}");
        }
        // Accepted on every engine with a delay signal.
        for mode in ["delayed", "async", "sync", "lockfree"] {
            let cfg = Config::parse(&format!(
                "[run]\nmode = {mode}\nadapt.step = kappa\n{}",
                if mode == "delayed" { "delay = fixed:2\n" } else { "" }
            ))
            .unwrap();
            assert!(RunSpec::from_config(&cfg).is_ok(), "{mode}");
        }
    }

    #[test]
    fn builder_adapt_policies_validated_per_engine() {
        let kappa = AdaptSpec {
            step: StepPolicy::Kappa,
            ..AdaptSpec::default()
        };
        assert!(RunSpec::new(Engine::Seq).adapt(kappa).validate().is_err());
        assert!(RunSpec::new(Engine::asynchronous(2))
            .adapt(kappa)
            .validate()
            .is_ok());
        let q = AdaptSpec {
            drop: DropPolicy::Quantile(0.9),
            ..AdaptSpec::default()
        };
        assert!(RunSpec::new(Engine::synchronous(2))
            .adapt(q)
            .validate()
            .is_err());
        assert!(RunSpec::new(Engine::delayed(DelayModel::None))
            .adapt(q)
            .validate()
            .is_ok());
        let b = AdaptSpec {
            batch: BatchPolicy::Auto { min: 1, max: 8 },
            ..AdaptSpec::default()
        };
        assert!(RunSpec::new(Engine::lockfree(2)).adapt(b).validate().is_err());
        assert!(RunSpec::new(Engine::asynchronous(2))
            .adapt(b)
            .validate()
            .is_ok());
        // Out-of-range builder values are caught like config text is.
        let badq = AdaptSpec {
            drop: DropPolicy::Quantile(1.5),
            ..AdaptSpec::default()
        };
        assert!(RunSpec::new(Engine::asynchronous(2))
            .adapt(badq)
            .validate()
            .is_err());
    }

    #[test]
    fn from_config_default_seed_matches_registry_default() {
        // One un-seeded `apbcfw solve` must use the same seed for data
        // generation (registry) and the solver (spec): the historical 1.
        let cfg = Config::parse("").unwrap();
        assert_eq!(RunSpec::from_config(&cfg).unwrap().seed, 1);
    }

    #[test]
    fn sequential_engines_refuse_run_config() {
        for engine in [Engine::Seq, Engine::Batch, Engine::Pbcd] {
            assert!(RunSpec::new(engine).run_config().is_err());
        }
    }

    #[test]
    #[should_panic(expected = "no `queue_factor` knob")]
    fn knob_on_wrong_engine_panics() {
        let _ = Engine::Seq.with_queue_factor(8);
    }
}
