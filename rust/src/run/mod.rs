//! The unified solve API: one spec -> engine -> report surface over every
//! execution engine.
//!
//! The paper's central claim is that one update rule (AP-BCFW) subsumes a
//! whole family of execution regimes — sequential, minibatched, delayed,
//! synchronous, asynchronous, serverless. This module makes the code say
//! the same thing: a [`RunSpec`] names an [`Engine`] plus the knobs shared
//! by all of them, a [`Runner`] dispatches it over any problem, and every
//! engine returns the same [`Report`]. An [`Observer`] can watch apply and
//! sample events live while the solve runs.
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use apbcfw::run::{Engine, ProblemInstance, Runner, RunSpec};
//! use apbcfw::util::config::Config;
//!
//! let cfg = Config::parse("[run]\nmode = async\nworkers = 4\ntau = 8\n")?;
//! let spec = RunSpec::from_config(&cfg)?;
//! let problem = ProblemInstance::from_config("gfl", &cfg)?;
//! let report = Runner::new(spec)?.solve(&problem)?;
//! println!("f = {:?}", report.last());
//! # Ok(())
//! # }
//! ```
//!
//! # How to add an engine
//!
//! 1. Implement the loop next to its family: sequential loops live in
//!    [`crate::solver`], threaded ones in [`crate::coordinator`]. Provide
//!    both a plain entry point and an `*_observed` variant that drives the
//!    [`Observer`] (one `on_apply` per server step, one `on_sample` per
//!    trace sample) and returns the family's result struct.
//! 2. Add a variant to [`Engine`] carrying the engine-specific knobs, a
//!    constructor with legacy-faithful defaults, and its name in
//!    [`ENGINE_NAMES`]. Extend `RunSpec::from_config` / `validate` and the
//!    lowering (`solve_options` or `run_config`).
//! 3. Dispatch it in `Runner::solve_problem_observed` (engines needing
//!    only [`Problem`](crate::problems::Problem)) or
//!    `Runner::solve_projectable_observed` (engines needing projections /
//!    a stateless server), wrapping the result with `Report::from_solve`
//!    or `Report::from_run`.
//! 4. Add a seeded equivalence test in `rust/tests/runner_equivalence.rs`
//!    pinning the `Runner` path to the legacy entry point.
//!
//! # How to add a batched engine knob
//!
//! `run.batch` (the worker fan-out tau_w) is the template for a knob whose
//! validity depends on BOTH the engine and the problem:
//!
//! 1. Put the field on [`RunSpec`] (shared across the threaded family) or
//!    on the [`Engine`] variant (single engine), with a default that
//!    reproduces legacy behaviour exactly — `batch = 1` is the historical
//!    single-block worker, pinned bit-identically in
//!    `rust/tests/batched_fanout_equivalence.rs`.
//! 2. Engine-independent validation goes in `RunSpec::validate` (`batch >
//!    1` requires a threaded engine) and `from_config`'s scoped-key table
//!    (`run.batch` rejected outright on sequential modes); the
//!    problem-dependent half lives in `Runner::check_batch` (`batch *
//!    workers <= n`), because only the dispatch site holds the problem.
//!    The engines keep a defensive assert for direct `RunConfig` callers.
//! 3. Thread the lowered value through `RunSpec::run_config` into
//!    [`crate::coordinator::RunConfig`] and consume it in the engine
//!    loops; every oracle a worker batches goes through the caller-owned
//!    [`crate::problems::Problem::Scratch`], so batching stays
//!    allocation-free by construction.
//!
//! # How to add a problem
//!
//! 1. Implement [`Problem`](crate::problems::Problem) (and
//!    [`ProjectableProblem`](crate::problems::ProjectableProblem) with
//!    `ServerState = ()` if the `pbcd`/`lockfree` engines should apply).
//! 2. Register it: a variant in [`ProblemInstance`], a name in
//!    [`PROBLEM_NAMES`], a `from_config` arm building it from its config
//!    section, and arms in the accessor/dispatch matches (the compiler
//!    walks you through them).
//!
//! Custom problems outside the registry can skip step 2 and call
//! [`Runner::solve_problem`] / [`Runner::solve_projectable`] directly.
// This module and `net/` are the crate's public API surface; undocumented
// public items are a CI failure (`cargo doc` runs with warnings denied).
#![deny(missing_docs)]

pub mod observe;
pub mod registry;
pub mod report;
pub mod spec;

pub use observe::{ChannelObserver, CollectObserver, LiveEvent, Observer};
pub use registry::{ProblemInstance, PROBLEM_NAMES};
pub use report::Report;
pub use spec::{Engine, RunSpec, StragglerSpec, ENGINE_NAMES};

use crate::coordinator::{apbcfw, lockfree, sync};
use crate::problems::{Problem, ProjectableProblem};
use crate::solver::{batch_fw, delayed, minibatch, pbcd};
use anyhow::{ensure, Result};

/// Executes a validated [`RunSpec`] against problems. The only production
/// path that lowers a spec into the engine option structs — everything
/// else (CLI, experiments, examples, services) goes through here.
pub struct Runner {
    spec: RunSpec,
}

impl Runner {
    /// Validate `spec` and wrap it. Straggler-arity mismatches, zero
    /// worker counts, and degenerate cadences are rejected here rather
    /// than panicking mid-solve.
    pub fn new(spec: RunSpec) -> Result<Runner> {
        spec.validate()?;
        Ok(Runner { spec })
    }

    /// The validated spec this runner executes.
    pub fn spec(&self) -> &RunSpec {
        &self.spec
    }

    /// Problem-dependent half of the batched fan-out validation: a spec
    /// alone can check that `batch > 1` names a threaded engine, but only
    /// here, with the problem in hand, can `batch * workers <= n` be
    /// enforced (each worker needs `batch` distinct blocks per round, and
    /// the fleet must not cover more than one full pass per snapshot).
    /// Crate-visible so the net serve role applies the identical rule.
    pub(crate) fn check_batch(&self, n: usize) -> Result<()> {
        let batch = self.spec.batch;
        if batch > 1 {
            let workers = self.spec.engine.workers();
            ensure!(
                batch * workers <= n,
                "run.batch ({batch}) x workers ({workers}) exceeds the \
                 problem's {n} blocks; lower the batch or the worker count"
            );
        }
        Ok(())
    }

    /// Solve a registered problem.
    pub fn solve(&self, problem: &ProblemInstance) -> Result<Report> {
        self.solve_observed(problem, &mut ())
    }

    /// Solve a registered problem, streaming live events to `obs`.
    pub fn solve_observed(
        &self,
        problem: &ProblemInstance,
        obs: &mut dyn Observer,
    ) -> Result<Report> {
        problem.supports(&self.spec.engine)?;
        match problem {
            ProblemInstance::Gfl(p) => self.solve_projectable_observed(p, obs),
            ProblemInstance::Qp(p) => self.solve_projectable_observed(p, obs),
            ProblemInstance::Chain(p) => self.solve_problem_observed(p, obs),
            ProblemInstance::Multiclass(p) => {
                self.solve_problem_observed(p, obs)
            }
        }
    }

    /// Solve any [`Problem`] (registered or not). Errors for the
    /// `pbcd`/`lockfree` engines, which need block projections and a
    /// stateless server — use [`Runner::solve_projectable`] for those.
    pub fn solve_problem<P: Problem>(&self, problem: &P) -> Result<Report> {
        self.solve_problem_observed(problem, &mut ())
    }

    /// Observer-streaming variant of [`Runner::solve_problem`].
    pub fn solve_problem_observed<P: Problem>(
        &self,
        problem: &P,
        obs: &mut dyn Observer,
    ) -> Result<Report> {
        let n = problem.num_blocks();
        self.check_batch(n)?;
        let name = self.spec.engine.name();
        Ok(match &self.spec.engine {
            Engine::Seq => Report::from_solve(
                name,
                n,
                minibatch::solve_observed(
                    problem,
                    &self.spec.solve_options(),
                    obs,
                ),
            ),
            Engine::Batch => Report::from_solve(
                name,
                n,
                batch_fw::solve_observed(
                    problem,
                    &self.spec.solve_options(),
                    obs,
                ),
            ),
            Engine::Delayed { .. } => Report::from_solve(
                name,
                n,
                delayed::solve_observed(
                    problem,
                    &self.spec.solve_options(),
                    &self.spec.delay_options().expect("delayed engine"),
                    obs,
                ),
            ),
            Engine::Async { .. } => Report::from_run(
                name,
                apbcfw::run_observed(problem, &self.spec.run_config()?, obs),
            ),
            Engine::Sync { .. } => Report::from_run(
                name,
                sync::run_observed(problem, &self.spec.run_config()?, obs),
            ),
            Engine::Pbcd | Engine::Lockfree { .. } => {
                return Err(registry::parameter_space_error(
                    &self.spec.engine,
                    problem.name(),
                ))
            }
        })
    }

    /// Solve any parameter-space problem (block projections + stateless
    /// server); this unlocks all seven engines.
    pub fn solve_projectable<P>(&self, problem: &P) -> Result<Report>
    where
        P: ProjectableProblem<ServerState = ()>,
    {
        self.solve_projectable_observed(problem, &mut ())
    }

    /// Observer-streaming variant of [`Runner::solve_projectable`].
    pub fn solve_projectable_observed<P>(
        &self,
        problem: &P,
        obs: &mut dyn Observer,
    ) -> Result<Report>
    where
        P: ProjectableProblem<ServerState = ()>,
    {
        let n = problem.num_blocks();
        self.check_batch(n)?;
        let name = self.spec.engine.name();
        match &self.spec.engine {
            Engine::Pbcd => Ok(Report::from_solve(
                name,
                n,
                pbcd::solve_observed(
                    problem,
                    &self.spec.solve_options(),
                    obs,
                ),
            )),
            Engine::Lockfree { .. } => Ok(Report::from_run(
                name,
                lockfree::run_observed(problem, &self.spec.run_config()?, obs),
            )),
            _ => self.solve_problem_observed(problem, obs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::signal;
    use crate::problems::gfl::Gfl;
    use crate::solver::StopCond;

    fn gfl() -> Gfl {
        let sig = signal::piecewise_constant(4, 24, 4, 2.0, 0.5, 11);
        Gfl::new(4, 24, 0.2, sig.noisy)
    }

    fn budget() -> StopCond {
        StopCond {
            max_epochs: 10.0,
            max_secs: 20.0,
            ..Default::default()
        }
    }

    #[test]
    fn runner_rejects_invalid_spec() {
        let spec = RunSpec::new(Engine::asynchronous(0));
        assert!(Runner::new(spec).is_err());
        let spec = RunSpec::new(Engine::Seq).sample_every(0);
        assert!(Runner::new(spec).is_err());
    }

    #[test]
    fn generic_path_rejects_parameter_space_engines() {
        // `solve_problem` only sees the Problem trait, so pbcd/lockfree
        // must be refused with the registry's single capability error.
        let p = gfl();
        for engine in [Engine::pbcd(), Engine::lockfree(2)] {
            let runner =
                Runner::new(RunSpec::new(engine).stop(budget())).unwrap();
            let err = runner.solve_problem(&p).unwrap_err().to_string();
            assert!(err.contains("parameter-space"), "{err}");
        }
    }

    #[test]
    fn projectable_path_runs_every_engine_on_gfl() {
        let p = gfl();
        let engines = [
            Engine::sequential(),
            Engine::batch(),
            Engine::delayed(crate::sim::delay::DelayModel::Fixed(1)),
            Engine::pbcd(),
            Engine::asynchronous(2),
            Engine::synchronous(2),
            Engine::lockfree(2),
        ];
        for engine in engines {
            let name = engine.name();
            let spec = RunSpec::new(engine)
                .tau(2)
                .sample_every(4)
                .stop(budget())
                .seed(5);
            let r = Runner::new(spec)
                .unwrap()
                .solve_projectable(&p)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(r.engine, name);
            assert!(r.last().is_some(), "{name}: empty trace");
            assert_eq!(r.param.len(), 4 * 23, "{name}");
            assert_eq!(r.raw_param.len(), 4 * 23, "{name}");
            assert!(r.oracle_calls() > 0, "{name}");
        }
    }
}
