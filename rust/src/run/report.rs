//! The unified solve report: one result surface over all engines.
//!
//! [`Report`] merges the sequential [`SolveResult`] and the threaded
//! [`RunResult`] into a single shape — trace, final/raw parameter,
//! counters, wall-clock, and seconds-per-effective-pass — so callers never
//! branch on which family of engine produced a result.

use crate::coordinator::RunResult;
use crate::solver::SolveResult;
use crate::util::metrics::{CounterSnapshot, Sample, Trace};

/// Outcome of a [`Runner`](crate::run::Runner) solve.
#[derive(Debug, Clone)]
pub struct Report {
    /// Canonical name of the engine that produced this report.
    pub engine: &'static str,
    /// Convergence trace (always ends with a final sample).
    pub trace: Trace,
    /// The reported iterate: the weighted average when averaging was on,
    /// otherwise the final raw iterate.
    pub param: Vec<f32>,
    /// The final raw (non-averaged) iterate.
    pub raw_param: Vec<f32>,
    /// Event counters (oracle calls, applied/dropped updates, collisions,
    /// server iterations). Sequential engines have zero collisions and
    /// count every non-dropped oracle call as applied.
    pub counters: CounterSnapshot,
    /// Total solve wall-clock seconds.
    pub elapsed_s: f64,
    /// Wall-clock seconds per effective data pass (n applied updates);
    /// infinite when nothing was applied.
    pub secs_per_pass: f64,
}

impl Report {
    /// Last (final) trace sample.
    pub fn last(&self) -> Option<&Sample> {
        self.trace.last()
    }

    /// Total oracle subproblems solved.
    pub fn oracle_calls(&self) -> u64 {
        self.counters.oracle_calls
    }

    /// Server iterations completed.
    pub fn iterations(&self) -> u64 {
        self.counters.iterations
    }

    /// Oracle calls whose updates were dropped (staleness/straggler).
    pub fn dropped(&self) -> u64 {
        self.counters.dropped
    }

    /// Effective data passes consumed (oracle calls / n).
    pub fn epochs(&self, num_blocks: usize) -> f64 {
        self.counters.oracle_calls as f64 / num_blocks.max(1) as f64
    }

    /// Wrap a sequential solve result.
    pub fn from_solve(
        engine: &'static str,
        num_blocks: usize,
        r: SolveResult,
    ) -> Report {
        let applied = r.oracle_calls.saturating_sub(r.dropped);
        let passes = applied as f64 / num_blocks.max(1) as f64;
        Report {
            engine,
            trace: r.trace,
            param: r.param,
            raw_param: r.raw_param,
            counters: CounterSnapshot {
                oracle_calls: r.oracle_calls,
                updates_applied: applied,
                dropped: r.dropped,
                iterations: r.iterations,
                gamma_damped_sum: r.gamma_damped_sum,
                drops_adaptive: r.drops_adaptive,
                // Everything else — collisions, channel/wire telemetry,
                // fleet membership, checkpoint counters — is populated
                // only by the threaded/serve engines; sequential solvers
                // read the parameter in place and ship nothing.
                ..CounterSnapshot::default()
            },
            elapsed_s: r.elapsed_s,
            secs_per_pass: if passes > 0.0 {
                r.elapsed_s / passes
            } else {
                f64::INFINITY
            },
        }
    }

    /// Wrap a threaded coordinator result.
    pub fn from_run(engine: &'static str, r: RunResult) -> Report {
        Report {
            engine,
            trace: r.trace,
            param: r.param,
            raw_param: r.raw_param,
            counters: r.counters,
            elapsed_s: r.elapsed_s,
            secs_per_pass: r.secs_per_pass,
        }
    }
}
