//! Problem registry: enum dispatch over the built-in problems plus the
//! single source of engine-capability errors.
//!
//! [`ProblemInstance`] collapses the launcher's problem x engine match
//! matrix: `ProblemInstance::from_config` builds any registered problem
//! from layered config, and [`Runner`](crate::run::Runner) dispatches any
//! engine over it. The "parameter-space problems only" restriction of the
//! `pbcd`/`lockfree` engines is enforced here, in one place, instead of
//! ad-hoc `bail!`s per call site.

use super::spec::Engine;
use crate::data::{mixture, ocr_like, signal};
use crate::problems::gfl::Gfl;
use crate::problems::simplex_qp::SimplexQp;
use crate::problems::ssvm::chain::ChainSsvm;
use crate::problems::ssvm::multiclass::MulticlassSsvm;
use crate::problems::Problem;
use crate::util::config::Config;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Registered problem names — the CLI `solve <problem>` vocabulary.
pub const PROBLEM_NAMES: &[&str] = &["gfl", "ssvm", "multiclass", "qp"];

/// The capability error for engines restricted to parameter-space
/// problems. Every dispatch path (registry and generic) routes through
/// this one constructor.
pub(crate) fn parameter_space_error(
    engine: &Engine,
    problem: &str,
) -> anyhow::Error {
    anyhow!(
        "engine `{}` requires a parameter-space problem (gfl/qp): \
         `{problem}` keeps per-block state on the server",
        engine.name()
    )
}

/// A built-in problem, constructed from config and solvable by any
/// supported engine through [`Runner::solve`](crate::run::Runner::solve).
pub enum ProblemInstance {
    /// Group Fused Lasso dual (`gfl`).
    Gfl(Gfl),
    /// Simplex-product QP (`qp`).
    Qp(SimplexQp),
    /// Chain-structured SVM on OCR-like data (`ssvm`).
    Chain(ChainSsvm),
    /// Multiclass SVM on mixture data (`multiclass`).
    Multiclass(MulticlassSsvm),
}

impl ProblemInstance {
    /// Build a registered problem from layered config. Section keys match
    /// the historical launcher defaults (`[gfl]`, `[ssvm]`, `[multiclass]`,
    /// `[qp]`); data generation is seeded from `run.seed`.
    pub fn from_config(name: &str, cfg: &Config) -> Result<Self> {
        let seed = cfg.get_u64("run.seed", 1);
        match name {
            "gfl" => {
                let d = cfg.get_usize("gfl.d", 10);
                let n = cfg.get_usize("gfl.n", 100);
                let lam = cfg.get_f64("gfl.lambda", 0.01);
                let segments = cfg.get_usize("gfl.segments", 6);
                let noise = cfg.get_f64("gfl.noise", 0.5);
                let sig =
                    signal::piecewise_constant(d, n, segments, 2.0, noise, seed);
                Ok(ProblemInstance::Gfl(Gfl::new(d, n, lam, sig.noisy)))
            }
            "ssvm" => {
                let n = cfg.get_usize("ssvm.n", 600);
                let k = cfg.get_usize("ssvm.k", 26);
                let d = cfg.get_usize("ssvm.d", 128);
                let ell = cfg.get_usize("ssvm.ell", 9);
                let lam = cfg.get_f64("ssvm.lambda", 1.0);
                let noise = cfg.get_f64("ssvm.noise", 0.15);
                let data =
                    Arc::new(ocr_like::generate(n, k, d, ell, noise, seed));
                Ok(ProblemInstance::Chain(ChainSsvm::new(data, lam)))
            }
            "multiclass" => {
                let n = cfg.get_usize("multiclass.n", 800);
                let k = cfg.get_usize("multiclass.k", 10);
                let d = cfg.get_usize("multiclass.d", 64);
                let lam = cfg.get_f64("multiclass.lambda", 0.01);
                let noise = cfg.get_f64("multiclass.noise", 0.05);
                let data = Arc::new(mixture::generate(n, k, d, noise, seed));
                Ok(ProblemInstance::Multiclass(MulticlassSsvm::new(data, lam)))
            }
            "qp" => {
                let n = cfg.get_usize("qp.n", 64);
                let m = cfg.get_usize("qp.m", 5);
                let mu = cfg.get_f64("qp.mu", 0.1);
                Ok(ProblemInstance::Qp(SimplexQp::random(
                    n, m, 1.0, mu, 4, seed,
                )))
            }
            other => bail!(
                "unknown problem {other:?}; registered: {PROBLEM_NAMES:?}"
            ),
        }
    }

    /// The inner problem's name (`gfl`, `simplex_qp`, `ssvm_chain`,
    /// `ssvm_multiclass`).
    pub fn name(&self) -> &'static str {
        match self {
            ProblemInstance::Gfl(p) => p.name(),
            ProblemInstance::Qp(p) => p.name(),
            ProblemInstance::Chain(p) => p.name(),
            ProblemInstance::Multiclass(p) => p.name(),
        }
    }

    /// Number of coordinate blocks n.
    pub fn num_blocks(&self) -> usize {
        match self {
            ProblemInstance::Gfl(p) => p.num_blocks(),
            ProblemInstance::Qp(p) => p.num_blocks(),
            ProblemInstance::Chain(p) => p.num_blocks(),
            ProblemInstance::Multiclass(p) => p.num_blocks(),
        }
    }

    /// Shared-parameter dimension.
    pub fn param_dim(&self) -> usize {
        match self {
            ProblemInstance::Gfl(p) => p.param_dim(),
            ProblemInstance::Qp(p) => p.param_dim(),
            ProblemInstance::Chain(p) => p.param_dim(),
            ProblemInstance::Multiclass(p) => p.param_dim(),
        }
    }

    /// Whether the problem exposes block projections + a stateless server
    /// (what the `pbcd` and `lockfree` engines need).
    pub fn is_parameter_space(&self) -> bool {
        matches!(self, ProblemInstance::Gfl(_) | ProblemInstance::Qp(_))
    }

    /// Capability check: can `engine` solve this problem?
    pub fn supports(&self, engine: &Engine) -> Result<()> {
        if engine.requires_parameter_space() && !self.is_parameter_space() {
            return Err(parameter_space_error(engine, self.name()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> Config {
        Config::parse(
            "[run]\nseed = 3\n\
             [gfl]\nd = 4\nn = 20\n\
             [qp]\nn = 12\nm = 3\n\
             [ssvm]\nn = 12\nk = 3\nd = 6\nell = 4\n\
             [multiclass]\nn = 16\nk = 3\nd = 6\n",
        )
        .unwrap()
    }

    #[test]
    fn builds_every_registered_problem() {
        let cfg = small_cfg();
        for &name in PROBLEM_NAMES {
            let p = ProblemInstance::from_config(name, &cfg).unwrap();
            assert!(p.num_blocks() > 0, "{name}");
            assert!(p.param_dim() > 0, "{name}");
        }
    }

    #[test]
    fn rejects_unknown_problem() {
        assert!(ProblemInstance::from_config("nosuch", &small_cfg()).is_err());
    }

    #[test]
    fn capability_matrix() {
        let cfg = small_cfg();
        let engines = [
            Engine::sequential(),
            Engine::batch(),
            Engine::delayed(crate::sim::delay::DelayModel::None),
            Engine::pbcd(),
            Engine::asynchronous(2),
            Engine::synchronous(2),
            Engine::lockfree(2),
        ];
        for &name in PROBLEM_NAMES {
            let p = ProblemInstance::from_config(name, &cfg).unwrap();
            for engine in &engines {
                let ok = p.supports(engine).is_ok();
                let expect = !engine.requires_parameter_space()
                    || p.is_parameter_space();
                assert_eq!(ok, expect, "{name} x {}", engine.name());
            }
        }
        // The error names the restriction.
        let ssvm = ProblemInstance::from_config("ssvm", &cfg).unwrap();
        let err = ssvm
            .supports(&Engine::lockfree(2))
            .unwrap_err()
            .to_string();
        assert!(err.contains("parameter-space"), "{err}");
    }
}
