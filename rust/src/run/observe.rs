//! Live solve observation: the streaming callback hook every engine drives.
//!
//! An [`Observer`] receives events *while the solve is in flight* — one
//! `on_apply` per server apply step and one `on_sample` per trace sample —
//! so the runtime service and future dashboards can watch convergence live
//! instead of scraping the trace post-hoc. Events are emitted from the
//! engine's monitor/server thread (never from oracle workers), so an
//! observer needs no synchronization of its own.
//!
//! The unit type `()` is the no-op observer behind the plain entry points
//! (`minibatch::solve`, `apbcfw::run`, ...); [`CollectObserver`] gathers
//! events in memory for tests and post-processing; [`ChannelObserver`]
//! streams them over an mpsc channel to a consumer on another thread.

use crate::util::metrics::Sample;
use std::sync::mpsc;

/// Callback surface for live solve events.
///
/// Both methods default to no-ops so an observer can subscribe to either
/// stream independently. Calls arrive in program order from a single
/// thread per solve.
pub trait Observer {
    /// One server apply step completed. `iter` is the server iteration
    /// count *after* the step; `gamma` is the step size actually used and
    /// `batch_gap` the applied batch's surrogate-gap mass (both NaN for
    /// engines without a Frank-Wolfe step, e.g. the PBCD baseline).
    fn on_apply(&mut self, iter: u64, gamma: f32, batch_gap: f64) {
        let _ = (iter, gamma, batch_gap);
    }

    /// One convergence sample was recorded into the trace.
    fn on_sample(&mut self, sample: &Sample) {
        let _ = sample;
    }
}

/// The no-op observer: every plain (observer-less) entry point lowers to
/// `solve_observed(.., &mut ())`.
impl Observer for () {}

/// Collects every event in memory (tests, post-hoc analysis).
#[derive(Debug, Default)]
pub struct CollectObserver {
    /// `(iter, gamma, batch_gap)` per apply step, in order.
    pub applies: Vec<(u64, f32, f64)>,
    /// Every trace sample, in order.
    pub samples: Vec<Sample>,
}

impl CollectObserver {
    /// An empty collector.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Observer for CollectObserver {
    fn on_apply(&mut self, iter: u64, gamma: f32, batch_gap: f64) {
        self.applies.push((iter, gamma, batch_gap));
    }

    fn on_sample(&mut self, sample: &Sample) {
        self.samples.push(*sample);
    }
}

/// A live solve event as shipped by [`ChannelObserver`].
#[derive(Debug, Clone, Copy)]
pub enum LiveEvent {
    /// One server apply step (see [`Observer::on_apply`]).
    Apply {
        /// Server iteration count after the step.
        iter: u64,
        /// Step size actually used.
        gamma: f32,
        /// Applied batch's surrogate-gap mass.
        batch_gap: f64,
    },
    /// One recorded convergence sample.
    Sample(Sample),
}

/// Streams events over an mpsc channel so a service/dashboard thread can
/// consume them while the solve runs. Sends are best-effort: a dropped
/// receiver never stalls or fails the solve.
pub struct ChannelObserver {
    tx: mpsc::Sender<LiveEvent>,
}

impl ChannelObserver {
    /// Create an observer and the receiving end of its event stream.
    pub fn pair() -> (Self, mpsc::Receiver<LiveEvent>) {
        let (tx, rx) = mpsc::channel();
        (Self { tx }, rx)
    }
}

impl Observer for ChannelObserver {
    fn on_apply(&mut self, iter: u64, gamma: f32, batch_gap: f64) {
        self.tx
            .send(LiveEvent::Apply {
                iter,
                gamma,
                batch_gap,
            })
            .ok();
    }

    fn on_sample(&mut self, sample: &Sample) {
        self.tx.send(LiveEvent::Sample(*sample)).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(iter: usize) -> Sample {
        Sample {
            iter,
            oracle_calls: iter as u64,
            elapsed_s: 0.0,
            objective: -1.0,
            gap: 0.5,
        }
    }

    #[test]
    fn collect_observer_records_in_order() {
        let mut obs = CollectObserver::new();
        obs.on_apply(1, 0.5, 0.1);
        obs.on_sample(&sample(1));
        obs.on_apply(2, 0.25, 0.05);
        assert_eq!(obs.applies, vec![(1, 0.5, 0.1), (2, 0.25, 0.05)]);
        assert_eq!(obs.samples.len(), 1);
        assert_eq!(obs.samples[0].iter, 1);
    }

    #[test]
    fn channel_observer_streams_and_survives_dropped_receiver() {
        let (mut obs, rx) = ChannelObserver::pair();
        obs.on_sample(&sample(3));
        match rx.recv().unwrap() {
            LiveEvent::Sample(s) => assert_eq!(s.iter, 3),
            other => panic!("{other:?}"),
        }
        drop(rx);
        // Must not panic or error once the consumer is gone.
        obs.on_apply(4, 1.0, 0.0);
        obs.on_sample(&sample(4));
    }

    #[test]
    fn unit_is_noop_observer() {
        let obs: &mut dyn Observer = &mut ();
        obs.on_apply(1, 0.1, 0.2);
        obs.on_sample(&sample(1));
    }
}
