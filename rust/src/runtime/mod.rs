//! PJRT runtime: load AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`) and execute them on the solve path.
//!
//! HLO *text* is the interchange format — jax >= 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md §2 and /opt/xla-example/README.md).
//!
//! [`ArtifactStore`] discovers artifacts via `artifacts/manifest.txt` and
//! compiles them lazily (once, cached). The `xla_backends` submodule adapts
//! compiled artifacts to the problem-layer traits ([`crate::problems`]), so
//! the coordinator can run its oracles through XLA instead of the native
//! rust implementations.

pub mod service;
pub mod xla_backends;

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled PJRT executable.
///
/// NOT `Send`: the `xla` crate's handles are `Rc`-based. Multi-threaded
/// callers must go through [`service::XlaHandle`], which pins all XLA work
/// to one service thread.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Artifact {
    /// Load an HLO-text artifact and compile it on the given client.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok(Self { exe, name })
    }

    /// Execute with literal inputs; returns the tuple elements (artifacts
    /// are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Lazily-compiling artifact registry backed by `manifest.txt`.
pub struct ArtifactStore {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// Artifact names listed in the manifest.
    names: Vec<String>,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<Artifact>>>,
}

impl ArtifactStore {
    /// Open a store over `dir` (must contain `manifest.txt`).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.txt — run `make artifacts` first",
                    dir.display()
                )
            })?;
        let names = manifest
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| {
                l.split('\t')
                    .next()
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow!("bad manifest line: {l:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            dir,
            names,
            cache: std::cell::RefCell::new(HashMap::new()),
        })
    }

    /// Artifact names available.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Whether the manifest lists `name`.
    pub fn contains(&self, name: &str) -> bool {
        self.names.iter().any(|n| n == name)
    }

    /// Get (compiling on first use) the artifact called `name`.
    pub fn get(&self, name: &str) -> Result<std::rc::Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        if !self.contains(name) {
            return Err(anyhow!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.names
            ));
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let artifact = std::rc::Rc::new(Artifact::load(&self.client, &path)?);
        self.cache
            .borrow_mut()
            .insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }
}

/// Build an f32 literal of logical shape `dims` from row-major data.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(
        expect as usize == data.len(),
        "literal_f32: {} elements vs dims {:?}",
        data.len(),
        dims
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of logical shape `dims` from row-major data.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(
        expect as usize == data.len(),
        "literal_i32: {} elements vs dims {:?}",
        data.len(),
        dims
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}
