//! Runtime services: the XLA oracle service and the live solve service.
//!
//! **XLA oracle service** — confines the (non-`Send`) PJRT client and
//! compiled executables to one dedicated thread and serves execution
//! requests over channels. The `xla` crate's handles hold `Rc`s and raw
//! pointers, so they must not cross threads. Worker threads instead hold a
//! cheap [`XlaHandle`] (Send + Sync) and submit raw tensors; the service
//! thread materializes literals, executes, and ships raw tensors back.
//! This mirrors how a real deployment would pin an accelerator context to
//! a driver thread.
//!
//! **Live solve service** — [`spawn_solve`] runs a unified-API solve
//! ([`crate::run::Runner`]) on a background thread and streams
//! [`LiveEvent`]s to the caller through the engine-driven
//! [`crate::run::Observer`] hook, so a service endpoint or dashboard can
//! watch convergence while the solve is in flight instead of scraping the
//! trace afterwards. [`spawn_serve`] does the same for the distributed
//! serve role ([`crate::net`]): the socket is bound (and the spec
//! validated) synchronously so the caller learns the listen address —
//! ephemeral port included — before any worker connects. The fleet
//! behind that address is elastic: workers may join mid-run and dead
//! ones are reaped by the liveness scan with their in-flight blocks
//! requeued ([`crate::net::NetOptions`]), so a serve session outlives
//! any individual connection.

use crate::net::BoundServer;
use crate::run::{
    ChannelObserver, LiveEvent, ProblemInstance, Report, Runner, RunSpec,
};
use crate::util::config::Config;
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::Mutex;

/// A tensor argument, row-major.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl Tensor {
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Tensor::F32(data, dims) => {
                super::literal_f32(data, dims)
            }
            Tensor::I32(data, dims) => {
                super::literal_i32(data, dims)
            }
        }
    }

    /// Extract as f32 data, erroring on type mismatch.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            Tensor::I32(..) => Err(anyhow!("tensor is i32, wanted f32")),
        }
    }

    /// Extract as i32 data.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(d, _) => Ok(d),
            Tensor::F32(..) => Err(anyhow!("tensor is f32, wanted i32")),
        }
    }
}

struct Request {
    artifact: String,
    args: Vec<Tensor>,
    resp: mpsc::Sender<Result<Vec<Tensor>>>,
}

/// Cloneable, thread-safe handle to the XLA service.
pub struct XlaHandle {
    tx: Mutex<mpsc::Sender<Request>>,
}

impl XlaHandle {
    /// Execute `artifact` with `args`; blocks until the result arrives.
    pub fn run(&self, artifact: &str, args: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (rtx, rrx) = mpsc::channel();
        {
            let tx = self.tx.lock().expect("xla handle poisoned");
            tx.send(Request {
                artifact: artifact.to_string(),
                args,
                resp: rtx,
            })
            .map_err(|_| anyhow!("xla service thread is gone"))?;
        }
        rrx.recv()
            .map_err(|_| anyhow!("xla service dropped the request"))?
    }
}

/// Spawn the service over an artifact directory. The returned handle can be
/// shared across worker threads (wrap in `Arc`). The service thread exits
/// when every handle clone is dropped.
pub fn spawn(artifact_dir: impl Into<std::path::PathBuf>) -> Result<std::sync::Arc<XlaHandle>> {
    let dir = artifact_dir.into();
    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    std::thread::Builder::new()
        .name("xla-service".into())
        .spawn(move || {
            let store = match super::ArtifactStore::open(&dir) {
                Ok(s) => {
                    ready_tx.send(Ok(())).ok();
                    s
                }
                Err(e) => {
                    ready_tx.send(Err(e)).ok();
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                let result = serve_one(&store, &req);
                req.resp.send(result).ok();
            }
        })?;
    ready_rx
        .recv()
        .map_err(|_| anyhow!("xla service died during startup"))??;
    Ok(std::sync::Arc::new(XlaHandle { tx: Mutex::new(tx) }))
}

/// A solve running on a background service thread, with its live event
/// stream. Drain [`SolveSession::events`] while it runs; [`join`]
/// (consuming) returns the final [`Report`].
///
/// [`join`]: SolveSession::join
pub struct SolveSession {
    /// Live apply/sample events, in engine order. Dropping the receiver is
    /// safe — the solve continues and only the stream stops.
    pub events: mpsc::Receiver<LiveEvent>,
    handle: std::thread::JoinHandle<Result<Report>>,
}

impl SolveSession {
    /// Block until the solve finishes and return its report.
    pub fn join(self) -> Result<Report> {
        self.handle
            .join()
            .map_err(|_| anyhow!("solve service thread panicked"))?
    }
}

/// Run `spec` against a registered problem on a dedicated thread,
/// streaming live events. The spec is validated — including the engine x
/// problem capability check — before the thread spawns, so configuration
/// errors surface synchronously instead of as a dead event stream.
pub fn spawn_solve(
    spec: RunSpec,
    problem: ProblemInstance,
) -> Result<SolveSession> {
    spec.validate()?;
    problem.supports(&spec.engine)?;
    let (mut obs, events) = ChannelObserver::pair();
    let handle = std::thread::Builder::new()
        .name("solve-service".into())
        .spawn(move || Runner::new(spec)?.solve_observed(&problem, &mut obs))?;
    Ok(SolveSession { events, handle })
}

/// A distributed serve-role solve running on a background thread: the
/// bound listen address (known before any worker connects), the live
/// event stream, and the final report via [`ServeSession::join`].
pub struct ServeSession {
    /// The resolved listen address workers should connect to.
    pub addr: std::net::SocketAddr,
    /// Live apply/sample events from the server loop.
    pub events: mpsc::Receiver<LiveEvent>,
    handle: std::thread::JoinHandle<Result<Report>>,
}

impl ServeSession {
    /// Block until the distributed solve finishes and return its report.
    pub fn join(self) -> Result<Report> {
        self.handle
            .join()
            .map_err(|_| anyhow!("serve service thread panicked"))?
    }
}

/// Bind the serve role on `addr` (validating `spec` against `problem`
/// synchronously — configuration errors surface here, not as a dead
/// stream) and run the accept + server loop on a dedicated thread,
/// streaming live events.
pub fn spawn_serve(
    spec: RunSpec,
    problem: &str,
    cfg: &Config,
    addr: &str,
) -> Result<ServeSession> {
    let server = BoundServer::bind(spec, problem, cfg, addr)?;
    let addr = server.local_addr()?;
    let (mut obs, events) = ChannelObserver::pair();
    let handle = std::thread::Builder::new()
        .name("serve-service".into())
        .spawn(move || server.run(&mut obs))?;
    Ok(ServeSession {
        addr,
        events,
        handle,
    })
}

fn serve_one(store: &super::ArtifactStore, req: &Request) -> Result<Vec<Tensor>> {
    let artifact = store.get(&req.artifact)?;
    let literals = req
        .args
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<Vec<_>>>()?;
    let outs = artifact.run(&literals)?;
    outs.into_iter()
        .map(|lit| {
            let shape = lit.array_shape()?;
            let dims: Vec<i64> = shape.dims().to_vec();
            match shape.ty() {
                xla::ElementType::F32 => {
                    Ok(Tensor::F32(lit.to_vec::<f32>()?, dims))
                }
                xla::ElementType::S32 => {
                    Ok(Tensor::I32(lit.to_vec::<i32>()?, dims))
                }
                other => Err(anyhow!("unsupported output type {other:?}")),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::run::{Engine, RunSpec};
    use crate::util::config::Config;

    #[test]
    fn solve_service_streams_events_and_reports() {
        let cfg = Config::parse(
            "[run]\nseed = 5\n[gfl]\nd = 4\nn = 24\nlambda = 0.2\n",
        )
        .unwrap();
        let problem = ProblemInstance::from_config("gfl", &cfg).unwrap();
        let spec = RunSpec::new(Engine::sequential())
            .tau(2)
            .sample_every(4)
            .exact_gap(true)
            .max_epochs(8.0)
            .max_secs(20.0)
            .seed(5);
        let session = spawn_solve(spec, problem).unwrap();
        let events: Vec<LiveEvent> = session.events.iter().collect();
        let report = session.join().unwrap();
        let samples = events
            .iter()
            .filter(|e| matches!(e, LiveEvent::Sample(_)))
            .count();
        assert_eq!(samples, report.trace.samples.len());
        assert!(
            events
                .iter()
                .any(|e| matches!(e, LiveEvent::Apply { .. })),
            "no apply events streamed"
        );
    }

    #[test]
    fn solve_service_rejects_invalid_spec_synchronously() {
        let cfg = Config::parse("[gfl]\nd = 4\nn = 24\n").unwrap();
        let problem = ProblemInstance::from_config("gfl", &cfg).unwrap();
        let spec = RunSpec::new(Engine::asynchronous(0));
        assert!(spawn_solve(spec, problem).is_err());
    }
}
