//! Adapters from compiled XLA artifacts to the problem-layer traits.
//!
//! These run the Pallas-kernel compute (lowered into the HLO artifacts) on
//! the solve path, replacing the native rust oracles. All execution goes
//! through the [`super::service::XlaHandle`] so the adapters are Send+Sync
//! and can be plugged into multi-threaded coordinator runs. Integration
//! tests assert native == XLA numerics (rust/tests/xla_integration.rs).
//!
//! Layout note: the rust GFL parameter is column-major (d x m, column t at
//! `t*d`), while the artifacts take/return row-major (d, m) arrays — the
//! adapters transpose at the boundary.

use super::service::{Tensor, XlaHandle};
use crate::data::ocr_like::ChainDataset;
use crate::problems::gfl::GflOracleBackend;
use crate::problems::ssvm::chain::ChainDecoder;
use crate::problems::ssvm::multiclass::MulticlassDecoder;
use anyhow::Result;
use std::sync::Arc;

/// Column-major (d x m, col-stride d) -> row-major (d x m) buffer.
pub fn colmajor_to_rowmajor(src: &[f32], d: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d * m];
    for t in 0..m {
        for r in 0..d {
            out[r * m + t] = src[t * d + r];
        }
    }
    out
}

/// Row-major (d x m) -> column-major buffer.
pub fn rowmajor_to_colmajor(src: &[f32], d: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d * m];
    for r in 0..d {
        for t in 0..m {
            out[t * d + r] = src[r * m + t];
        }
    }
    out
}

/// `gfl_step` artifact as a [`GflOracleBackend`].
pub struct XlaGfl {
    handle: Arc<XlaHandle>,
    name: String,
    d: usize,
    m: usize,
    /// Row-major copy of B = Y D.
    b_rm: Vec<f32>,
    lam: f32,
}

impl XlaGfl {
    /// Build over a service handle; `b_colmajor` is the problem's B.
    pub fn new(
        handle: Arc<XlaHandle>,
        d: usize,
        n: usize,
        lam: f64,
        b_colmajor: &[f32],
    ) -> Result<Self> {
        let m = n - 1;
        Ok(Self {
            handle,
            name: format!("gfl_step_d{d}_n{n}"),
            d,
            m,
            b_rm: colmajor_to_rowmajor(b_colmajor, d, m),
            lam: lam as f32,
        })
    }
}

impl GflOracleBackend for XlaGfl {
    fn step(&self, u: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>, f64) {
        let (d, m) = (self.d, self.m);
        let u_rm = colmajor_to_rowmajor(u, d, m);
        let args = vec![
            Tensor::F32(u_rm, vec![d as i64, m as i64]),
            Tensor::F32(self.b_rm.clone(), vec![d as i64, m as i64]),
            Tensor::F32(vec![self.lam], vec![1]),
        ];
        let outs = self
            .handle
            .run(&self.name, args)
            .expect("gfl_step artifact");
        let g_rm = outs[0].as_f32().unwrap();
        let s_rm = outs[1].as_f32().unwrap();
        let gap = outs[2].as_f32().unwrap().to_vec();
        let f = outs[3].as_f32().unwrap()[0] as f64;
        (
            rowmajor_to_colmajor(g_rm, d, m),
            rowmajor_to_colmajor(s_rm, d, m),
            gap,
            f,
        )
    }
}

/// `gfl_primal` artifact: primal recovery + primal objective.
pub struct XlaGflPrimal {
    handle: Arc<XlaHandle>,
    name: String,
    d: usize,
    n: usize,
    y_rm: Vec<f32>,
    lam: f32,
}

impl XlaGflPrimal {
    pub fn new(
        handle: Arc<XlaHandle>,
        d: usize,
        n: usize,
        lam: f64,
        y_colmajor: &[f32],
    ) -> Result<Self> {
        Ok(Self {
            handle,
            name: format!("gfl_primal_d{d}_n{n}"),
            d,
            n,
            y_rm: colmajor_to_rowmajor(y_colmajor, d, n),
            lam: lam as f32,
        })
    }

    /// Returns (x_colmajor, primal_objective).
    pub fn primal(&self, u_colmajor: &[f32]) -> (Vec<f32>, f64) {
        let (d, n) = (self.d, self.n);
        let m = n - 1;
        let u_rm = colmajor_to_rowmajor(u_colmajor, d, m);
        let args = vec![
            Tensor::F32(u_rm, vec![d as i64, m as i64]),
            Tensor::F32(self.y_rm.clone(), vec![d as i64, n as i64]),
            Tensor::F32(vec![self.lam], vec![1]),
        ];
        let outs = self
            .handle
            .run(&self.name, args)
            .expect("gfl_primal artifact");
        let x_rm = outs[0].as_f32().unwrap();
        let p = outs[1].as_f32().unwrap()[0] as f64;
        (rowmajor_to_colmajor(x_rm, d, n), p)
    }
}

/// `ssvm_chain` artifact (batch = 1) as a [`ChainDecoder`].
pub struct XlaChainDecoder {
    handle: Arc<XlaHandle>,
    name: String,
    data: Arc<ChainDataset>,
}

impl XlaChainDecoder {
    pub fn new(handle: Arc<XlaHandle>, data: Arc<ChainDataset>) -> Result<Self> {
        let (k, d, ell) = (data.k, data.d, data.ell);
        Ok(Self {
            handle,
            name: format!("ssvm_chain_K{k}_d{d}_L{ell}_B1"),
            data,
        })
    }
}

impl ChainDecoder for XlaChainDecoder {
    fn decode(&self, w: &[f32], i: usize, loss_weight: f32) -> (Vec<u16>, f64) {
        let (k, d, ell) = (self.data.k, self.data.d, self.data.ell);
        let wu = w[..k * d].to_vec();
        let tr = w[k * d..].to_vec();
        let xs =
            self.data.features[(i * ell) * d..(i * ell + ell) * d].to_vec();
        let ys: Vec<i32> = self
            .data
            .label_seq(i)
            .iter()
            .map(|&v| v as i32)
            .collect();
        let args = vec![
            Tensor::F32(wu, vec![k as i64, d as i64]),
            Tensor::F32(tr, vec![k as i64, k as i64]),
            Tensor::F32(xs, vec![1, ell as i64, d as i64]),
            Tensor::I32(ys, vec![1, ell as i64]),
            Tensor::F32(vec![loss_weight], vec![1]),
        ];
        let outs = self
            .handle
            .run(&self.name, args)
            .expect("ssvm_chain artifact");
        let ystar = outs[0].as_i32().unwrap();
        let h = outs[1].as_f32().unwrap()[0] as f64;
        (ystar.iter().map(|&v| v as u16).collect(), h)
    }
}

/// `ssvm_multiclass` artifact (batch = 1) as a [`MulticlassDecoder`].
pub struct XlaMulticlassDecoder {
    handle: Arc<XlaHandle>,
    name: String,
    data: Arc<crate::data::mixture::MulticlassDataset>,
}

impl XlaMulticlassDecoder {
    pub fn new(
        handle: Arc<XlaHandle>,
        data: Arc<crate::data::mixture::MulticlassDataset>,
    ) -> Result<Self> {
        let (k, d) = (data.k, data.d);
        Ok(Self {
            handle,
            name: format!("ssvm_multiclass_K{k}_d{d}_B1"),
            data,
        })
    }
}

impl MulticlassDecoder for XlaMulticlassDecoder {
    fn decode(&self, w: &[f32], i: usize, loss_weight: f32) -> (usize, f64) {
        let (k, d) = (self.data.k, self.data.d);
        let args = vec![
            Tensor::F32(w.to_vec(), vec![k as i64, d as i64]),
            Tensor::F32(self.data.feature(i).to_vec(), vec![1, d as i64]),
            Tensor::I32(vec![self.data.label(i) as i32], vec![1]),
            Tensor::F32(vec![loss_weight], vec![1]),
        ];
        let outs = self
            .handle
            .run(&self.name, args)
            .expect("ssvm_multiclass artifact");
        let ystar = outs[0].as_i32().unwrap()[0] as usize;
        let h = outs[1].as_f32().unwrap()[0] as f64;
        (ystar, h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_transposes_roundtrip() {
        let (d, m) = (3, 5);
        let col: Vec<f32> = (0..d * m).map(|v| v as f32).collect();
        let row = colmajor_to_rowmajor(&col, d, m);
        // element (r=1, t=2): col idx 2*3+1=7 -> row idx 1*5+2=7
        assert_eq!(row[m + 2], col[2 * d + 1]);
        let back = rowmajor_to_colmajor(&row, d, m);
        assert_eq!(back, col);
    }
}
