//! Lock-free shared parameter vector, packed two f32 lanes per `AtomicU64`.
//!
//! Workers read the parameter without locks while the server (or, in the
//! lock-free variant, other workers) writes it concurrently — the paper's
//! shared-memory model (Algorithm 2). Since the §Perf pass the storage is
//! *wide*: each `AtomicU64` word carries two adjacent f32 elements (low
//! lane = even index), which halves the number of atomic operations per
//! snapshot/publish versus the original one-`AtomicU32`-per-element layout.
//!
//! Read semantics are selected per instance by [`SnapshotMode`]:
//!
//! - [`SnapshotMode::Torn`] (default): element reads/writes are
//!   individually atomic, so a reader may observe a *mix* of iterations
//!   across elements. That torn-read model is precisely the
//!   inconsistent/delayed-parameter regime the paper's §2.3 analysis
//!   tolerates (each element is some recent iterate's value) — packing two
//!   lanes per word preserves it exactly, it just makes pairs of elements
//!   tear together instead of separately.
//! - [`SnapshotMode::Consistent`]: a seqlock around publishes gives
//!   readers full-vector snapshots that never interleave two publishes —
//!   the "consistent read" comparison scenario. Readers retry while a
//!   publish is in flight; writers never wait for readers.
//!
//! Partial publishes ([`SharedParam::publish_range`]) store interior words
//! wholesale and CAS the (at most two) boundary words whose other lane
//! falls outside the range, so adjacent-range publishers never trample
//! each other's lanes.

use std::sync::atomic::{fence, AtomicU64, Ordering};

/// Snapshot consistency contract for a [`SharedParam`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SnapshotMode {
    /// Element-wise atomic, whole-vector torn reads allowed (paper §2.3).
    #[default]
    Torn,
    /// Seqlock-guarded publishes; `read` returns non-torn snapshots.
    Consistent,
}

/// Wide words (u64) per 64-byte cache line — the [`ParamLayout::Padded`]
/// stride.
const WORDS_PER_LINE: usize = 8;

/// Memory layout of the wide-word storage — the NUMA/false-sharing study
/// knob (ROADMAP). Both layouts have identical read/publish semantics;
/// they trade memory footprint against cross-core cache-line contention.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParamLayout {
    /// Words packed contiguously (default): four element-pairs share each
    /// cache line, so concurrent writers of adjacent small blocks can
    /// false-share a line even though their lanes are disjoint.
    #[default]
    Packed,
    /// One wide word per 64-byte cache line (8x the footprint): adjacent
    /// blocks land on distinct lines, isolating per-block hogwild writers
    /// at the cost of 8x less spatial locality for full-vector
    /// snapshots. Opt-in for small-dim problems where false sharing
    /// dominates; the `hot_paths` bench emits the packed-vs-padded
    /// publish/read rows.
    Padded,
}

impl ParamLayout {
    #[inline]
    fn stride(self) -> usize {
        match self {
            ParamLayout::Packed => 1,
            ParamLayout::Padded => WORDS_PER_LINE,
        }
    }
}

/// Pack two adjacent f32 elements into one u64 word (low lane = even idx).
#[inline]
fn pack(lo: f32, hi: f32) -> u64 {
    (lo.to_bits() as u64) | ((hi.to_bits() as u64) << 32)
}

const LO_MASK: u64 = 0x0000_0000_FFFF_FFFF;
const HI_MASK: u64 = 0xFFFF_FFFF_0000_0000;

/// Shared parameter + iteration version counter.
pub struct SharedParam {
    /// ceil(len/2) logical words at [`ParamLayout`]-dependent stride; odd
    /// `len` leaves the last word's high lane unused.
    words: Vec<AtomicU64>,
    /// Physical distance between consecutive logical words (1 packed,
    /// [`WORDS_PER_LINE`] padded).
    stride: usize,
    len: usize,
    version: AtomicU64,
    /// Seqlock word (odd = publish in flight); used in `Consistent` mode.
    seq: AtomicU64,
    mode: SnapshotMode,
    layout: ParamLayout,
}

impl SharedParam {
    pub fn new(init: &[f32]) -> Self {
        Self::with_mode(init, SnapshotMode::Torn)
    }

    /// Construct with an explicit snapshot consistency mode (packed
    /// layout).
    pub fn with_mode(init: &[f32], mode: SnapshotMode) -> Self {
        Self::with_layout(init, mode, ParamLayout::Packed)
    }

    /// Construct with explicit snapshot consistency mode AND storage
    /// layout.
    pub fn with_layout(
        init: &[f32],
        mode: SnapshotMode,
        layout: ParamLayout,
    ) -> Self {
        let len = init.len();
        let stride = layout.stride();
        let nwords = len.div_ceil(2);
        let mut words = Vec::with_capacity(nwords * stride);
        let mut push_word = |bits: u64| {
            words.push(AtomicU64::new(bits));
            for _ in 1..stride {
                words.push(AtomicU64::new(0));
            }
        };
        let mut chunks = init.chunks_exact(2);
        for pair in &mut chunks {
            push_word(pack(pair[0], pair[1]));
        }
        if let [last] = chunks.remainder() {
            push_word(pack(*last, 0.0));
        }
        Self {
            words,
            stride,
            len,
            version: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            mode,
            layout,
        }
    }

    /// The atomic word holding elements `2*wi` and `2*wi + 1`.
    #[inline]
    fn word(&self, wi: usize) -> &AtomicU64 {
        &self.words[wi * self.stride]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The configured snapshot mode.
    pub fn mode(&self) -> SnapshotMode {
        self.mode
    }

    /// The configured storage layout.
    pub fn layout(&self) -> ParamLayout {
        self.layout
    }

    /// Current server iteration.
    #[inline]
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    // --- seqlock (Consistent mode only) ---------------------------------

    /// Acquire the writer side of the seqlock (spin on a concurrent
    /// publish; uncontended in the single-server runtimes).
    fn seq_lock(&self) {
        loop {
            let s = self.seq.load(Ordering::Relaxed);
            if s & 1 == 0
                && self
                    .seq
                    .compare_exchange_weak(
                        s,
                        s + 1,
                        Ordering::Acquire,
                        Ordering::Relaxed,
                    )
                    .is_ok()
            {
                return;
            }
            std::hint::spin_loop();
        }
    }

    fn seq_unlock(&self) {
        self.seq.fetch_add(1, Ordering::Release);
    }

    // --- reads ----------------------------------------------------------

    /// Raw wide-word snapshot (no consistency loop).
    fn read_words(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.len);
        let full = self.len / 2;
        for wi in 0..full {
            let bits = self.word(wi).load(Ordering::Relaxed);
            out.push(f32::from_bits(bits as u32));
            out.push(f32::from_bits((bits >> 32) as u32));
        }
        if self.len % 2 == 1 {
            let bits = self.word(full).load(Ordering::Relaxed);
            out.push(f32::from_bits(bits as u32));
        }
    }

    /// Snapshot the whole parameter into `out` (cleared; capacity reused).
    ///
    /// `Torn` mode: one relaxed load per word, elements may mix
    /// iterations. `Consistent` mode: retries until a publish-free
    /// interval is observed, so the snapshot never interleaves publishes.
    pub fn read(&self, out: &mut Vec<f32>) {
        match self.mode {
            SnapshotMode::Torn => self.read_words(out),
            SnapshotMode::Consistent => loop {
                let s1 = self.seq.load(Ordering::Acquire);
                if s1 & 1 == 1 {
                    std::hint::spin_loop();
                    continue;
                }
                self.read_words(out);
                // Order the word loads before the re-check of seq.
                fence(Ordering::Acquire);
                if self.seq.load(Ordering::Relaxed) == s1 {
                    return;
                }
            },
        }
    }

    /// Convenience allocating read.
    pub fn read_vec(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.len);
        self.read(&mut v);
        v
    }

    // --- writes ---------------------------------------------------------

    /// Publish new values (wide-word atomic stores) and bump the version.
    pub fn publish(&self, values: &[f32], new_version: u64) {
        debug_assert_eq!(values.len(), self.len);
        let guard = self.mode == SnapshotMode::Consistent;
        if guard {
            self.seq_lock();
        }
        let mut chunks = values.chunks_exact(2);
        for (wi, pair) in (&mut chunks).enumerate() {
            self.word(wi).store(pack(pair[0], pair[1]), Ordering::Relaxed);
        }
        if let [last] = chunks.remainder() {
            // Odd tail: the high lane is unused, safe to overwrite whole.
            self.word(self.len / 2)
                .store(pack(*last, 0.0), Ordering::Relaxed);
        }
        if guard {
            self.seq_unlock();
        }
        self.version.store(new_version, Ordering::Release);
    }

    /// Publish only a sub-range (for sparse block updates). Interior words
    /// are stored wholesale; a boundary word whose other lane lies outside
    /// the range is updated lane-wise with CAS, so concurrent publishers
    /// of adjacent ranges cannot clobber each other.
    pub fn publish_range(&self, offset: usize, values: &[f32]) {
        let guard = self.mode == SnapshotMode::Consistent;
        if guard {
            self.seq_lock();
        }
        self.publish_range_unguarded(offset, values);
        if guard {
            self.seq_unlock();
        }
    }

    /// Publish several disjoint sub-ranges of `master` as ONE consistency
    /// section: in `Consistent` mode a reader sees all of them or none
    /// (one server batch must never appear half-applied). Bumps the
    /// version once.
    pub fn publish_ranges(
        &self,
        ranges: &[std::ops::Range<usize>],
        master: &[f32],
    ) -> u64 {
        debug_assert_eq!(master.len(), self.len);
        let guard = self.mode == SnapshotMode::Consistent;
        if guard {
            self.seq_lock();
        }
        for r in ranges {
            self.publish_range_unguarded(r.start, &master[r.clone()]);
        }
        if guard {
            self.seq_unlock();
        }
        self.bump_version()
    }

    fn publish_range_unguarded(&self, offset: usize, values: &[f32]) {
        let end = offset + values.len();
        assert!(end <= self.len, "publish_range out of bounds");
        if values.is_empty() {
            return;
        }
        let mut i = offset;
        let mut v = 0usize;
        if i % 2 == 1 {
            // Leading partial word: only its high lane is ours.
            self.store_lane(i, values[v]);
            i += 1;
            v += 1;
        }
        while i + 1 < end {
            self.word(i / 2)
                .store(pack(values[v], values[v + 1]), Ordering::Relaxed);
            i += 2;
            v += 2;
        }
        if i < end {
            // Trailing partial word: only its low lane is ours.
            self.store_lane(i, values[v]);
        }
    }

    /// CAS-update the single lane holding element `idx`.
    fn store_lane(&self, idx: usize, val: f32) {
        let cell = self.word(idx / 2);
        let bits = val.to_bits() as u64;
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = if idx % 2 == 0 {
                (cur & HI_MASK) | bits
            } else {
                (cur & LO_MASK) | (bits << 32)
            };
            match cell.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Bump the version counter by one, returning the *previous* value.
    pub fn bump_version(&self) -> u64 {
        self.version.fetch_add(1, Ordering::AcqRel)
    }

    /// Atomically add `delta` to element `idx` (lock-free variant's
    /// update). CAS on the containing word; the sibling lane rides along
    /// unchanged, so two threads updating the two lanes of one word
    /// serialize through CAS retries but never lose an update.
    ///
    /// In `Consistent` mode the update runs inside the seqlock so the
    /// never-torn read guarantee holds against hogwild writers too (at
    /// the cost of serializing them — the hogwild runtime asserts `Torn`).
    pub fn fetch_add_f32(&self, idx: usize, delta: f32) {
        assert!(idx < self.len);
        let guard = self.mode == SnapshotMode::Consistent;
        if guard {
            self.seq_lock();
        }
        self.fetch_add_f32_unguarded(idx, delta);
        if guard {
            self.seq_unlock();
        }
    }

    fn fetch_add_f32_unguarded(&self, idx: usize, delta: f32) {
        let cell = self.word(idx / 2);
        let hi_lane = idx % 2 == 1;
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let old_bits = if hi_lane { (cur >> 32) as u32 } else { cur as u32 };
            let new_bits =
                (f32::from_bits(old_bits) + delta).to_bits() as u64;
            let new = if hi_lane {
                (cur & LO_MASK) | (new_bits << 32)
            } else {
                (cur & HI_MASK) | new_bits
            };
            match cell.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn read_write_roundtrip() {
        let sp = SharedParam::new(&[1.0, -2.5, 3.25]);
        assert_eq!(sp.read_vec(), vec![1.0, -2.5, 3.25]);
        sp.publish(&[4.0, 5.0, 6.0], 3);
        assert_eq!(sp.read_vec(), vec![4.0, 5.0, 6.0]);
        assert_eq!(sp.version(), 3);
    }

    #[test]
    fn roundtrip_even_and_odd_lengths() {
        for len in [0usize, 1, 2, 3, 4, 5, 8, 9, 33] {
            let init: Vec<f32> = (0..len).map(|i| i as f32 - 2.5).collect();
            let sp = SharedParam::new(&init);
            assert_eq!(sp.len(), len);
            assert_eq!(sp.read_vec(), init, "len={len}");
            let flip: Vec<f32> = init.iter().map(|v| -v).collect();
            sp.publish(&flip, 1);
            assert_eq!(sp.read_vec(), flip, "len={len}");
        }
    }

    #[test]
    fn publish_range_is_partial() {
        let sp = SharedParam::new(&[0.0; 5]);
        sp.publish_range(2, &[7.0, 8.0]);
        assert_eq!(sp.read_vec(), vec![0.0, 0.0, 7.0, 8.0, 0.0]);
    }

    #[test]
    fn publish_range_odd_offsets_preserve_neighbors() {
        // Ranges starting/ending mid-word must not clobber the sibling
        // lane of a boundary word.
        let init: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let sp = SharedParam::new(&init);
        sp.publish_range(1, &[-1.0, -2.0, -3.0]); // elements 1..4
        assert_eq!(
            sp.read_vec(),
            vec![0.0, -1.0, -2.0, -3.0, 4.0, 5.0, 6.0, 7.0, 8.0]
        );
        sp.publish_range(8, &[-8.0]); // odd tail element
        assert_eq!(sp.read_vec()[8], -8.0);
        assert_eq!(sp.read_vec()[7], 7.0);
        sp.publish_range(3, &[30.0, 40.0]); // hi lane of word 1 + lo of 2
        let v = sp.read_vec();
        assert_eq!(v[3], 30.0);
        assert_eq!(v[4], 40.0);
        assert_eq!(v[2], -2.0);
        assert_eq!(v[5], 5.0);
    }

    #[test]
    fn concurrent_fetch_add_sums_exactly() {
        // Both lanes of one word under contention: no lost updates.
        let sp = Arc::new(SharedParam::new(&[0.0f32, 0.0f32]));
        let mut handles = vec![];
        for t in 0..8 {
            let sp = Arc::clone(&sp);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    sp.fetch_add_f32(t % 2, 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 40k per lane stays exactly representable in f32.
        assert_eq!(sp.read_vec(), vec![40_000.0, 40_000.0]);
    }

    #[test]
    fn version_bump_is_sequential() {
        let sp = SharedParam::new(&[0.0]);
        assert_eq!(sp.bump_version(), 0);
        assert_eq!(sp.bump_version(), 1);
        assert_eq!(sp.version(), 2);
    }

    #[test]
    fn publish_ranges_is_one_section_and_bumps_version() {
        let init = vec![0.0f32; 7];
        let sp = SharedParam::with_mode(&init, SnapshotMode::Consistent);
        let master: Vec<f32> = (0..7).map(|i| i as f32 + 1.0).collect();
        let prev = sp.publish_ranges(&[1..3, 5..7], &master);
        assert_eq!(prev, 0);
        assert_eq!(sp.version(), 1);
        assert_eq!(
            sp.read_vec(),
            vec![0.0, 2.0, 3.0, 0.0, 0.0, 6.0, 7.0]
        );
    }

    #[test]
    fn padded_layout_roundtrips_all_operations() {
        for len in [0usize, 1, 2, 3, 5, 8, 9, 33] {
            let init: Vec<f32> = (0..len).map(|i| i as f32 - 2.5).collect();
            let sp = SharedParam::with_layout(
                &init,
                SnapshotMode::Torn,
                ParamLayout::Padded,
            );
            assert_eq!(sp.layout(), ParamLayout::Padded);
            assert_eq!(sp.read_vec(), init, "len={len}");
            let flip: Vec<f32> = init.iter().map(|v| -v).collect();
            sp.publish(&flip, 1);
            assert_eq!(sp.read_vec(), flip, "publish len={len}");
            if len >= 4 {
                sp.publish_range(1, &[7.0, 8.0, 9.0]);
                let v = sp.read_vec();
                assert_eq!(&v[1..4], &[7.0, 8.0, 9.0], "range len={len}");
                assert_eq!(v[0], flip[0], "neighbor lane len={len}");
            }
            if len >= 1 {
                sp.fetch_add_f32(len - 1, 2.0);
            }
        }
    }

    #[test]
    fn padded_layout_consistent_mode_roundtrip() {
        let sp = SharedParam::with_layout(
            &[1.0, 2.0, 3.0],
            SnapshotMode::Consistent,
            ParamLayout::Padded,
        );
        sp.publish(&[4.0, 5.0, 6.0], 1);
        assert_eq!(sp.read_vec(), vec![4.0, 5.0, 6.0]);
        sp.publish_range(1, &[9.0]);
        assert_eq!(sp.read_vec(), vec![4.0, 9.0, 6.0]);
    }

    #[test]
    fn consistent_mode_roundtrip() {
        let sp = SharedParam::with_mode(&[1.0, 2.0, 3.0], SnapshotMode::Consistent);
        assert_eq!(sp.mode(), SnapshotMode::Consistent);
        sp.publish(&[4.0, 5.0, 6.0], 1);
        assert_eq!(sp.read_vec(), vec![4.0, 5.0, 6.0]);
        sp.publish_range(1, &[9.0]);
        assert_eq!(sp.read_vec(), vec![4.0, 9.0, 6.0]);
    }
}
