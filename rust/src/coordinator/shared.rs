//! Lock-free shared parameter vector.
//!
//! Workers read the parameter without locks while the server (or, in the
//! lock-free variant, other workers) writes it concurrently — the paper's
//! shared-memory model (Algorithm 2). f32 values live in `AtomicU32` bit
//! patterns; element reads/writes are individually atomic, so a reader may
//! observe a *mix* of iterations across elements. That torn-read model is
//! precisely the inconsistent/delayed-parameter regime the paper's §2.3
//! analysis tolerates (each element is some recent iterate's value).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Shared parameter + iteration version counter.
pub struct SharedParam {
    bits: Vec<AtomicU32>,
    version: AtomicU64,
}

impl SharedParam {
    pub fn new(init: &[f32]) -> Self {
        Self {
            bits: init
                .iter()
                .map(|v| AtomicU32::new(v.to_bits()))
                .collect(),
            version: AtomicU64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.bits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// Current server iteration.
    #[inline]
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Snapshot the whole parameter (element-wise atomic).
    pub fn read(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend(
            self.bits
                .iter()
                .map(|b| f32::from_bits(b.load(Ordering::Relaxed))),
        );
    }

    /// Convenience allocating read.
    pub fn read_vec(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.bits.len());
        self.read(&mut v);
        v
    }

    /// Publish new values (element-wise atomic stores) and bump the version.
    pub fn publish(&self, values: &[f32], new_version: u64) {
        debug_assert_eq!(values.len(), self.bits.len());
        for (b, v) in self.bits.iter().zip(values.iter()) {
            b.store(v.to_bits(), Ordering::Relaxed);
        }
        self.version.store(new_version, Ordering::Release);
    }

    /// Publish only a sub-range (for sparse block updates).
    pub fn publish_range(&self, offset: usize, values: &[f32]) {
        for (b, v) in self.bits[offset..offset + values.len()]
            .iter()
            .zip(values.iter())
        {
            b.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Bump the version counter by one, returning the *previous* value.
    pub fn bump_version(&self) -> u64 {
        self.version.fetch_add(1, Ordering::AcqRel)
    }

    /// Atomically add `delta` to element `idx` (lock-free variant's update).
    pub fn fetch_add_f32(&self, idx: usize, delta: f32) {
        let cell = &self.bits[idx];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f32::from_bits(cur) + delta).to_bits();
            match cell.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn read_write_roundtrip() {
        let sp = SharedParam::new(&[1.0, -2.5, 3.25]);
        assert_eq!(sp.read_vec(), vec![1.0, -2.5, 3.25]);
        sp.publish(&[4.0, 5.0, 6.0], 3);
        assert_eq!(sp.read_vec(), vec![4.0, 5.0, 6.0]);
        assert_eq!(sp.version(), 3);
    }

    #[test]
    fn publish_range_is_partial() {
        let sp = SharedParam::new(&[0.0; 5]);
        sp.publish_range(2, &[7.0, 8.0]);
        assert_eq!(sp.read_vec(), vec![0.0, 0.0, 7.0, 8.0, 0.0]);
    }

    #[test]
    fn concurrent_fetch_add_sums_exactly() {
        let sp = Arc::new(SharedParam::new(&[0.0f32]));
        let mut handles = vec![];
        for _ in 0..8 {
            let sp = Arc::clone(&sp);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    sp.fetch_add_f32(0, 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 80k stays exactly representable in f32.
        assert_eq!(sp.read_vec()[0], 80_000.0);
    }

    #[test]
    fn version_bump_is_sequential() {
        let sp = SharedParam::new(&[0.0]);
        assert_eq!(sp.bump_version(), 0);
        assert_eq!(sp.bump_version(), 1);
        assert_eq!(sp.version(), 2);
    }
}
