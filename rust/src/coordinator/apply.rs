//! The transport-agnostic apply/accounting core shared by every
//! delayed-update server loop.
//!
//! [`ApplyCore`] owns the state and the *exact* operation order of the
//! server side of the paper's Algorithm 1: payload telemetry at receipt,
//! the k/2 staleness verdict (Theorem 4 — delegated to
//! [`crate::sim::delay::accept_delay`], the one definition site of the
//! rule in the whole crate), collision-overwrite buffering, delay
//! stamping, the step-size schedule, the gap EMA, iterate averaging,
//! sample/stop checks, and the final-report epilogue.
//!
//! Three transports drive it:
//!
//! - the in-process async engine ([`super::apbcfw`]) feeds it channel
//!   messages and publishes applied parameters to a [`super::shared::SharedParam`];
//! - the TCP serve role ([`crate::net::server`]) feeds it decoded wire
//!   frames and records dirty ranges into its snapshot delta log;
//! - the sharded serve loops (`run.shards > 1`) run one core per shard
//!   over that shard's block range.
//!
//! The transports differ only in their hooks: what happens to an applied
//! batch ([`PublishHook`]) and where dropped/displaced payload containers
//! go ([`RecycleHook`]). Everything float-ordered — the apply, the EMA,
//! the averaging, the objective/gap evaluation — lives here, which is
//! what makes the pinned net==in-process bit-identity structural rather
//! than a line-by-line coincidence (see `rust/tests/net_transport.rs`).

use super::buffer::BatchAssembler;
use super::{RunResult, UpdateMsg};
use crate::problems::{ApplyOptions, BlockOracle, Problem};
use crate::run::Observer;
use crate::sim::adapt::{
    accept_delay_adjusted, damping_factor, DelayWindowRing, DropPolicy,
    KappaEma, StepPolicy, DELAY_WINDOW,
};
use crate::sim::delay::accept_delay;
use crate::solver::{schedule_gamma, StopCond, WeightedAverage};
use crate::util::metrics::{Counters, Sample, Stopwatch, Trace};
use std::ops::Range;
use std::sync::atomic::Ordering;

/// What a server loop does with an applied batch, called once per apply
/// with the post-apply iteration `k`, the updated master parameter, the
/// batch's dirty ranges (`None` = dense whole-parameter write), and the
/// applied oracles (for container recycling). The in-process engine
/// publishes to its shared parameter; the net server logs the ranges for
/// snapshot deltas.
pub type PublishHook<'h> =
    dyn FnMut(u64, &[f32], Option<Vec<Range<usize>>>, Vec<BlockOracle>) + 'h;

/// Where dropped or displaced payload containers go. The in-process
/// engine returns them to its worker free-lists; transports without a
/// recycle ring pass a no-op and let the containers drop.
pub type RecycleHook<'h> = dyn Fn(Vec<BlockOracle>) + 'h;

/// The knobs the core needs — the common subset of
/// [`super::RunConfig`] and [`crate::run::RunSpec`], lowered by the
/// transport that builds the core.
#[derive(Debug, Clone)]
pub struct ApplyKnobs {
    /// Server minibatch size tau (clamped to `[1, n]` by the core).
    pub tau: usize,
    /// Exact coordinate line search instead of the schedule.
    pub line_search: bool,
    /// Enforce the paper's k/2 staleness rule (Theorem 4).
    pub staleness_rule: bool,
    /// Collision policy: overwrite pending updates with fresher ones.
    pub collision_overwrite: bool,
    /// Trace sample cadence in server iterations.
    pub sample_every: usize,
    /// Exact duality gap at sample points (otherwise the gap EMA).
    pub exact_gap: bool,
    /// Weighted iterate averaging x-bar_k on the server.
    pub weighted_averaging: bool,
    /// Stop conditions (epoch/wall-clock budgets, gap/primal targets).
    pub stop: StopCond,
    /// Iteration-clock multiplier for the step-size schedule. A shard
    /// owning `1/S` of the blocks advances its local `k` at roughly
    /// `1/S` of the global rate, so its schedule evaluates at
    /// `k * iter_scale` to track the global clock in expectation
    /// (the relaxed block-sampling regime of Braun–Pokutta–Woodstock,
    /// arXiv:2409.06931). Everything unsharded passes 1, which leaves
    /// the schedule bit-identical to the historical call.
    pub iter_scale: u64,
    /// `run.adapt.step`: damp the gamma schedule by the smoothed
    /// observed kappa ([`StepPolicy::Off`] keeps the historical
    /// expression bit-for-bit).
    pub adapt_step: StepPolicy,
    /// `run.adapt.drop`: the staleness verdict ([`DropPolicy::K2`] is
    /// the paper's k/2 rule on the historical code path).
    pub adapt_drop: DropPolicy,
}

/// The shared server core: master parameter, apply state, assembler,
/// trace, and every accounting rule of the delayed-update loop. See the
/// module docs for the transport split.
pub struct ApplyCore<'a, P: Problem> {
    problem: &'a P,
    counters: &'a Counters,
    knobs: ApplyKnobs,
    /// Global block count n (gamma schedule, epoch accounting, gap
    /// scaling) — *not* a shard's owned span.
    n: usize,
    tau: usize,
    master: Vec<f32>,
    state: P::ServerState,
    avg: Option<WeightedAverage>,
    trace: Trace,
    gap_estimate: f64,
    k: u64,
    /// Session generation (crash recovery). 0 for every in-process
    /// engine and every fresh serve loop; a restore from a durable
    /// checkpoint resumes at `checkpoint generation + 1`, and `ingest`
    /// fences messages stamped with any other generation.
    generation: u64,
    asm: BatchAssembler,
    watch: Stopwatch,
    /// Smoothed observed kappa behind `run.adapt.step = kappa` (reports
    /// 0 before the first applied update — never NaN).
    kappa: KappaEma,
    /// Recent ingested delays backing the `quantile:Q` drop threshold.
    delay_window: DelayWindowRing,
}

impl<'a, P: Problem> ApplyCore<'a, P> {
    /// Build a core over `problem`, starting the wall clock. `counters`
    /// is shared with the transport's reader/worker threads.
    pub fn new(
        problem: &'a P,
        knobs: ApplyKnobs,
        counters: &'a Counters,
    ) -> Self {
        let n = problem.num_blocks();
        let tau = knobs.tau.clamp(1, n);
        let avg = if knobs.weighted_averaging {
            Some(WeightedAverage::new(problem.param_dim()))
        } else {
            None
        };
        ApplyCore {
            problem,
            counters,
            knobs,
            n,
            tau,
            master: problem.init_param(),
            state: problem.init_server(),
            avg,
            trace: Trace::default(),
            gap_estimate: f64::INFINITY,
            k: 0,
            generation: 0,
            asm: BatchAssembler::new(),
            watch: Stopwatch::start(),
            kappa: KappaEma::new(),
            delay_window: DelayWindowRing::new(DELAY_WINDOW),
        }
    }

    /// Resume this core from a durable checkpoint (crash recovery): jump
    /// the iteration clock to `k`, adopt the checkpointed master
    /// parameter bits, gap EMA, and trace prefix, and fence every future
    /// message that is not stamped with `generation`. The caller
    /// pre-loads the counters (`Counters::absorb`) and restores the
    /// problem's server state separately — this method only owns what
    /// the core itself owns.
    pub fn resume(
        &mut self,
        k: u64,
        master: Vec<f32>,
        gap_estimate: f64,
        trace: Trace,
        generation: u64,
    ) {
        assert_eq!(
            master.len(),
            self.master.len(),
            "checkpointed master dimension mismatch"
        );
        self.k = k;
        self.master = master;
        self.gap_estimate = gap_estimate;
        self.trace = trace;
        self.generation = generation;
    }

    /// The session generation this core accepts updates for.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Borrow the problem's server apply state (checkpoint encoding).
    pub fn server_state(&self) -> &P::ServerState {
        &self.state
    }

    /// Mutably borrow the server apply state (checkpoint restore).
    pub fn server_state_mut(&mut self) -> &mut P::ServerState {
        &mut self.state
    }

    /// The current gap EMA (checkpoint encoding; `drain` keeps it live).
    pub fn gap_estimate(&self) -> f64 {
        self.gap_estimate
    }

    /// Borrow the trace accumulated so far (checkpoint encoding).
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// The current master parameter (e.g. for snapshot answers).
    pub fn master(&self) -> &[f32] {
        &self.master
    }

    /// The current server iteration k (the snapshot version).
    pub fn k(&self) -> u64 {
        self.k
    }

    /// Drop a dead worker's buffered updates from the assembler,
    /// returning how many blocks were freed (requeue telemetry).
    pub fn requeue_worker(&mut self, worker: usize) -> usize {
        self.asm.remove_worker(worker)
    }

    /// Ingest one update payload: payload telemetry, the k/2 staleness
    /// verdict (the whole payload was read at one `k_read`, so it shares
    /// one verdict), then buffer or drop. Displaced and dropped
    /// containers go to `recycle`.
    pub fn ingest(&mut self, msg: UpdateMsg, recycle: &RecycleHook<'_>) {
        // Payload telemetry: nnz + *logical* wire bytes of everything
        // shipped worker -> server, counted at receipt (includes payloads
        // later dropped or displaced — they crossed the transport either
        // way). "Logical" means the exact-mode encoding cost regardless
        // of `run.wire`; the serve role's readers count the actually
        // shipped (possibly quantized) frame bytes separately in
        // `shipped_payload_bytes`.
        let (mut nnz, mut bytes) = (0u64, 0u64);
        for o in &msg.oracles {
            nnz += o.s.nnz() as u64;
            bytes += o.s.wire_bytes() as u64;
        }
        Counters::add(&self.counters.payload_nnz, nnz);
        Counters::add(&self.counters.payload_bytes, bytes);
        // Generation fence (crash recovery): a message computed under a
        // different session generation was in flight across a crash +
        // restore — its snapshot lineage is unverifiable, so it must
        // never reach the assembler, no matter how fresh its k_read
        // looks. Fenced before the staleness verdict; the telemetry
        // above still counts it (the bytes crossed the transport).
        if msg.generation != self.generation {
            Counters::bump(&self.counters.stale_fenced);
            recycle(msg.oracles);
            return;
        }
        // Staleness rule (paper Thm 4): drop if delay > k/2. The rule
        // itself lives in `sim::delay::accept_delay` — the single
        // definition site shared with the sequential delayed engine.
        // Under `run.adapt.drop = quantile:Q` the threshold is
        // re-centered by the running-quantile adjustment (the k2 arm is
        // the historical call, untouched).
        let delay = self.k.saturating_sub(msg.k_read);
        let accepted = match self.knobs.adapt_drop {
            DropPolicy::K2 => accept_delay(self.k, delay),
            DropPolicy::Quantile(q) => {
                let adj = self.delay_window.adjustment(q);
                let v = accept_delay_adjusted(self.k, delay, adj);
                // Marginal drops: rejections the plain k/2 rule would
                // have admitted (only meaningful when enforced).
                if self.knobs.staleness_rule
                    && !v
                    && accept_delay(self.k, delay)
                {
                    Counters::add(
                        &self.counters.drops_adaptive,
                        msg.oracles.len() as u64,
                    );
                }
                // The window sees every ingested delay, accepted or
                // not, *after* this verdict (the verdict only depends
                // on strictly older traffic).
                self.delay_window.push(delay);
                v
            }
        };
        if self.knobs.staleness_rule && !accepted {
            Counters::add(&self.counters.dropped, msg.oracles.len() as u64);
            recycle(msg.oracles);
        } else if self.knobs.collision_overwrite {
            recycle(self.asm.insert(msg));
        } else {
            recycle(self.asm.insert_keep_old(msg));
        }
    }

    /// Drain every ready tau-batch: delay stamping, schedule/line-search
    /// apply, publish hook, averaging, gap EMA, and the sample/stop
    /// check. Returns `true` when a stop condition fired (the transport
    /// breaks its serve loop).
    pub fn drain(
        &mut self,
        obs: &mut dyn Observer,
        publish: &mut PublishHook<'_>,
    ) -> bool {
        while let Some(batch_msgs) = self.asm.take_batch(self.tau) {
            // Stamp every applied update with its observed delay (the
            // expected-delay counters behind `mean_delay()` — the
            // paper's empirical kappa). Under `run.adapt.step = kappa`
            // the EMA folds these in *before* this apply's gamma, so a
            // constant injected delay yields a constant damping factor
            // from the very first applied update (the fixed-delay pin).
            for m in &batch_msgs {
                let d = m.delay(self.k);
                Counters::add(&self.counters.delay_sum, d);
                Counters::max_of(&self.counters.delay_max, d);
                if self.knobs.adapt_step == StepPolicy::Kappa {
                    self.kappa.observe(d);
                }
            }
            let batch: Vec<_> =
                batch_msgs.into_iter().map(|m| m.oracle).collect();
            // A multi-block payload can push the pending set past tau
            // before the drain, so the applied batch may exceed tau; the
            // step size, counters, and gap scaling all use the actual
            // size (at batch = 1 this is exactly tau, bit-for-bit).
            let applied = batch.len();
            let gamma = match self.knobs.adapt_step {
                // The pinned default: the historical expression,
                // bit-for-bit.
                StepPolicy::Off => schedule_gamma(
                    self.n,
                    applied,
                    self.k * self.knobs.iter_scale,
                ),
                // Damped regime (arXiv:1612.04425): scale the schedule
                // by kappa_exp / (kappa_exp + kappa_obs), expected
                // kappa := tau, observed kappa := the delay EMA. The
                // deficit telemetry is integer parts-per-thousand so
                // the counter stays exact under absorb().
                StepPolicy::Kappa => {
                    let damp = damping_factor(
                        self.tau as f64,
                        self.kappa.value(),
                    );
                    Counters::add(
                        &self.counters.gamma_damped_sum,
                        ((1.0 - damp) * 1000.0).round() as u64,
                    );
                    (schedule_gamma(
                        self.n,
                        applied,
                        self.k * self.knobs.iter_scale,
                    ) as f64
                        * damp) as f32
                }
            };
            let info = self.problem.apply(
                &mut self.state,
                &mut self.master,
                &batch,
                ApplyOptions {
                    gamma,
                    line_search: self.knobs.line_search,
                },
            );
            self.k += 1;
            let ranges = self.problem.touched_ranges(&batch);
            publish(self.k, &self.master, ranges, batch);
            Counters::add(&self.counters.updates_applied, applied as u64);
            self.counters.iterations.store(self.k, Ordering::Relaxed);
            obs.on_apply(self.k, info.gamma, info.batch_gap);
            if let Some(a) = &mut self.avg {
                a.update(&self.master, self.problem.aux(&self.state));
            }
            let inst = info.batch_gap * self.n as f64 / applied as f64;
            self.gap_estimate = if self.gap_estimate.is_finite() {
                0.8 * self.gap_estimate + 0.2 * inst
            } else {
                inst
            };

            if self.k % self.knobs.sample_every as u64 == 0 {
                let (objective, gap) = self.eval();
                let snap = self.counters.snapshot();
                let sample = Sample {
                    iter: self.k as usize,
                    oracle_calls: snap.oracle_calls,
                    elapsed_s: self.watch.elapsed_s(),
                    objective,
                    gap,
                };
                obs.on_sample(&sample);
                self.trace.push(sample);
                let epochs = snap.oracle_calls as f64 / self.n as f64;
                if self.knobs.stop.target_met(objective, gap)
                    || self
                        .knobs
                        .stop
                        .exhausted(epochs, self.watch.elapsed_s())
                {
                    return true;
                }
            }
        }
        false
    }

    /// Budget check while starved of updates (no samples fire then, so
    /// the epoch/wall-clock caps must be re-checked every loop turn).
    pub fn budget_exhausted(&self) -> bool {
        let snap = self.counters.snapshot();
        let epochs = snap.oracle_calls as f64 / self.n as f64;
        self.knobs.stop.exhausted(epochs, self.watch.elapsed_s())
    }

    /// Epilogue: fold buffered collisions into the counters, record the
    /// final sample (averaged iterate when enabled), and produce the
    /// unified [`RunResult`].
    pub fn finish(mut self, obs: &mut dyn Observer) -> RunResult {
        Counters::add(&self.counters.collisions, self.asm.collisions());
        let mut snap = self.counters.snapshot();
        snap.iterations = self.k;
        let elapsed_s = self.watch.elapsed_s();
        let passes = snap.updates_applied as f64 / self.n as f64;
        let secs_per_pass = if passes > 0.0 {
            elapsed_s / passes
        } else {
            f64::INFINITY
        };
        let (objective, gap) = self.eval();
        let sample = Sample {
            iter: self.k as usize,
            oracle_calls: snap.oracle_calls,
            elapsed_s,
            objective,
            gap,
        };
        obs.on_sample(&sample);
        self.trace.push(sample);
        let (param, raw_param) = match self.avg {
            Some(a) => (a.param, self.master),
            None => {
                let raw = self.master.clone();
                (self.master, raw)
            }
        };
        RunResult {
            trace: self.trace,
            param,
            raw_param,
            counters: snap,
            elapsed_s,
            secs_per_pass,
        }
    }

    /// The sample-point evaluation shared by `drain` and `finish`:
    /// averaged iterate when averaging is on, exact gap when requested,
    /// otherwise the EMA estimate.
    fn eval(&self) -> (f64, f64) {
        let objective = match &self.avg {
            Some(a) => self.problem.objective_from(&a.param, a.aux),
            None => self.problem.objective(&self.state, &self.master),
        };
        let gap = if self.knobs.exact_gap {
            match &self.avg {
                Some(a) => self.problem.full_gap(&self.state, &a.param),
                None => self.problem.full_gap(&self.state, &self.master),
            }
        } else {
            self.gap_estimate
        };
        (objective, gap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::util::rng::Pcg64;

    fn knobs() -> ApplyKnobs {
        ApplyKnobs {
            tau: 1,
            line_search: false,
            staleness_rule: true,
            collision_overwrite: true,
            sample_every: 4,
            exact_gap: true,
            weighted_averaging: false,
            stop: StopCond::default(),
            iter_scale: 1,
            adapt_step: StepPolicy::Off,
            adapt_drop: DropPolicy::K2,
        }
    }

    fn gfl_instance() -> Gfl {
        let mut rng = Pcg64::seeded(9);
        let (d, n) = (4, 12);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.2, y)
    }

    #[test]
    fn stale_payloads_are_dropped_at_the_shared_site() {
        let p = gfl_instance();
        let counters = Counters::new();
        let mut core = ApplyCore::new(&p, knobs(), &counters);
        let noop: &RecycleHook<'_> = &|_| {};
        // Advance the clock past the tolerance of a k_read = 0 payload.
        for _ in 0..8 {
            let o = p.oracle(core.master(), 3);
            core.ingest(
                UpdateMsg {
                    oracles: vec![o],
                    k_read: core.k(),
                    worker: 0,
                    generation: 0,
                },
                noop,
            );
            assert!(!core.drain(&mut (), &mut |_, _, _, _| {}));
        }
        assert_eq!(core.k(), 8);
        let fresh = p.oracle(core.master(), 3);
        core.ingest(
            UpdateMsg {
                oracles: vec![fresh],
                k_read: 0, // delay 8 > k/2 = 4
                worker: 0,
                generation: 0,
            },
            noop,
        );
        let snap = counters.snapshot();
        assert_eq!(snap.dropped, 1);
        assert_eq!(snap.updates_applied, 8);
    }

    #[test]
    fn stale_generation_updates_are_fenced_before_the_assembler() {
        let p = gfl_instance();
        let counters = Counters::new();
        let mut core = ApplyCore::new(&p, knobs(), &counters);
        let noop: &RecycleHook<'_> = &|_| {};
        // Simulate a restore: the core now runs generation 1.
        let master = core.master().to_vec();
        core.resume(0, master, f64::INFINITY, Trace::default(), 1);
        assert_eq!(core.generation(), 1);
        let before = core.master().to_vec();
        // A pre-crash in-flight payload still stamped generation 0 — a
        // perfectly fresh k_read must not save it from the fence.
        let o = p.oracle(core.master(), 2);
        core.ingest(
            UpdateMsg {
                oracles: vec![o],
                k_read: core.k(),
                worker: 0,
                generation: 0,
            },
            noop,
        );
        assert!(!core.drain(&mut (), &mut |_, _, _, _| {}));
        assert_eq!(counters.snapshot().stale_fenced, 1);
        assert_eq!(counters.snapshot().updates_applied, 0);
        assert_eq!(core.master(), before.as_slice(), "param untouched");
        // The same payload at the adopted generation applies fine.
        let o = p.oracle(core.master(), 2);
        core.ingest(
            UpdateMsg {
                oracles: vec![o],
                k_read: core.k(),
                worker: 0,
                generation: 1,
            },
            noop,
        );
        assert!(!core.drain(&mut (), &mut |_, _, _, _| {}));
        assert_eq!(counters.snapshot().updates_applied, 1);
        assert_eq!(counters.snapshot().stale_fenced, 1);
    }

    #[test]
    fn finish_reports_final_sample_and_counters() {
        let p = gfl_instance();
        let counters = Counters::new();
        let mut core = ApplyCore::new(&p, knobs(), &counters);
        let o = p.oracle(core.master(), 0);
        core.ingest(
            UpdateMsg {
                oracles: vec![o],
                k_read: 0,
                worker: 1,
                generation: 0,
            },
            &|_| {},
        );
        let mut published = 0usize;
        assert!(!core.drain(&mut (), &mut |k, master, ranges, batch| {
            assert_eq!(k, 1);
            assert!(!master.is_empty());
            assert!(ranges.is_some(), "gfl names its dirty ranges");
            assert_eq!(batch.len(), 1);
            published += 1;
        }));
        assert_eq!(published, 1);
        let result = core.finish(&mut ());
        assert_eq!(result.counters.updates_applied, 1);
        assert_eq!(result.counters.iterations, 1);
        assert_eq!(result.trace.samples.len(), 1);
        assert!(result.trace.samples[0].objective.is_finite());
    }

    #[test]
    fn requeue_worker_frees_buffered_blocks() {
        let p = gfl_instance();
        let counters = Counters::new();
        // tau = 3 so single-block payloads stay buffered.
        let mut k = knobs();
        k.tau = 3;
        let mut core = ApplyCore::new(&p, k, &counters);
        for (worker, block) in [(7usize, 0usize), (7, 1)] {
            let o = p.oracle(core.master(), block);
            core.ingest(
                UpdateMsg {
                    oracles: vec![o],
                    k_read: 0,
                    worker,
                    generation: 0,
                },
                &|_| {},
            );
        }
        assert_eq!(core.requeue_worker(7), 2);
        assert_eq!(core.requeue_worker(7), 0);
    }

    #[test]
    fn kappa_damping_stamps_deficit_on_delayed_updates() {
        let p = gfl_instance();
        let counters = Counters::new();
        let mut k = knobs();
        k.adapt_step = StepPolicy::Kappa;
        let mut core = ApplyCore::new(&p, k, &counters);
        let noop: &RecycleHook<'_> = &|_| {};
        // Advance the clock with fresh updates, then land one stale
        // (but admissible) update so the EMA sees a real delay.
        for _ in 0..4 {
            let o = p.oracle(core.master(), 1);
            core.ingest(
                UpdateMsg {
                    oracles: vec![o],
                    k_read: core.k(),
                    worker: 0,
                    generation: 0,
                },
                noop,
            );
            assert!(!core.drain(&mut (), &mut |_, _, _, _| {}));
        }
        let o = p.oracle(core.master(), 2);
        core.ingest(
            UpdateMsg {
                oracles: vec![o],
                k_read: 2, // delay 2 <= k/2 = 2: accepted, damped
                worker: 0,
                generation: 0,
            },
            noop,
        );
        assert!(!core.drain(&mut (), &mut |_, _, _, _| {}));
        let snap = counters.snapshot();
        assert_eq!(snap.updates_applied, 5);
        // damp = tau / (tau + ema) = 1 / (1 + 2) -> deficit ~667.
        assert!(
            snap.gamma_damped_sum > 0,
            "observed delay must register a damping deficit"
        );
        assert_eq!(snap.drops_adaptive, 0, "k2 drop arm untouched");
    }

    #[test]
    fn quantile_drop_counts_marginal_rejections() {
        let p = gfl_instance();
        let counters = Counters::new();
        let mut k = knobs();
        // The strictest quantile: threshold re-centered by
        // T_0 - T_median (nonpositive), so some k/2-admissible updates
        // get rejected and counted as adaptive drops.
        k.adapt_drop = DropPolicy::Quantile(0.0);
        let mut core = ApplyCore::new(&p, k, &counters);
        let noop: &RecycleHook<'_> = &|_| {};
        // Warm the clock and the delay window with mixed (admissible)
        // delays: k_read stamps chosen so the ingested delays are
        // 0, 0, 1, 1, 2, 1 against the growing clock.
        for kr in [0u64, 1, 1, 2, 2, 4] {
            let o = p.oracle(core.master(), 1);
            core.ingest(
                UpdateMsg {
                    oracles: vec![o],
                    k_read: kr,
                    worker: 0,
                    generation: 0,
                },
                noop,
            );
            assert!(!core.drain(&mut (), &mut |_, _, _, _| {}));
        }
        // Window sorted: {0, 0, 1, 1, 1, 2} -> T_0 - T_med = 0 - 1 =
        // -1, so a delay-3 update at k = 6 (k/2 admits exactly 3) is
        // adaptively rejected.
        assert_eq!(core.k(), 6);
        let o = p.oracle(core.master(), 2);
        core.ingest(
            UpdateMsg {
                oracles: vec![o],
                k_read: 3,
                worker: 0,
                generation: 0,
            },
            noop,
        );
        let snap = counters.snapshot();
        assert_eq!(snap.dropped, 1);
        assert_eq!(
            snap.drops_adaptive, 1,
            "the k/2 rule would have accepted delay 3 at k = 6"
        );
    }

    #[test]
    fn quantile_median_matches_k2_verdicts() {
        // Q = 0.5 re-centers by T_med - T_med = 0 for *any* window —
        // verdict-identical to the k2 arm on the same traffic.
        let p = gfl_instance();
        let run = |drop: DropPolicy| -> (u64, u64) {
            let counters = Counters::new();
            let mut k = knobs();
            k.adapt_drop = drop;
            let mut core = ApplyCore::new(&p, k, &counters);
            let noop: &RecycleHook<'_> = &|_| {};
            for i in 0..12u64 {
                let o = p.oracle(core.master(), (i % 4) as usize);
                // Alternate fresh and very stale reads.
                let k_read = if i % 3 == 0 { 0 } else { core.k() };
                core.ingest(
                    UpdateMsg {
                        oracles: vec![o],
                        k_read,
                        worker: 0,
                        generation: 0,
                    },
                    noop,
                );
                assert!(!core.drain(&mut (), &mut |_, _, _, _| {}));
            }
            let s = counters.snapshot();
            (s.updates_applied, s.dropped)
        };
        let k2 = run(DropPolicy::K2);
        let med = run(DropPolicy::Quantile(0.5));
        assert_eq!(k2, med);
    }

    #[test]
    fn requeue_sums_across_shard_cores() {
        // A sharded plane (`run.shards > 1`) runs one ApplyCore per
        // shard; a dead worker with in-flight updates buffered on two
        // shards must be requeued on both, and the per-shard
        // `blocks_requeued` telemetry sums to the global count the
        // rendezvous reports.
        let p = gfl_instance();
        let mut knobs = knobs();
        knobs.tau = 4; // single-block payloads stay buffered everywhere
        let shard_counters = [Counters::new(), Counters::new()];
        let mut cores: Vec<_> = shard_counters
            .iter()
            .map(|c| ApplyCore::new(&p, knobs.clone(), c))
            .collect();
        // Worker 7 holds one outstanding block on shard 0 and two on
        // shard 1; worker 2's update on shard 1 must survive the reap.
        for (shard, worker, block) in
            [(0usize, 7usize, 0usize), (1, 7, 1), (1, 7, 2), (1, 2, 3)]
        {
            let o = p.oracle(cores[shard].master(), block);
            cores[shard].ingest(
                UpdateMsg {
                    oracles: vec![o],
                    k_read: 0,
                    worker,
                    generation: 0,
                },
                &|_| {},
            );
        }
        let mut total = 0u64;
        for (core, counters) in cores.iter_mut().zip(&shard_counters) {
            let freed = core.requeue_worker(7) as u64;
            Counters::add(&counters.blocks_requeued, freed);
            total += freed;
        }
        assert_eq!(total, 3, "both shards requeue their share");
        assert_eq!(shard_counters[0].snapshot().blocks_requeued, 1);
        assert_eq!(shard_counters[1].snapshot().blocks_requeued, 2);
        // Requeueing worker 7 never touched worker 2's buffered block.
        assert_eq!(cores[1].requeue_worker(2), 1);
    }
}
