//! AP-BCFW: the asynchronous parallel server/worker runtime
//! (paper Algorithm 1 — distributed form — and Algorithm 2 — shared
//! memory; here worker threads + a server thread over a shared parameter).
//!
//! Workers loop: snapshot the shared parameter (lock-free, possibly mid-
//! publish — the delayed/inconsistent-read regime of §2.3), pick
//! `cfg.batch` distinct blocks uniformly, solve all their linear
//! subproblems against that one snapshot, and push them as one multi-block
//! payload (the batched fan-out; `batch = 1` is the paper's single-block
//! worker). The server assembles tau disjoint blocks across payloads
//! (collision-overwrite), applies them with the paper's step size (or
//! exact line search), publishes, and repeats. No thread ever waits for a
//! straggler.
//!
//! §Perf: the loop is allocation-free in steady state. Each worker owns a
//! snapshot buffer (re-read only on version change — batching further
//! amortizes the O(dim) read across `batch` solves), a caller-owned
//! [`Problem::Scratch`], and a payload container of [`BlockOracle`] slots
//! filled by [`Problem::oracle_into`]; the server recycles both the
//! applied/displaced payload buffers and the emptied message containers
//! back to workers through bounded free-lists, so after warm-up the
//! worker->server->worker ring reuses the same allocations.
//! Straggler-dropped and redone solves never allocate at all. Old-vs-new
//! numbers in EXPERIMENTS.md §Perf (`benches/hot_paths.rs`).

use super::apply::{ApplyCore, ApplyKnobs};
use super::shared::SharedParam;
use super::{pick_blocks, RunConfig, RunResult, UpdateMsg};
use crate::problems::{BlockOracle, OraclePayload, OracleScratch, Problem};
use crate::run::Observer;
use crate::util::metrics::Counters;
use crate::util::rng::Pcg64;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Duration;

/// Run asynchronous AP-BCFW with `cfg.workers` worker threads.
pub fn run<P: Problem>(problem: &P, cfg: &RunConfig) -> RunResult {
    run_observed(problem, cfg, &mut ())
}

/// Run asynchronous AP-BCFW, streaming live events to `obs` from the
/// server thread (workers never touch the observer).
pub fn run_observed<P: Problem>(
    problem: &P,
    cfg: &RunConfig,
    obs: &mut dyn Observer,
) -> RunResult {
    assert_eq!(
        cfg.straggler.probs.len(),
        cfg.workers,
        "straggler model arity must match worker count"
    );
    let n = problem.num_blocks();
    let tau = cfg.tau.clamp(1, n);
    let wbatch = cfg.worker_batch(n);
    // Payload representation workers request from `oracle_into` (the
    // `run.payload` knob resolved against the problem's natural
    // representation; bit-identical either way by the payload contract).
    let pkind = cfg.payload.resolve(problem.preferred_payload());
    let counters = Counters::new();
    // The transport-agnostic server core (see [`super::apply`]): master
    // parameter, staleness verdict, delay stamping, step schedule, gap
    // EMA, averaging, sampling, stop checks. This engine's transport is
    // an in-process channel + a [`SharedParam`] the workers snapshot.
    let mut core = ApplyCore::new(
        problem,
        ApplyKnobs {
            tau: cfg.tau,
            line_search: cfg.line_search,
            staleness_rule: cfg.staleness_rule,
            collision_overwrite: cfg.collision_overwrite,
            sample_every: cfg.sample_every,
            exact_gap: cfg.exact_gap,
            weighted_averaging: cfg.weighted_averaging,
            stop: cfg.stop,
            iter_scale: 1,
            adapt_step: cfg.adapt.step,
            adapt_drop: cfg.adapt.drop,
        },
        &counters,
    );
    let shared = SharedParam::with_mode(core.master(), cfg.snapshot_mode);
    let stop = AtomicBool::new(false);
    // Bounded queue: workers block when the server falls behind. This is
    // the system's backpressure — without it fast workers would race
    // arbitrarily far ahead of the server and every update would exceed
    // the k/2 staleness rule (all work wasted). A real deployment gets the
    // same effect from its network/receive buffer.
    let queue_cap = (cfg.queue_factor.max(1) * tau).max(2 * cfg.workers);
    let (tx, rx) = mpsc::sync_channel::<UpdateMsg>(queue_cap);
    // Payload-container free list: the server returns applied/displaced/
    // dropped `s` containers here (dense OR sparse — the pool is
    // representation-agnostic, so displaced sparse containers are reused
    // exactly like dense ones) and workers pick them up before the next
    // solve, making the send path allocation-free after warm-up. Bounded
    // so a slow consumer cannot hoard memory.
    let pool_cap = (queue_cap + cfg.workers) * wbatch;
    let oracle_pool: Mutex<Vec<OraclePayload>> = Mutex::new(Vec::new());
    // Message-container free list: the assembler hands back each payload's
    // emptied `Vec<BlockOracle>` and the server returns it here, so the
    // multi-block send path reuses containers as well as buffers.
    let msg_pool: Mutex<Vec<Vec<BlockOracle>>> = Mutex::new(Vec::new());
    let msg_pool_cap = queue_cap + cfg.workers;

    std::thread::scope(|scope| {
        // ---------------- workers ----------------
        for w in 0..cfg.workers {
            let tx = tx.clone();
            let shared = &shared;
            let stop = &stop;
            let counters = &counters;
            let pool = &oracle_pool;
            let vec_pool = &msg_pool;
            let straggler = cfg.straggler.clone();
            let (lo, hi) = cfg.work_multiplier;
            let seed = cfg.seed;
            scope.spawn(move || {
                let mut rng = Pcg64::new(seed, 1000 + w as u64);
                let mut snapshot: Vec<f32> = Vec::new();
                let mut blocks: Vec<usize> = Vec::new();
                // Caller-owned oracle scratch: one per worker, reused
                // across every block of every batch.
                let mut oscratch = OracleScratch::<P>::default();
                // Multi-block payload under construction: `oracle_into`
                // fills its slots in place; the container and its payload
                // buffers are handed to the server on send and replaced
                // from the recycle pools.
                let mut payload: Vec<BlockOracle> = Vec::new();
                // Re-read the shared parameter only when the server has
                // published a new version — between publishes the snapshot
                // is bit-identical, and the O(dim) atomic read was the
                // dominant per-oracle cost for cheap oracles; batching
                // amortizes it over `wbatch` solves either way (§Perf).
                let mut snap_version = u64::MAX;
                while !stop.load(Ordering::Acquire) {
                    let k_read = shared.version();
                    if k_read != snap_version || snapshot.is_empty() {
                        shared.read(&mut snapshot);
                        snap_version = k_read;
                        Counters::bump(&counters.snapshot_reads);
                    }
                    // tau_w distinct blocks per snapshot (one `below(n)`
                    // draw — the historical single-block path — at 1).
                    pick_blocks(&mut rng, n, wbatch, &mut blocks);
                    // Harder-subproblem simulation (Fig 2d): redo each
                    // solve m ~ Uniform(lo, hi) times; only the last
                    // counts.
                    let reps = if hi > lo {
                        lo + rng.below((hi - lo + 1) as usize) as u32
                    } else {
                        lo
                    };
                    // Top up the container and its payload buffers from
                    // the recycle pools. Opportunistic: on contention just
                    // allocate.
                    if payload.capacity() == 0 {
                        if let Ok(mut p) = vec_pool.try_lock() {
                            if let Some(v) = p.pop() {
                                payload = v;
                            }
                        }
                    }
                    while payload.len() < wbatch {
                        payload.push(BlockOracle::empty_with(pkind));
                    }
                    for (slot, &i) in payload.iter_mut().zip(blocks.iter()) {
                        if slot.s.is_unallocated() {
                            if let Ok(mut p) = pool.try_lock() {
                                if let Some(buf) = p.pop() {
                                    // Pooled containers may carry either
                                    // representation; convert in place
                                    // (buffer-reusing) to this run's
                                    // requested kind.
                                    slot.s = buf;
                                    slot.s.set_kind(pkind);
                                }
                            }
                        }
                        problem.oracle_into(&snapshot, i, &mut oscratch, slot);
                        for _ in 1..reps {
                            problem.oracle_into(
                                &snapshot,
                                i,
                                &mut oscratch,
                                slot,
                            );
                        }
                        Counters::bump(&counters.oracle_calls);
                    }
                    if !straggler.reports(w, &mut rng) {
                        // The whole payload fails to report; its slots are
                        // reused next iteration without any allocation.
                        Counters::add(&counters.dropped, wbatch as u64);
                        continue;
                    }
                    let oracles = std::mem::take(&mut payload);
                    if tx
                        .send(UpdateMsg {
                            oracles,
                            k_read,
                            worker: w,
                            generation: 0,
                        })
                        .is_err()
                    {
                        break;
                    }
                }
            });
        }
        drop(tx);

        // ---------------- server ----------------
        // Recycle a message container and the payload containers inside it
        // back to the worker pools — opportunistically: if a pool is
        // contended or full, dropping is cheaper than waiting. The payload
        // pool takes dense and sparse containers alike (workers re-shape
        // them on pickup), so a displaced sparse oracle's buffers are
        // reused, not dropped.
        let recycle = |mut oracles: Vec<BlockOracle>| {
            if !oracles.is_empty() {
                if let Ok(mut p) = oracle_pool.try_lock() {
                    while let Some(o) = oracles.pop() {
                        if p.len() >= pool_cap {
                            break;
                        }
                        let mut s = o.s;
                        s.recycle();
                        p.push(s);
                    }
                }
                oracles.clear();
            }
            if let Ok(mut p) = msg_pool.try_lock() {
                if p.len() < msg_pool_cap {
                    p.push(oracles);
                }
            }
        };
        // Publish hook: push each applied batch to the shared parameter —
        // only the dirty ranges when the problem can name them (GFL/QP:
        // tau block slices instead of the whole parameter); SSVM updates
        // w densely -> full publish. The whole batch is one consistency
        // section in Consistent mode — readers never see it half-applied.
        // Then recycle the applied payload buffers AND the batch
        // container back to the workers.
        let mut publish = |kk: u64,
                           master: &[f32],
                           ranges: Option<Vec<Range<usize>>>,
                           batch: Vec<BlockOracle>| {
            match ranges {
                Some(ranges) => shared.publish_ranges(&ranges, master),
                None => shared.publish(master, kk),
            }
            recycle(batch);
        };
        'serve: loop {
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(msg) => core.ingest(msg, &recycle),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break 'serve,
            }

            if core.drain(&mut *obs, &mut publish) {
                break 'serve;
            }

            // Budget check even while starved of updates.
            if core.budget_exhausted() {
                break 'serve;
            }
        }
        stop.store(true, Ordering::Release);
        // Drop the receiver: workers blocked on a full queue get a send
        // error and exit; anyone mid-loop sees the stop flag.
        drop(rx);
    });

    // Epilogue (collision fold, final sample, result assembly) is the
    // core's — shared verbatim with the net serve role.
    core.finish(obs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::run::{Engine, RunSpec};
    use crate::sim::straggler::StragglerModel;
    use crate::solver::StopCond;
    use crate::util::rng::Pcg64;

    fn gfl_instance() -> Gfl {
        let mut rng = Pcg64::seeded(77);
        let (d, n) = (6, 40);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.2, y)
    }

    fn cfg(workers: usize, tau: usize) -> RunConfig {
        RunSpec::new(Engine::asynchronous(workers))
            .tau(tau)
            .sample_every(16)
            .exact_gap(true)
            .eps_gap(0.05)
            .max_epochs(5000.0)
            .max_secs(30.0)
            .seed(5)
            .run_config()
            .unwrap()
    }

    #[test]
    fn async_run_converges_gfl() {
        let p = gfl_instance();
        let r = run(&p, &cfg(3, 4));
        let last = r.trace.last().unwrap();
        assert!(last.gap <= 0.05, "gap={}", last.gap);
        assert!(r.counters.updates_applied > 0);
        // feasibility of the final iterate
        for t in 0..p.m {
            let nrm = crate::util::la::norm2(&r.param[t * p.d..(t + 1) * p.d]);
            assert!(nrm <= p.lam + 1e-5);
        }
    }

    #[test]
    fn straggler_does_not_block_convergence() {
        let p = gfl_instance();
        let mut c = cfg(4, 4);
        c.straggler = StragglerModel::single(4, 0.2);
        let r = run(&p, &c);
        assert!(r.trace.last().unwrap().gap <= 0.05);
        assert!(r.counters.dropped > 0, "straggler must drop updates");
    }

    #[test]
    fn single_worker_tau1_matches_bcfw_quality() {
        let p = gfl_instance();
        let mut c = cfg(1, 1);
        c.stop.eps_gap = Some(0.05);
        let r = run(&p, &c);
        assert!(r.trace.last().unwrap().gap <= 0.05);
    }

    #[test]
    fn weighted_averaging_reports_feasible_average() {
        let p = gfl_instance();
        let mut c = cfg(2, 2);
        c.weighted_averaging = true;
        c.stop.eps_gap = Some(0.15);
        let r = run(&p, &c);
        // The averaged iterate is a convex combination of feasible
        // iterates, so it must be feasible itself; the trace reports it.
        assert!(r.trace.last().unwrap().gap <= 0.15);
        for t in 0..p.m {
            let nrm =
                crate::util::la::norm2(&r.param[t * p.d..(t + 1) * p.d]);
            assert!(nrm <= p.lam + 1e-4, "block {t} norm {nrm}");
        }
    }

    #[test]
    fn consistent_snapshot_mode_converges() {
        let p = gfl_instance();
        let mut c = cfg(3, 4);
        c.snapshot_mode = crate::coordinator::shared::SnapshotMode::Consistent;
        let r = run(&p, &c);
        assert!(r.trace.last().unwrap().gap <= 0.05);
    }

    #[test]
    fn batched_workers_converge_and_amortize_snapshot_reads() {
        let p = gfl_instance(); // 39 blocks
        let mut c = cfg(2, 4);
        c.batch = 4; // 4 x 2 <= 39
        let r = run(&p, &c);
        assert!(r.trace.last().unwrap().gap <= 0.05);
        assert!(r.counters.snapshot_reads > 0);
        // Each worker reads at most one snapshot per 4-block round (and
        // only on version change), so reads are at most ~calls/4 plus a
        // partial final round per worker.
        assert!(
            r.counters.snapshot_reads
                <= r.counters.oracle_calls / 4 + 2 * c.workers as u64,
            "reads={} calls={}",
            r.counters.snapshot_reads,
            r.counters.oracle_calls
        );
    }

    #[test]
    #[should_panic(expected = "must not exceed")]
    fn oversized_batch_panics() {
        let p = gfl_instance(); // 39 blocks
        let mut c = cfg(8, 4);
        c.batch = 8; // 8 x 8 > 39
        let _ = run(&p, &c);
    }

    #[test]
    fn sparse_payload_ships_fewer_bytes_per_oracle() {
        // Simplex QP's oracle is a 1-hot vertex: forced-sparse runs must
        // ship far fewer payload bytes per oracle than forced-dense ones,
        // and both must converge (they are bit-identical by the payload
        // contract).
        use crate::problems::simplex_qp::SimplexQp;
        use crate::problems::PayloadMode;
        let qp = SimplexQp::random(24, 8, 1.0, 0.2, 3, 21);
        let mut bytes_per_oracle = Vec::new();
        for mode in [PayloadMode::Dense, PayloadMode::Sparse] {
            let mut c = cfg(2, 4);
            c.payload = mode;
            c.line_search = true;
            c.stop.eps_gap = Some(0.1);
            let r = run(&qp, &c);
            assert!(r.trace.last().unwrap().gap <= 0.1, "{mode:?}");
            assert!(r.counters.payload_bytes > 0);
            assert!(r.counters.payload_nnz > 0);
            bytes_per_oracle.push(
                r.counters.payload_bytes as f64
                    / r.counters.oracle_calls.max(1) as f64,
            );
        }
        // Dense ships 4*m = 32 bytes per oracle; sparse 4 + 8 = 12.
        assert!(
            bytes_per_oracle[1] < bytes_per_oracle[0],
            "sparse {} !< dense {}",
            bytes_per_oracle[1],
            bytes_per_oracle[0]
        );
    }

    #[test]
    fn adaptive_policies_still_converge() {
        // Damped steps (damp >= MIN_DAMP) and a permissive quantile drop
        // must not break convergence — adaptivity degrades the rate at
        // worst, never correctness.
        let p = gfl_instance();
        let mut c = cfg(3, 4);
        c.adapt.step = crate::sim::adapt::StepPolicy::Kappa;
        c.adapt.drop = crate::sim::adapt::DropPolicy::Quantile(0.9);
        let r = run(&p, &c);
        assert!(r.trace.last().unwrap().gap <= 0.05);
        assert!(r.counters.updates_applied > 0);
    }

    #[test]
    fn respects_time_budget() {
        let p = gfl_instance();
        let mut c = cfg(2, 2);
        c.stop = StopCond {
            eps_gap: Some(0.0), // unreachable
            max_epochs: f64::INFINITY,
            max_secs: 0.3,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let _ = run(&p, &c);
        assert!(t0.elapsed().as_secs_f64() < 5.0);
    }
}
