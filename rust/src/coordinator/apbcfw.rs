//! AP-BCFW: the asynchronous parallel server/worker runtime
//! (paper Algorithm 1 — distributed form — and Algorithm 2 — shared
//! memory; here worker threads + a server thread over a shared parameter).
//!
//! Workers loop: snapshot the shared parameter (lock-free, possibly mid-
//! publish — the delayed/inconsistent-read regime of §2.3), pick a block
//! uniformly, solve the linear subproblem, and push the update. The server
//! assembles tau disjoint blocks (collision-overwrite), applies them with
//! the paper's step size (or exact line search), publishes, and repeats.
//! No thread ever waits for a straggler.
//!
//! §Perf: the loop is allocation-free in steady state. Each worker owns a
//! snapshot buffer (re-read only on version change) and a [`BlockOracle`]
//! scratch filled by [`Problem::oracle_into`]; payload buffers of applied
//! updates are recycled back to workers through a bounded free-list, so
//! after warm-up the worker->server->worker ring reuses the same
//! allocations. Straggler-dropped and redone solves never allocate at all.
//! Old-vs-new numbers in EXPERIMENTS.md §Perf (`benches/hot_paths.rs`).

use super::buffer::BatchAssembler;
use super::shared::SharedParam;
use super::{RunConfig, RunResult, UpdateMsg};
use crate::problems::{ApplyOptions, BlockOracle, Problem};
use crate::run::Observer;
use crate::solver::{schedule_gamma, WeightedAverage};
use crate::util::metrics::{Counters, Sample, Stopwatch, Trace};
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Duration;

/// Run asynchronous AP-BCFW with `cfg.workers` worker threads.
pub fn run<P: Problem>(problem: &P, cfg: &RunConfig) -> RunResult {
    run_observed(problem, cfg, &mut ())
}

/// Run asynchronous AP-BCFW, streaming live events to `obs` from the
/// server thread (workers never touch the observer).
pub fn run_observed<P: Problem>(
    problem: &P,
    cfg: &RunConfig,
    obs: &mut dyn Observer,
) -> RunResult {
    assert_eq!(
        cfg.straggler.probs.len(),
        cfg.workers,
        "straggler model arity must match worker count"
    );
    let n = problem.num_blocks();
    let tau = cfg.tau.clamp(1, n);
    let mut master = problem.init_param();
    let mut state = problem.init_server();
    let shared = SharedParam::with_mode(&master, cfg.snapshot_mode);
    let stop = AtomicBool::new(false);
    let counters = Counters::new();
    // Bounded queue: workers block when the server falls behind. This is
    // the system's backpressure — without it fast workers would race
    // arbitrarily far ahead of the server and every update would exceed
    // the k/2 staleness rule (all work wasted). A real deployment gets the
    // same effect from its network/receive buffer.
    let queue_cap = (cfg.queue_factor.max(1) * tau).max(2 * cfg.workers);
    let (tx, rx) = mpsc::sync_channel::<UpdateMsg>(queue_cap);
    // Payload-buffer free list: the server returns applied/dropped `s`
    // vectors here and workers pick them up before the next solve, making
    // the send path allocation-free after warm-up. Bounded so a slow
    // consumer cannot hoard memory.
    let pool_cap = queue_cap + cfg.workers;
    let oracle_pool: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());
    let watch = Stopwatch::start();

    let mut trace = Trace::default();
    // Weighted iterate averaging (matches the sequential solvers; the
    // async trace/result then report the averaged iterate).
    let mut avg: Option<WeightedAverage> = if cfg.weighted_averaging {
        Some(WeightedAverage::new(problem.param_dim()))
    } else {
        None
    };
    let mut gap_estimate = f64::INFINITY;
    let mut k: u64 = 0;
    let mut asm = BatchAssembler::new();

    std::thread::scope(|scope| {
        // ---------------- workers ----------------
        for w in 0..cfg.workers {
            let tx = tx.clone();
            let shared = &shared;
            let stop = &stop;
            let counters = &counters;
            let pool = &oracle_pool;
            let straggler = cfg.straggler.clone();
            let (lo, hi) = cfg.work_multiplier;
            let seed = cfg.seed;
            scope.spawn(move || {
                let mut rng = Pcg64::new(seed, 1000 + w as u64);
                let mut snapshot: Vec<f32> = Vec::new();
                // Reusable oracle slot: `oracle_into` fills it in place;
                // its payload buffer is handed to the server on send and
                // replaced from the recycle pool.
                let mut scratch = BlockOracle::empty();
                // Re-read the shared parameter only when the server has
                // published a new version — between publishes the snapshot
                // is bit-identical, and the O(dim) atomic read was the
                // dominant per-oracle cost for cheap oracles (§Perf).
                let mut snap_version = u64::MAX;
                while !stop.load(Ordering::Acquire) {
                    let k_read = shared.version();
                    if k_read != snap_version || snapshot.is_empty() {
                        shared.read(&mut snapshot);
                        snap_version = k_read;
                    }
                    let i = rng.below(n);
                    // Harder-subproblem simulation (Fig 2d): redo the solve
                    // m ~ Uniform(lo, hi) times; only the last one counts.
                    let reps = if hi > lo {
                        lo + rng.below((hi - lo + 1) as usize) as u32
                    } else {
                        lo
                    };
                    if scratch.s.capacity() == 0 {
                        // Opportunistic: on contention just allocate.
                        if let Ok(mut p) = pool.try_lock() {
                            if let Some(buf) = p.pop() {
                                scratch.s = buf;
                            }
                        }
                    }
                    problem.oracle_into(&snapshot, i, &mut scratch);
                    for _ in 1..reps {
                        problem.oracle_into(&snapshot, i, &mut scratch);
                    }
                    Counters::bump(&counters.oracle_calls);
                    if !straggler.reports(w, &mut rng) {
                        Counters::bump(&counters.dropped);
                        continue;
                    }
                    let oracle =
                        std::mem::replace(&mut scratch, BlockOracle::empty());
                    if tx
                        .send(UpdateMsg {
                            oracle,
                            k_read,
                            worker: w,
                        })
                        .is_err()
                    {
                        break;
                    }
                }
            });
        }
        drop(tx);

        // ---------------- server ----------------
        'serve: loop {
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(msg) => {
                    // Staleness rule (paper Thm 4): drop if delay > k/2.
                    let delay = k.saturating_sub(msg.k_read);
                    if cfg.staleness_rule && 2 * delay > k && delay > 0 {
                        Counters::bump(&counters.dropped);
                        if let Ok(mut p) = oracle_pool.try_lock() {
                            if p.len() < pool_cap {
                                let mut s = msg.oracle.s;
                                s.clear();
                                p.push(s);
                            }
                        }
                    } else if cfg.collision_overwrite {
                        asm.insert(msg);
                    } else {
                        asm.insert_keep_old(msg);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break 'serve,
            }

            while let Some(batch_msgs) = asm.take_batch(tau) {
                let batch: Vec<_> =
                    batch_msgs.into_iter().map(|m| m.oracle).collect();
                let gamma = schedule_gamma(n, tau, k);
                let info = problem.apply(
                    &mut state,
                    &mut master,
                    &batch,
                    ApplyOptions {
                        gamma,
                        line_search: cfg.line_search,
                    },
                );
                k += 1;
                // Publish only the dirty ranges when the problem can name
                // them (GFL/QP: tau block slices instead of the whole
                // parameter); SSVM updates w densely -> full publish. The
                // whole batch is one consistency section in Consistent
                // mode — readers never see it half-applied.
                match problem.touched_ranges(&batch) {
                    Some(ranges) => {
                        shared.publish_ranges(&ranges, &master);
                    }
                    None => shared.publish(&master, k),
                }
                // Recycle applied payload buffers back to the workers —
                // opportunistically: if the pool is contended, dropping
                // the buffers is cheaper than waiting.
                if let Ok(mut p) = oracle_pool.try_lock() {
                    for o in batch {
                        if p.len() >= pool_cap {
                            break;
                        }
                        let mut s = o.s;
                        s.clear();
                        p.push(s);
                    }
                }
                Counters::add(&counters.updates_applied, tau as u64);
                counters.iterations.store(k, Ordering::Relaxed);
                obs.on_apply(k, info.gamma, info.batch_gap);
                if let Some(a) = &mut avg {
                    a.update(&master, problem.aux(&state));
                }
                let inst = info.batch_gap * n as f64 / tau as f64;
                gap_estimate = if gap_estimate.is_finite() {
                    0.8 * gap_estimate + 0.2 * inst
                } else {
                    inst
                };

                if k % cfg.sample_every as u64 == 0 {
                    // Report the averaged iterate when averaging is on
                    // (exactly like the sequential Monitor).
                    let objective = match &avg {
                        Some(a) => problem.objective_from(&a.param, a.aux),
                        None => problem.objective(&state, &master),
                    };
                    let gap = if cfg.exact_gap {
                        match &avg {
                            Some(a) => problem.full_gap(&state, &a.param),
                            None => problem.full_gap(&state, &master),
                        }
                    } else {
                        gap_estimate
                    };
                    let snap = counters.snapshot();
                    let sample = Sample {
                        iter: k as usize,
                        oracle_calls: snap.oracle_calls,
                        elapsed_s: watch.elapsed_s(),
                        objective,
                        gap,
                    };
                    obs.on_sample(&sample);
                    trace.push(sample);
                    let epochs = snap.oracle_calls as f64 / n as f64;
                    if cfg.stop.target_met(objective, gap)
                        || cfg.stop.exhausted(epochs, watch.elapsed_s())
                    {
                        break 'serve;
                    }
                }
            }

            // Budget check even while starved of updates.
            let snap = counters.snapshot();
            let epochs = snap.oracle_calls as f64 / n as f64;
            if cfg.stop.exhausted(epochs, watch.elapsed_s()) {
                break 'serve;
            }
        }
        stop.store(true, Ordering::Release);
        // Drop the receiver: workers blocked on a full queue get a send
        // error and exit; anyone mid-loop sees the stop flag.
        drop(rx);
    });

    // Fold buffered collisions into the counter snapshot.
    Counters::add(&counters.collisions, asm.collisions());
    let mut snap = counters.snapshot();
    snap.iterations = k;
    let elapsed_s = watch.elapsed_s();
    let passes = snap.updates_applied as f64 / n as f64;
    let secs_per_pass = if passes > 0.0 {
        elapsed_s / passes
    } else {
        f64::INFINITY
    };

    // Final sample for completeness (averaged iterate when enabled).
    let objective = match &avg {
        Some(a) => problem.objective_from(&a.param, a.aux),
        None => problem.objective(&state, &master),
    };
    let gap = if cfg.exact_gap {
        match &avg {
            Some(a) => problem.full_gap(&state, &a.param),
            None => problem.full_gap(&state, &master),
        }
    } else {
        gap_estimate
    };
    let sample = Sample {
        iter: k as usize,
        oracle_calls: snap.oracle_calls,
        elapsed_s,
        objective,
        gap,
    };
    obs.on_sample(&sample);
    trace.push(sample);

    let (param, raw_param) = match avg {
        Some(a) => (a.param, master),
        None => {
            let raw = master.clone();
            (master, raw)
        }
    };
    RunResult {
        trace,
        param,
        raw_param,
        counters: snap,
        elapsed_s,
        secs_per_pass,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::run::{Engine, RunSpec};
    use crate::sim::straggler::StragglerModel;
    use crate::solver::StopCond;
    use crate::util::rng::Pcg64;

    fn gfl_instance() -> Gfl {
        let mut rng = Pcg64::seeded(77);
        let (d, n) = (6, 40);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.2, y)
    }

    fn cfg(workers: usize, tau: usize) -> RunConfig {
        RunSpec::new(Engine::asynchronous(workers))
            .tau(tau)
            .sample_every(16)
            .exact_gap(true)
            .eps_gap(0.05)
            .max_epochs(5000.0)
            .max_secs(30.0)
            .seed(5)
            .run_config()
            .unwrap()
    }

    #[test]
    fn async_run_converges_gfl() {
        let p = gfl_instance();
        let r = run(&p, &cfg(3, 4));
        let last = r.trace.last().unwrap();
        assert!(last.gap <= 0.05, "gap={}", last.gap);
        assert!(r.counters.updates_applied > 0);
        // feasibility of the final iterate
        for t in 0..p.m {
            let nrm = crate::util::la::norm2(&r.param[t * p.d..(t + 1) * p.d]);
            assert!(nrm <= p.lam + 1e-5);
        }
    }

    #[test]
    fn straggler_does_not_block_convergence() {
        let p = gfl_instance();
        let mut c = cfg(4, 4);
        c.straggler = StragglerModel::single(4, 0.2);
        let r = run(&p, &c);
        assert!(r.trace.last().unwrap().gap <= 0.05);
        assert!(r.counters.dropped > 0, "straggler must drop updates");
    }

    #[test]
    fn single_worker_tau1_matches_bcfw_quality() {
        let p = gfl_instance();
        let mut c = cfg(1, 1);
        c.stop.eps_gap = Some(0.05);
        let r = run(&p, &c);
        assert!(r.trace.last().unwrap().gap <= 0.05);
    }

    #[test]
    fn weighted_averaging_reports_feasible_average() {
        let p = gfl_instance();
        let mut c = cfg(2, 2);
        c.weighted_averaging = true;
        c.stop.eps_gap = Some(0.15);
        let r = run(&p, &c);
        // The averaged iterate is a convex combination of feasible
        // iterates, so it must be feasible itself; the trace reports it.
        assert!(r.trace.last().unwrap().gap <= 0.15);
        for t in 0..p.m {
            let nrm =
                crate::util::la::norm2(&r.param[t * p.d..(t + 1) * p.d]);
            assert!(nrm <= p.lam + 1e-4, "block {t} norm {nrm}");
        }
    }

    #[test]
    fn consistent_snapshot_mode_converges() {
        let p = gfl_instance();
        let mut c = cfg(3, 4);
        c.snapshot_mode = crate::coordinator::shared::SnapshotMode::Consistent;
        let r = run(&p, &c);
        assert!(r.trace.last().unwrap().gap <= 0.05);
    }

    #[test]
    fn respects_time_budget() {
        let p = gfl_instance();
        let mut c = cfg(2, 2);
        c.stop = StopCond {
            eps_gap: Some(0.0), // unreachable
            max_epochs: f64::INFINITY,
            max_secs: 0.3,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let _ = run(&p, &c);
        assert!(t0.elapsed().as_secs_f64() < 5.0);
    }
}
