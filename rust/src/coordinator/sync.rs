//! SP-BCFW: the synchronous minibatch comparator (paper §3.3).
//!
//! Each iteration the server picks tau disjoint blocks, assigns them to
//! workers in contiguous chunks (round-robin; `batch = 1` is the
//! historical element-wise round-robin), and *waits for all of them*
//! before applying the batch. A worker solves its whole assignment
//! against ONE snapshot of the shared parameter — the synchronous form of
//! the batched fan-out. Because the server samples only tau blocks per
//! round, `cfg.batch` is a CAP on the chunk, clamped to the floor share
//! `tau / workers` so no worker is ever idled (the full fan-out needs
//! `tau >= batch * workers`). Stragglers are simulated with return
//! probabilities: a failed report forces the worker to redo the solve, so
//! the iteration takes as long as the slowest worker — the behaviour Fig 3
//! contrasts with AP-BCFW.

use super::shared::SharedParam;
use super::{RunConfig, RunResult};
use crate::problems::{
    ApplyOptions, BlockOracle, OraclePayload, OracleScratch, Problem,
};
use crate::run::Observer;
use crate::sim::adapt::{damping_factor, StepPolicy};
use crate::solver::schedule_gamma;
use crate::util::metrics::{Counters, Sample, Stopwatch, Trace};
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};

enum Assignment {
    Solve(Vec<usize>),
    Stop,
}

/// Run synchronous SP-BCFW.
pub fn run<P: Problem>(problem: &P, cfg: &RunConfig) -> RunResult {
    run_observed(problem, cfg, &mut ())
}

/// Run synchronous SP-BCFW, streaming live events to `obs` from the
/// server thread.
pub fn run_observed<P: Problem>(
    problem: &P,
    cfg: &RunConfig,
    obs: &mut dyn Observer,
) -> RunResult {
    assert_eq!(cfg.straggler.probs.len(), cfg.workers);
    let n = problem.num_blocks();
    let tau = cfg.tau.clamp(1, n);
    let wbatch = cfg.worker_batch(n);
    let pkind = cfg.payload.resolve(problem.preferred_payload());
    let mut master = problem.init_param();
    let mut state = problem.init_server();
    let shared = SharedParam::with_mode(&master, cfg.snapshot_mode);
    let counters = Counters::new();
    let watch = Stopwatch::start();
    let stop_flag = AtomicBool::new(false);

    let mut trace = Trace::default();
    let mut gap_estimate = f64::INFINITY;
    let mut k: u64 = 0;
    // Payload-container free list (same scheme as the async runtime,
    // representation-agnostic): the server recycles applied `s`
    // containers, workers pick them up before a solve, so the report path
    // is allocation-free after warm-up.
    let pool_cap = 2 * tau + cfg.workers;
    let oracle_pool: Mutex<Vec<OraclePayload>> = Mutex::new(Vec::new());

    // Per-worker assignment channels + shared result channel.
    let mut assign_txs = Vec::with_capacity(cfg.workers);
    let mut assign_rxs = Vec::with_capacity(cfg.workers);
    for _ in 0..cfg.workers {
        let (tx, rx) = mpsc::channel::<Assignment>();
        assign_txs.push(tx);
        assign_rxs.push(rx);
    }
    let (res_tx, res_rx) = mpsc::channel::<Vec<BlockOracle>>();

    std::thread::scope(|scope| {
        for (w, a_rx) in assign_rxs.into_iter().enumerate() {
            let res_tx = res_tx.clone();
            let shared = &shared;
            let counters = &counters;
            let pool = &oracle_pool;
            let straggler = cfg.straggler.clone();
            let stop_flag = &stop_flag;
            let seed = cfg.seed;
            scope.spawn(move || {
                let mut rng = Pcg64::new(seed, 2000 + w as u64);
                let mut snapshot: Vec<f32> = Vec::new();
                // Caller-owned oracle scratch, reused across the whole
                // assignment (and across straggler redos).
                let mut oscratch = OracleScratch::<P>::default();
                // Payload slot reused across straggler redos: only the
                // successfully-reported solve transfers its buffer (§Perf).
                let mut scratch = BlockOracle::empty_with(pkind);
                while let Ok(Assignment::Solve(blocks)) = a_rx.recv() {
                    if stop_flag.load(Ordering::Acquire) {
                        break;
                    }
                    // One snapshot per assignment: every block of this
                    // round's chunk is solved against the same parameter.
                    shared.read(&mut snapshot);
                    Counters::bump(&counters.snapshot_reads);
                    let mut out = Vec::with_capacity(blocks.len());
                    for i in blocks {
                        if scratch.s.is_unallocated() {
                            // Opportunistic: on contention just allocate.
                            if let Ok(mut p) = pool.try_lock() {
                                if let Some(buf) = p.pop() {
                                    scratch.s = buf;
                                    scratch.s.set_kind(pkind);
                                }
                            }
                        }
                        // Redo until the solve is successfully reported —
                        // the synchronous server can't proceed without it.
                        loop {
                            problem.oracle_into(
                                &snapshot,
                                i,
                                &mut oscratch,
                                &mut scratch,
                            );
                            Counters::bump(&counters.oracle_calls);
                            if straggler.reports(w, &mut rng) {
                                out.push(std::mem::replace(
                                    &mut scratch,
                                    BlockOracle::empty_with(pkind),
                                ));
                                break;
                            }
                            Counters::bump(&counters.dropped);
                        }
                    }
                    if res_tx.send(out).is_err() {
                        break;
                    }
                }
            });
        }
        drop(res_tx);

        let mut rng = Pcg64::new(cfg.seed, 4);
        // Per-worker chunk: `wbatch` blocks per snapshot, but never more
        // than the FLOOR share of tau — a larger chunk (ceil, or a fan-out
        // with batch >= tau) would leave trailing workers with no blocks
        // on every round and silently shrink the fleet (e.g. tau=4, T=3,
        // chunk=2 assigns 2/2/0). The floor keeps every worker assigned
        // whenever tau >= workers; at wbatch = 1 the chunk is 1: the
        // historical element-wise round-robin, bit-for-bit.
        let chunk = wbatch.min(tau / cfg.workers).max(1);
        'serve: loop {
            // Assign tau disjoint blocks across workers in contiguous
            // chunks (round-robin over chunks); a worker solves its whole
            // chunk against one snapshot.
            let blocks = rng.subset(n, tau);
            let mut assignments: Vec<Vec<usize>> =
                vec![Vec::new(); cfg.workers];
            for (j, &b) in blocks.iter().enumerate() {
                assignments[(j / chunk) % cfg.workers].push(b);
            }
            let mut outstanding = 0usize;
            for (w, a) in assignments.into_iter().enumerate() {
                if !a.is_empty() {
                    assign_txs[w].send(Assignment::Solve(a)).ok();
                    outstanding += 1;
                }
            }
            // Barrier: wait for every assigned worker.
            let mut batch: Vec<BlockOracle> = Vec::with_capacity(tau);
            for _ in 0..outstanding {
                match res_rx.recv() {
                    Ok(mut os) => batch.append(&mut os),
                    Err(_) => break 'serve,
                }
            }
            // Payload telemetry: everything shipped worker -> server.
            let (mut nnz, mut bytes) = (0u64, 0u64);
            for o in &batch {
                nnz += o.s.nnz() as u64;
                bytes += o.s.wire_bytes() as u64;
            }
            Counters::add(&counters.payload_nnz, nnz);
            Counters::add(&counters.payload_bytes, bytes);
            let gamma = match cfg.adapt.step {
                // Pinned default: the historical expression verbatim.
                StepPolicy::Off => schedule_gamma(n, tau, k),
                // Structural threading: the barrier makes every round's
                // observed delay exactly 0, so the damping factor is
                // identically 1 and the deficit identically 0 — only
                // delay-observing engines ever damp.
                StepPolicy::Kappa => {
                    let damp = damping_factor(tau as f64, 0.0);
                    Counters::add(
                        &counters.gamma_damped_sum,
                        ((1.0 - damp) * 1000.0).round() as u64,
                    );
                    (schedule_gamma(n, tau, k) as f64 * damp) as f32
                }
            };
            let info = problem.apply(
                &mut state,
                &mut master,
                &batch,
                ApplyOptions {
                    gamma,
                    line_search: cfg.line_search,
                },
            );
            k += 1;
            shared.publish(&master, k);
            obs.on_apply(k, info.gamma, info.batch_gap);
            Counters::add(&counters.updates_applied, batch.len() as u64);
            // Recycle applied payload containers back to the workers
            // (dense or sparse alike).
            if let Ok(mut p) = oracle_pool.try_lock() {
                for o in batch {
                    if p.len() >= pool_cap {
                        break;
                    }
                    let mut s = o.s;
                    s.recycle();
                    p.push(s);
                }
            }
            counters.iterations.store(k, Ordering::Relaxed);
            let inst = info.batch_gap * n as f64 / tau as f64;
            gap_estimate = if gap_estimate.is_finite() {
                0.8 * gap_estimate + 0.2 * inst
            } else {
                inst
            };

            if k % cfg.sample_every as u64 == 0 {
                let objective = problem.objective(&state, &master);
                let gap = if cfg.exact_gap {
                    problem.full_gap(&state, &master)
                } else {
                    gap_estimate
                };
                let snap = counters.snapshot();
                let sample = Sample {
                    iter: k as usize,
                    oracle_calls: snap.oracle_calls,
                    elapsed_s: watch.elapsed_s(),
                    objective,
                    gap,
                };
                obs.on_sample(&sample);
                trace.push(sample);
                let epochs = snap.oracle_calls as f64 / n as f64;
                if cfg.stop.target_met(objective, gap)
                    || cfg.stop.exhausted(epochs, watch.elapsed_s())
                {
                    break 'serve;
                }
            }
            let snap = counters.snapshot();
            if cfg
                .stop
                .exhausted(snap.oracle_calls as f64 / n as f64, watch.elapsed_s())
            {
                break 'serve;
            }
        }
        stop_flag.store(true, Ordering::Release);
        for tx in &assign_txs {
            tx.send(Assignment::Stop).ok();
        }
    });

    let mut snap = counters.snapshot();
    snap.iterations = k;
    let elapsed_s = watch.elapsed_s();
    let passes = snap.updates_applied as f64 / n as f64;
    let secs_per_pass = if passes > 0.0 {
        elapsed_s / passes
    } else {
        f64::INFINITY
    };
    let objective = problem.objective(&state, &master);
    let gap = if cfg.exact_gap {
        problem.full_gap(&state, &master)
    } else {
        gap_estimate
    };
    let sample = Sample {
        iter: k as usize,
        oracle_calls: snap.oracle_calls,
        elapsed_s,
        objective,
        gap,
    };
    obs.on_sample(&sample);
    trace.push(sample);

    RunResult {
        trace,
        raw_param: master.clone(),
        param: master,
        counters: snap,
        elapsed_s,
        secs_per_pass,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::run::{Engine, RunSpec};
    use crate::sim::straggler::StragglerModel;
    use crate::util::rng::Pcg64;

    fn gfl_instance() -> Gfl {
        let mut rng = Pcg64::seeded(88);
        let (d, n) = (6, 40);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.2, y)
    }

    fn cfg(workers: usize, tau: usize) -> RunConfig {
        RunSpec::new(Engine::synchronous(workers))
            .tau(tau)
            .sample_every(16)
            .exact_gap(true)
            .eps_gap(0.05)
            .max_epochs(5000.0)
            .max_secs(30.0)
            .seed(6)
            .run_config()
            .unwrap()
    }

    #[test]
    fn sync_run_converges() {
        let p = gfl_instance();
        let r = run(&p, &cfg(3, 6));
        assert!(r.trace.last().unwrap().gap <= 0.05);
        // Sync mode with no stragglers drops nothing.
        assert_eq!(r.counters.dropped, 0);
    }

    #[test]
    fn straggler_forces_redo_work() {
        let p = gfl_instance();
        let mut c = cfg(3, 6);
        c.straggler = StragglerModel::single(3, 0.3);
        c.stop.max_epochs = 60.0;
        c.stop.eps_gap = None;
        let r = run(&p, &c);
        // Redos mean oracle calls strictly exceed applied updates.
        assert!(r.counters.dropped > 0);
        assert!(r.counters.oracle_calls > r.counters.updates_applied);
    }

    #[test]
    fn batched_assignment_converges() {
        let p = gfl_instance(); // 39 blocks
        let mut c = cfg(3, 6);
        c.batch = 2; // chunks of 2, 3 workers: 6 <= 39
        let r = run(&p, &c);
        assert!(r.trace.last().unwrap().gap <= 0.05);
        assert_eq!(r.counters.dropped, 0);
    }

    #[test]
    fn every_iteration_applies_exactly_tau() {
        let p = gfl_instance();
        let mut c = cfg(2, 5);
        c.stop.eps_gap = None;
        c.stop.max_epochs = 20.0;
        let r = run(&p, &c);
        assert_eq!(
            r.counters.updates_applied,
            r.counters.iterations * 5
        );
    }
}
