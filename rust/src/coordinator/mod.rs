//! The AP-BCFW coordinator — the paper's system contribution (Algorithms
//! 1-3), on real threads.
//!
//! - [`shared`]: the lock-free shared parameter (f32-in-atomics + version).
//! - [`buffer`]: the server's update buffer with collision-overwrite and
//!   disjoint-tau batch assembly (Algorithm 1, step 1).
//! - [`apply`]: the transport-agnostic server core — staleness verdict,
//!   delay stamping, step schedule, gap EMA, averaging, stop checks —
//!   shared by [`apbcfw`], the TCP serve role, and its shards.
//! - [`apbcfw`]: the asynchronous server/worker runtime (Algorithms 1-2).
//! - [`sync`]: SP-BCFW, the synchronous comparator of §3.3.
//! - [`lockfree`]: the tau = 1 serverless variant (Algorithm 3).
//!
//! These are the threaded engine implementations behind the unified
//! [`crate::run::Runner`] API — prefer launching them through a
//! [`crate::run::RunSpec`], which lowers to the [`RunConfig`] consumed
//! here. Each engine exposes a `run` entry point plus a `run_observed`
//! variant that streams live [`crate::run::Observer`] events from the
//! server/monitor thread.

pub mod apbcfw;
pub mod apply;
pub mod buffer;
pub mod lockfree;
pub mod shared;
pub mod sync;

use crate::problems::BlockOracle;
use crate::util::rng::Pcg64;

/// Message from a worker to the server: a multi-block payload of oracles
/// for pairwise-distinct blocks, all solved against ONE shared-parameter
/// snapshot (the batched fan-out that amortizes snapshot reads across
/// `RunConfig::batch` solves). Single-block workers (`batch = 1`) send a
/// one-entry payload through exactly the same path.
pub struct UpdateMsg {
    /// Oracles for pairwise-distinct blocks (length = worker batch).
    pub oracles: Vec<BlockOracle>,
    /// Server iteration whose parameter the oracles were computed from.
    pub k_read: u64,
    /// Sender worker id.
    pub worker: usize,
    /// Session generation the sender computed under. In-process engines
    /// always run generation 0; the net serve role bumps its generation
    /// on every restore from a durable checkpoint, and
    /// [`apply::ApplyCore::ingest`] fences messages whose generation is
    /// not the core's own (`stale_fenced`) so pre-crash in-flight
    /// oracles can never corrupt a restored parameter.
    pub generation: u64,
}

/// Sample the `batch` pairwise-distinct blocks a worker solves against one
/// snapshot. At `batch = 1` this consumes exactly one `rng.below(n)` draw
/// and yields its value — bit-identical, draw-for-draw, to the historical
/// single-block worker path (pinned in
/// `rust/tests/batched_fanout_equivalence.rs`); for larger batches it is a
/// uniform size-`batch` subset via Floyd's sampling — O(batch) work per
/// round, never the O(n) index fill of `subset_into`, so block selection
/// stays off the worker's critical path at any problem size. (The subset
/// is uniform; its order is not, which no engine depends on: the async
/// server re-orders batches by block anyway, and lockfree's per-block
/// updates are order-agnostic.)
#[inline]
pub fn pick_blocks(
    rng: &mut Pcg64,
    n: usize,
    batch: usize,
    out: &mut Vec<usize>,
) {
    if batch <= 1 {
        // Same single draw as `subset_into(n, 1, ..)` without its O(n)
        // index fill: out[0] = swap target of the first Fisher-Yates step,
        // which over 0..n is the drawn index itself.
        out.clear();
        out.push(rng.below(n));
    } else {
        debug_assert!(batch <= n);
        out.clear();
        for i in (n - batch)..n {
            let j = rng.below(i + 1);
            // Linear membership scan: batch is small (tau_w), so this
            // beats any set structure and allocates nothing.
            if out.contains(&j) {
                out.push(i);
            } else {
                out.push(j);
            }
        }
    }
}

/// Configuration of the threaded coordinator runs.
///
/// Production call sites never build this directly: a
/// [`crate::run::RunSpec`] lowers to it via `RunSpec::run_config`, which
/// also derives the straggler model's arity from `workers` (the
/// `Default` below pairs `workers: 2` with `StragglerModel::none(2)`, but
/// a struct-update override of `workers` alone would desynchronize them —
/// the spec builder makes that unrepresentable). Direct construction is
/// reserved for `rust/src/run/` and the equivalence tests that pin the
/// lowering.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Number of worker threads T.
    pub workers: usize,
    /// Minibatch size tau.
    pub tau: usize,
    /// Worker fan-out batch tau_w: distinct blocks each worker solves per
    /// shared-parameter snapshot, submitted as one multi-block payload.
    /// 1 reproduces the historical single-block worker loop exactly;
    /// larger values amortize the O(dim) snapshot read across `batch`
    /// oracle solves. Engines require `batch * workers <= n` when
    /// `batch > 1` (the `RunSpec` lowering validates this).
    pub batch: usize,
    /// Oracle payload representation workers request (`run.payload`):
    /// `Auto` resolves to each problem's natural representation, pinned
    /// bit-identical to `Dense` by the equivalence tests — see the payload
    /// representation contract in `crate::problems`.
    pub payload: crate::problems::PayloadMode,
    /// Exact line search on the server.
    pub line_search: bool,
    /// Enforce the paper's staleness rule (drop updates older than k/2).
    pub staleness_rule: bool,
    /// Straggler model (return probabilities per worker).
    pub straggler: crate::sim::straggler::StragglerModel,
    /// Extra oracle work multiplier range [lo, hi] (Fig 2d "harder
    /// subproblems": each solve is repeated m ~ Uniform(lo, hi) times).
    pub work_multiplier: (u32, u32),
    /// Trace sample cadence in server iterations.
    pub sample_every: usize,
    /// Compute exact duality gap at sample points (expensive).
    pub exact_gap: bool,
    /// Collision policy: true = overwrite pending updates with fresher
    /// ones (paper Algorithm 1 step 1); false = keep the old one
    /// (ablation).
    pub collision_overwrite: bool,
    /// Worker->server queue capacity as a multiple of tau (backpressure
    /// depth; see §Perf).
    pub queue_factor: usize,
    /// Weighted iterate averaging x-bar_k (rho_k prop. to k) on the server,
    /// matching the sequential solvers' option — the SSVM experiments
    /// report the averaged iterate.
    pub weighted_averaging: bool,
    /// Shared-parameter snapshot contract: `Torn` is the paper's §2.3
    /// inconsistent-read regime (default); `Consistent` serves seqlock
    /// snapshots for the consistent-read comparison scenario.
    pub snapshot_mode: shared::SnapshotMode,
    /// Delay-adaptive control policies (`run.adapt.*`). The all-off
    /// default leaves every engine on its historical code path
    /// bit-for-bit; in-process engines honor `step` and `drop` (the
    /// `batch` policy only acts in the net worker loop, mirroring how
    /// `run.chaos` parses everywhere but injects only on the wire).
    pub adapt: crate::sim::adapt::AdaptSpec,
    pub stop: crate::solver::StopCond,
    pub seed: u64,
}

impl RunConfig {
    /// The clamped worker fan-out batch, with the n-dependent backstop
    /// check shared by every threaded engine. The production validation
    /// is `Runner::check_batch` (a clean error at dispatch); this assert
    /// guards callers that hand a `RunConfig` to an engine directly.
    pub(crate) fn worker_batch(&self, n: usize) -> usize {
        let batch = self.batch.max(1);
        assert!(
            batch == 1 || batch * self.workers <= n,
            "batch ({batch}) x workers ({}) must not exceed n = {n} blocks",
            self.workers
        );
        batch
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            tau: 2,
            batch: 1,
            payload: crate::problems::PayloadMode::Auto,
            line_search: false,
            staleness_rule: true,
            straggler: crate::sim::straggler::StragglerModel::none(2),
            work_multiplier: (1, 1),
            sample_every: 64,
            exact_gap: false,
            collision_overwrite: true,
            queue_factor: 4,
            weighted_averaging: false,
            snapshot_mode: shared::SnapshotMode::Torn,
            adapt: crate::sim::adapt::AdaptSpec::default(),
            stop: crate::solver::StopCond::default(),
            seed: 0,
        }
    }
}

/// Outcome of a threaded run.
pub struct RunResult {
    pub trace: crate::util::metrics::Trace,
    /// The reported iterate (the weighted average when averaging was on).
    pub param: Vec<f32>,
    /// The final raw (non-averaged) master iterate.
    pub raw_param: Vec<f32>,
    pub counters: crate::util::metrics::CounterSnapshot,
    pub elapsed_s: f64,
    /// Wall-clock seconds per effective data pass (n applied updates).
    pub secs_per_pass: f64,
}
