//! The AP-BCFW coordinator — the paper's system contribution (Algorithms
//! 1-3), on real threads.
//!
//! - [`shared`]: the lock-free shared parameter (f32-in-atomics + version).
//! - [`buffer`]: the server's update buffer with collision-overwrite and
//!   disjoint-tau batch assembly (Algorithm 1, step 1).
//! - [`apbcfw`]: the asynchronous server/worker runtime (Algorithms 1-2).
//! - [`sync`]: SP-BCFW, the synchronous comparator of §3.3.
//! - [`lockfree`]: the tau = 1 serverless variant (Algorithm 3).
//!
//! These are the threaded engine implementations behind the unified
//! [`crate::run::Runner`] API — prefer launching them through a
//! [`crate::run::RunSpec`], which lowers to the [`RunConfig`] consumed
//! here. Each engine exposes a `run` entry point plus a `run_observed`
//! variant that streams live [`crate::run::Observer`] events from the
//! server/monitor thread.

pub mod apbcfw;
pub mod buffer;
pub mod lockfree;
pub mod shared;
pub mod sync;

use crate::problems::BlockOracle;

/// Message from a worker to the server.
pub struct UpdateMsg {
    pub oracle: BlockOracle,
    /// Server iteration whose parameter the oracle was computed from.
    pub k_read: u64,
    /// Sender worker id.
    pub worker: usize,
}

/// Configuration of the threaded coordinator runs.
///
/// Production call sites never build this directly: a
/// [`crate::run::RunSpec`] lowers to it via `RunSpec::run_config`, which
/// also derives the straggler model's arity from `workers` (the
/// `Default` below pairs `workers: 2` with `StragglerModel::none(2)`, but
/// a struct-update override of `workers` alone would desynchronize them —
/// the spec builder makes that unrepresentable). Direct construction is
/// reserved for `rust/src/run/` and the equivalence tests that pin the
/// lowering.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Number of worker threads T.
    pub workers: usize,
    /// Minibatch size tau.
    pub tau: usize,
    /// Exact line search on the server.
    pub line_search: bool,
    /// Enforce the paper's staleness rule (drop updates older than k/2).
    pub staleness_rule: bool,
    /// Straggler model (return probabilities per worker).
    pub straggler: crate::sim::straggler::StragglerModel,
    /// Extra oracle work multiplier range [lo, hi] (Fig 2d "harder
    /// subproblems": each solve is repeated m ~ Uniform(lo, hi) times).
    pub work_multiplier: (u32, u32),
    /// Trace sample cadence in server iterations.
    pub sample_every: usize,
    /// Compute exact duality gap at sample points (expensive).
    pub exact_gap: bool,
    /// Collision policy: true = overwrite pending updates with fresher
    /// ones (paper Algorithm 1 step 1); false = keep the old one
    /// (ablation).
    pub collision_overwrite: bool,
    /// Worker->server queue capacity as a multiple of tau (backpressure
    /// depth; see §Perf).
    pub queue_factor: usize,
    /// Weighted iterate averaging x-bar_k (rho_k prop. to k) on the server,
    /// matching the sequential solvers' option — the SSVM experiments
    /// report the averaged iterate.
    pub weighted_averaging: bool,
    /// Shared-parameter snapshot contract: `Torn` is the paper's §2.3
    /// inconsistent-read regime (default); `Consistent` serves seqlock
    /// snapshots for the consistent-read comparison scenario.
    pub snapshot_mode: shared::SnapshotMode,
    pub stop: crate::solver::StopCond,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            tau: 2,
            line_search: false,
            staleness_rule: true,
            straggler: crate::sim::straggler::StragglerModel::none(2),
            work_multiplier: (1, 1),
            sample_every: 64,
            exact_gap: false,
            collision_overwrite: true,
            queue_factor: 4,
            weighted_averaging: false,
            snapshot_mode: shared::SnapshotMode::Torn,
            stop: crate::solver::StopCond::default(),
            seed: 0,
        }
    }
}

/// Outcome of a threaded run.
pub struct RunResult {
    pub trace: crate::util::metrics::Trace,
    /// The reported iterate (the weighted average when averaging was on).
    pub param: Vec<f32>,
    /// The final raw (non-averaged) master iterate.
    pub raw_param: Vec<f32>,
    pub counters: crate::util::metrics::CounterSnapshot,
    pub elapsed_s: f64,
    /// Wall-clock seconds per effective data pass (n applied updates).
    pub secs_per_pass: f64,
}
