//! Lock-free serverless AP-BCFW at tau = 1 (paper Algorithm 3).
//!
//! No server: every thread repeatedly picks `cfg.batch` distinct blocks,
//! solves their subproblems against ONE lock-free snapshot of the shared
//! parameter (the batched fan-out; `batch = 1` is the paper's per-block
//! loop), then for each block reads the global counter for its step size
//! gamma = 2n/(k+2n) and atomically adds the delta gamma (s_i - x_i) into
//! the shared block — Hogwild-style. Restricted to parameter-space
//! problems (`ServerState = ()`) with block-addressable payloads
//! ([`ProjectableProblem`] supplies `block_range`).

use super::shared::SharedParam;
use super::{pick_blocks, RunConfig, RunResult};
use crate::problems::{BlockOracle, OracleScratch, ProjectableProblem};
use crate::run::Observer;
use crate::sim::adapt::{damping_factor, KappaEma, StepPolicy};
use crate::util::metrics::{Counters, Sample, Stopwatch, Trace};
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Run the lock-free variant. `cfg.tau` is ignored (always 1).
pub fn run<P>(problem: &P, cfg: &RunConfig) -> RunResult
where
    P: ProjectableProblem<ServerState = ()>,
{
    run_observed(problem, cfg, &mut ())
}

/// Run the lock-free variant, streaming live sample events to `obs` from
/// the monitor thread. Updates land from worker threads without a server
/// step, so no `on_apply` events are emitted.
pub fn run_observed<P>(
    problem: &P,
    cfg: &RunConfig,
    obs: &mut dyn Observer,
) -> RunResult
where
    P: ProjectableProblem<ServerState = ()>,
{
    let n = problem.num_blocks();
    // Hogwild element-wise updates are inherently torn across elements; a
    // Consistent-mode request would serialize every fetch_add through the
    // seqlock and still not give cross-element consistency guarantees the
    // algorithm could use. Reject it loudly instead of ignoring the flag.
    assert!(
        cfg.snapshot_mode == super::shared::SnapshotMode::Torn,
        "lockfree variant requires SnapshotMode::Torn (hogwild updates)"
    );
    let wbatch = cfg.worker_batch(n);
    let pkind = cfg.payload.resolve(problem.preferred_payload());
    let shared = SharedParam::new(&problem.init_param());
    let counter = AtomicU64::new(0);
    let stop = AtomicBool::new(false);
    let counters = Counters::new();
    let watch = Stopwatch::start();
    let mut trace = Trace::default();

    std::thread::scope(|scope| {
        for w in 0..cfg.workers {
            let shared = &shared;
            let counter = &counter;
            let stop = &stop;
            let counters = &counters;
            let seed = cfg.seed;
            let adapt_step = cfg.adapt.step;
            scope.spawn(move || {
                let mut rng = Pcg64::new(seed, 3000 + w as u64);
                let mut snapshot: Vec<f32> = Vec::new();
                let mut blocks: Vec<usize> = Vec::new();
                // Smoothed observed kappa for `run.adapt.step = kappa`:
                // serverless, so each thread damps against its own view
                // of the global counter (delay = counter at apply minus
                // counter at snapshot read).
                let mut kappa = KappaEma::new();
                // The oracles never leave this thread, so one slot per
                // batch position plus one caller-owned oracle scratch
                // serve the whole run — the loop is allocation-free in
                // steady state (§Perf).
                let mut oscratch = OracleScratch::<P>::default();
                let mut slots: Vec<BlockOracle> = (0..wbatch)
                    .map(|_| BlockOracle::empty_with(pkind))
                    .collect();
                while !stop.load(Ordering::Acquire) {
                    // tau_w distinct blocks, all solved against the one
                    // snapshot read below (one `below(n)` draw at 1 — the
                    // historical per-block loop).
                    pick_blocks(&mut rng, n, wbatch, &mut blocks);
                    shared.read(&mut snapshot);
                    // The counter value this round's snapshot was read
                    // at — the k_read of the delay stamp below.
                    let round_k = counter.load(Ordering::Relaxed);
                    Counters::bump(&counters.snapshot_reads);
                    let (mut nnz, mut bytes) = (0u64, 0u64);
                    for (slot, &i) in slots.iter_mut().zip(blocks.iter()) {
                        problem.oracle_into(&snapshot, i, &mut oscratch, slot);
                        Counters::bump(&counters.oracle_calls);
                        nnz += slot.s.nnz() as u64;
                        bytes += slot.s.wire_bytes() as u64;
                    }
                    // Serverless: nothing crosses a channel, but the
                    // telemetry still reports what a distributed
                    // deployment of this loop would ship.
                    Counters::add(&counters.payload_nnz, nnz);
                    Counters::add(&counters.payload_bytes, bytes);
                    // Apply per block: each update reads the counter for
                    // its own step size, exactly as the per-block loop
                    // did. The dense arm keeps the historical indexed
                    // loop; the sparse arm streams `dense_iter`, which
                    // yields the same float sequence, so the hogwild
                    // deltas are bit-identical either way.
                    for (slot, &i) in slots.iter().zip(blocks.iter()) {
                        let k = counter.load(Ordering::Relaxed);
                        let gamma = 2.0 * n as f32
                            / (k as f32 + 2.0 * n as f32);
                        // `run.adapt.step`: the Off arm is the
                        // historical gamma verbatim; Kappa damps by the
                        // smoothed observed delay (counter drift since
                        // this round's snapshot), expected kappa := the
                        // per-round fan-out width.
                        let gamma = match adapt_step {
                            StepPolicy::Off => gamma,
                            StepPolicy::Kappa => {
                                kappa.observe(k.saturating_sub(round_k));
                                let damp = damping_factor(
                                    wbatch as f64,
                                    kappa.value(),
                                );
                                Counters::add(
                                    &counters.gamma_damped_sum,
                                    ((1.0 - damp) * 1000.0).round()
                                        as u64,
                                );
                                (gamma as f64 * damp) as f32
                            }
                        };
                        let range = problem.block_range(i);
                        debug_assert_eq!(slot.s.dim(), range.len());
                        match slot.s.as_dense() {
                            Some(s) => {
                                for (j, idx) in range.enumerate() {
                                    let delta =
                                        gamma * (s[j] - snapshot[idx]);
                                    shared.fetch_add_f32(idx, delta);
                                }
                            }
                            None => {
                                for (idx, sj) in
                                    range.zip(slot.s.dense_iter())
                                {
                                    let delta =
                                        gamma * (sj - snapshot[idx]);
                                    shared.fetch_add_f32(idx, delta);
                                }
                            }
                        }
                        counter.fetch_add(1, Ordering::Relaxed);
                        Counters::bump(&counters.updates_applied);
                    }
                }
            });
        }

        // Monitor thread (this thread): sample + stop conditions.
        let mut last_sampled: u64 = 0;
        loop {
            std::thread::sleep(std::time::Duration::from_millis(2));
            let k = counter.load(Ordering::Relaxed);
            if k >= last_sampled + cfg.sample_every as u64 {
                last_sampled = k;
                let param = shared.read_vec();
                let objective = problem.objective_from(&param, 0.0);
                let gap = if cfg.exact_gap {
                    problem.full_gap(&(), &param)
                } else {
                    f64::NAN
                };
                let snap = counters.snapshot();
                let sample = Sample {
                    iter: k as usize,
                    oracle_calls: snap.oracle_calls,
                    elapsed_s: watch.elapsed_s(),
                    objective,
                    gap,
                };
                obs.on_sample(&sample);
                trace.push(sample);
                let epochs = snap.oracle_calls as f64 / n as f64;
                if cfg.stop.target_met(objective, gap)
                    || cfg.stop.exhausted(epochs, watch.elapsed_s())
                {
                    break;
                }
            }
            let snap = counters.snapshot();
            if cfg
                .stop
                .exhausted(snap.oracle_calls as f64 / n as f64, watch.elapsed_s())
            {
                break;
            }
        }
        stop.store(true, Ordering::Release);
    });

    let mut snap = counters.snapshot();
    snap.iterations = counter.load(Ordering::Relaxed);
    let elapsed_s = watch.elapsed_s();
    let passes = snap.updates_applied as f64 / n as f64;
    let secs_per_pass = if passes > 0.0 {
        elapsed_s / passes
    } else {
        f64::INFINITY
    };
    let param = shared.read_vec();
    let objective = problem.objective_from(&param, 0.0);
    let gap = problem.full_gap(&(), &param);
    let sample = Sample {
        iter: snap.iterations as usize,
        oracle_calls: snap.oracle_calls,
        elapsed_s,
        objective,
        gap,
    };
    obs.on_sample(&sample);
    trace.push(sample);

    RunResult {
        trace,
        raw_param: param.clone(),
        param,
        counters: snap,
        elapsed_s,
        secs_per_pass,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::run::{Engine, RunSpec};
    use crate::util::rng::Pcg64;

    fn gfl_instance() -> Gfl {
        let mut rng = Pcg64::seeded(99);
        let (d, n) = (6, 40);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.2, y)
    }

    fn cfg(workers: usize) -> RunConfig {
        RunSpec::new(Engine::lockfree(workers))
            .sample_every(64)
            .exact_gap(true)
            .eps_gap(0.1)
            .max_epochs(5000.0)
            .max_secs(30.0)
            .seed(9)
            .run_config()
            .unwrap()
    }

    #[test]
    fn lockfree_converges_single_thread() {
        let p = gfl_instance();
        let r = run(&p, &cfg(1));
        assert!(r.trace.last().unwrap().gap <= 0.1);
    }

    #[test]
    fn lockfree_converges_multi_thread() {
        let p = gfl_instance();
        let r = run(&p, &cfg(4));
        assert!(
            r.trace.last().unwrap().gap <= 0.15,
            "gap={}",
            r.trace.last().unwrap().gap
        );
        assert!(r.counters.updates_applied > 0);
    }

    #[test]
    fn batched_lockfree_converges() {
        let p = gfl_instance(); // 39 blocks
        let mut c = cfg(2);
        c.batch = 4; // 4 x 2 <= 39
        let r = run(&p, &c);
        assert!(
            r.trace.last().unwrap().gap <= 0.15,
            "gap={}",
            r.trace.last().unwrap().gap
        );
        // One snapshot read serves the whole 4-block round.
        assert!(
            r.counters.snapshot_reads <= r.counters.oracle_calls / 4 + 2,
            "reads={} calls={}",
            r.counters.snapshot_reads,
            r.counters.oracle_calls
        );
    }

    #[test]
    fn near_feasibility_multi_thread() {
        // Hogwild updates can transiently overshoot the ball; the final
        // iterate must stay within a small tolerance of feasibility.
        let p = gfl_instance();
        let r = run(&p, &cfg(4));
        for t in 0..p.m {
            let nrm =
                crate::util::la::norm2(&r.param[t * p.d..(t + 1) * p.d]);
            assert!(nrm <= p.lam * 1.5 + 1e-4, "block {t} norm {nrm}");
        }
    }
}
