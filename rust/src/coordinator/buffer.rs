//! Server-side update buffer: Algorithm 1, step 1.
//!
//! "Read from buffer until it has updates for tau disjoint blocks
//! (overwrite in case of collision)." The assembler ingests worker updates
//! one at a time; a second update for a block already pending *replaces* it
//! (it was computed from a fresher parameter), counting a collision. When
//! tau distinct blocks are pending, `take_batch` drains them.

use super::UpdateMsg;
use std::collections::HashMap;

/// Disjoint-block batch assembler with collision-overwrite semantics.
#[derive(Default)]
pub struct BatchAssembler {
    pending: HashMap<usize, UpdateMsg>,
    collisions: u64,
}

impl BatchAssembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ingest one update. Returns true if it overwrote a pending one.
    pub fn insert(&mut self, msg: UpdateMsg) -> bool {
        let collided = self
            .pending
            .insert(msg.oracle.block, msg)
            .is_some();
        if collided {
            self.collisions += 1;
        }
        collided
    }

    /// Ablation variant: on collision keep the OLD pending update instead
    /// of the fresher one. Returns true if the new update was discarded.
    pub fn insert_keep_old(&mut self, msg: UpdateMsg) -> bool {
        use std::collections::hash_map::Entry;
        match self.pending.entry(msg.oracle.block) {
            Entry::Occupied(_) => {
                self.collisions += 1;
                true
            }
            Entry::Vacant(v) => {
                v.insert(msg);
                false
            }
        }
    }

    /// Number of distinct blocks pending.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Total collisions observed so far.
    pub fn collisions(&self) -> u64 {
        self.collisions
    }

    /// If at least `tau` distinct blocks are pending, drain and return
    /// exactly the pending set (which is disjoint by construction).
    pub fn take_batch(&mut self, tau: usize) -> Option<Vec<UpdateMsg>> {
        if self.pending.len() < tau {
            return None;
        }
        Some(self.pending.drain().map(|(_, m)| m).collect())
    }

    /// Drop every pending update (used on shutdown).
    pub fn clear(&mut self) {
        self.pending.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::BlockOracle;

    fn msg(block: usize, k_read: u64) -> UpdateMsg {
        UpdateMsg {
            oracle: BlockOracle {
                block,
                s: vec![k_read as f32],
                ls: 0.0,
            },
            k_read,
            worker: 0,
        }
    }

    #[test]
    fn assembles_disjoint_batches() {
        let mut asm = BatchAssembler::new();
        asm.insert(msg(1, 0));
        asm.insert(msg(2, 0));
        assert!(asm.take_batch(3).is_none());
        asm.insert(msg(3, 0));
        let batch = asm.take_batch(3).unwrap();
        assert_eq!(batch.len(), 3);
        let mut blocks: Vec<usize> =
            batch.iter().map(|m| m.oracle.block).collect();
        blocks.sort_unstable();
        assert_eq!(blocks, vec![1, 2, 3]);
        assert!(asm.is_empty());
    }

    #[test]
    fn collision_overwrites_with_fresher_update() {
        let mut asm = BatchAssembler::new();
        assert!(!asm.insert(msg(5, 1)));
        assert!(asm.insert(msg(5, 9))); // collision
        assert_eq!(asm.collisions(), 1);
        assert_eq!(asm.len(), 1);
        let batch = asm.take_batch(1).unwrap();
        assert_eq!(batch[0].k_read, 9, "must keep the fresher update");
    }

    #[test]
    fn batch_never_contains_duplicate_blocks() {
        let mut asm = BatchAssembler::new();
        for i in 0..100 {
            asm.insert(msg(i % 10, i as u64));
        }
        let batch = asm.take_batch(10).unwrap();
        let mut blocks: Vec<usize> =
            batch.iter().map(|m| m.oracle.block).collect();
        blocks.sort_unstable();
        blocks.dedup();
        assert_eq!(blocks.len(), 10);
        assert_eq!(asm.collisions(), 90);
    }

    #[test]
    fn clear_empties() {
        let mut asm = BatchAssembler::new();
        asm.insert(msg(1, 0));
        asm.clear();
        assert!(asm.is_empty());
        assert!(asm.take_batch(1).is_none());
    }
}
