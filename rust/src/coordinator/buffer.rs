//! Server-side update buffer: Algorithm 1, step 1.
//!
//! "Read from buffer until it has updates for tau disjoint blocks
//! (overwrite in case of collision)." The assembler ingests worker
//! messages — each a multi-block payload of oracles for distinct blocks
//! solved against one snapshot — and tracks one pending update per block; a
//! second update for a block already pending *replaces* it (it was computed
//! from a fresher parameter), counting a collision. When tau distinct
//! blocks are pending, `take_batch` drains them **in ascending block
//! order**, so the applied batch (and therefore every float accumulated
//! over it) is a deterministic function of the pending set — what lets the
//! batched-fan-out equivalence tests compare single-block and multi-block
//! ingestion bit-for-bit.
//!
//! §Perf: `insert` consumes the message's payload container and hands it
//! back emptied (refilled with any displaced oracles), so the server can
//! recycle both the container and the displaced `s` buffers to workers
//! instead of allocating per round trip.

use super::UpdateMsg;
use crate::problems::BlockOracle;
use std::collections::HashMap;

/// One pending per-block update inside the assembler.
pub struct PendingUpdate {
    pub oracle: BlockOracle,
    /// Server iteration whose parameter the oracle was computed from.
    pub k_read: u64,
    /// Worker that solved it.
    pub worker: usize,
}

impl PendingUpdate {
    /// Observed delay at server iteration `k_now`: how many applies
    /// happened between this oracle's snapshot and now. Servers stamp
    /// every applied update with this at apply time (the
    /// `delay_sum`/`delay_max` counters — the empirical expected-delay
    /// kappa of the paper's §2.3/§3.4 analysis).
    #[inline]
    pub fn delay(&self, k_now: u64) -> u64 {
        k_now.saturating_sub(self.k_read)
    }
}

/// Disjoint-block batch assembler with collision-overwrite semantics.
#[derive(Default)]
pub struct BatchAssembler {
    pending: HashMap<usize, PendingUpdate>,
    collisions: u64,
}

impl BatchAssembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ingest every oracle in the message (blocks within one message are
    /// distinct by the worker contract). A block already pending is
    /// overwritten by the fresher oracle, counting a collision. Returns
    /// the message's payload container, emptied and refilled with the
    /// displaced oracles (empty when nothing collided) so the caller can
    /// recycle the buffers.
    pub fn insert(&mut self, msg: UpdateMsg) -> Vec<BlockOracle> {
        // The generation fence runs upstream in `ApplyCore::ingest`; by
        // the time a message reaches the assembler its generation has
        // already been validated, so it is dropped here.
        let UpdateMsg {
            mut oracles,
            k_read,
            worker,
            generation: _,
        } = msg;
        // Compact displaced oracles into the front of the container while
        // draining it: position `idx` has already been taken by the time
        // `kept <= idx` is written.
        let mut kept = 0usize;
        for idx in 0..oracles.len() {
            let o = std::mem::replace(&mut oracles[idx], BlockOracle::empty());
            if let Some(old) = self.pending.insert(
                o.block,
                PendingUpdate {
                    oracle: o,
                    k_read,
                    worker,
                },
            ) {
                self.collisions += 1;
                oracles[kept] = old.oracle;
                kept += 1;
            }
        }
        oracles.truncate(kept);
        oracles
    }

    /// Ablation variant: on collision keep the OLD pending update instead
    /// of the fresher one. Returns the container refilled with the
    /// discarded (new) oracles.
    pub fn insert_keep_old(&mut self, msg: UpdateMsg) -> Vec<BlockOracle> {
        use std::collections::hash_map::Entry;
        let UpdateMsg {
            mut oracles,
            k_read,
            worker,
            generation: _,
        } = msg;
        let mut kept = 0usize;
        for idx in 0..oracles.len() {
            let o = std::mem::replace(&mut oracles[idx], BlockOracle::empty());
            match self.pending.entry(o.block) {
                Entry::Occupied(_) => {
                    self.collisions += 1;
                    oracles[kept] = o;
                    kept += 1;
                }
                Entry::Vacant(v) => {
                    v.insert(PendingUpdate {
                        oracle: o,
                        k_read,
                        worker,
                    });
                }
            }
        }
        oracles.truncate(kept);
        oracles
    }

    /// Number of distinct blocks pending.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Total collisions observed so far.
    pub fn collisions(&self) -> u64 {
        self.collisions
    }

    /// If at least `tau` distinct blocks are pending, drain and return
    /// exactly the pending set (disjoint by construction), sorted by block
    /// index so the applied batch order — and every order-sensitive float
    /// reduction over it — is deterministic given the set.
    pub fn take_batch(&mut self, tau: usize) -> Option<Vec<PendingUpdate>> {
        if self.pending.len() < tau {
            return None;
        }
        let mut batch: Vec<PendingUpdate> =
            self.pending.drain().map(|(_, m)| m).collect();
        batch.sort_unstable_by_key(|p| p.oracle.block);
        Some(batch)
    }

    /// Drop every pending update contributed by `worker`, returning how
    /// many were discarded. Used when a connection is declared dead: its
    /// buffered oracles may reflect a state the worker never finished
    /// shipping, and the freed blocks fall back into the sampling pool
    /// (counted by the server's `blocks_requeued` telemetry).
    pub fn remove_worker(&mut self, worker: usize) -> usize {
        let before = self.pending.len();
        self.pending.retain(|_, p| p.worker != worker);
        before - self.pending.len()
    }

    /// Drop every pending update (used on shutdown).
    pub fn clear(&mut self) {
        self.pending.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(block: usize, k_read: u64) -> UpdateMsg {
        UpdateMsg {
            oracles: vec![BlockOracle::dense(block, vec![k_read as f32], 0.0)],
            k_read,
            worker: 0,
            generation: 0,
        }
    }

    fn multi_msg(blocks: &[usize], k_read: u64) -> UpdateMsg {
        UpdateMsg {
            oracles: blocks
                .iter()
                .map(|&block| {
                    BlockOracle::dense(block, vec![k_read as f32], 0.0)
                })
                .collect(),
            k_read,
            worker: 0,
            generation: 0,
        }
    }

    fn sparse_msg(block: usize, k_read: u64) -> UpdateMsg {
        UpdateMsg {
            oracles: vec![BlockOracle {
                block,
                s: crate::problems::OraclePayload::Sparse {
                    idx: vec![0],
                    val: vec![k_read as f32],
                    dim: 4,
                },
                ls: 0.0,
            }],
            k_read,
            worker: 0,
            generation: 0,
        }
    }

    #[test]
    fn assembles_disjoint_batches() {
        let mut asm = BatchAssembler::new();
        asm.insert(msg(1, 0));
        asm.insert(msg(2, 0));
        assert!(asm.take_batch(3).is_none());
        asm.insert(msg(3, 0));
        let batch = asm.take_batch(3).unwrap();
        assert_eq!(batch.len(), 3);
        let blocks: Vec<usize> =
            batch.iter().map(|m| m.oracle.block).collect();
        // take_batch returns blocks in ascending order (deterministic).
        assert_eq!(blocks, vec![1, 2, 3]);
        assert!(asm.is_empty());
    }

    #[test]
    fn collision_overwrites_with_fresher_update() {
        let mut asm = BatchAssembler::new();
        assert!(asm.insert(msg(5, 1)).is_empty());
        let displaced = asm.insert(msg(5, 9)); // collision
        assert_eq!(displaced.len(), 1, "old oracle handed back for recycle");
        assert_eq!(displaced[0].s.as_dense().unwrap(), &[1.0f32]);
        assert_eq!(asm.collisions(), 1);
        assert_eq!(asm.len(), 1);
        let batch = asm.take_batch(1).unwrap();
        assert_eq!(batch[0].k_read, 9, "must keep the fresher update");
    }

    #[test]
    fn keep_old_discards_new_and_returns_it() {
        let mut asm = BatchAssembler::new();
        assert!(asm.insert_keep_old(msg(5, 1)).is_empty());
        let discarded = asm.insert_keep_old(msg(5, 9));
        assert_eq!(discarded.len(), 1);
        assert_eq!(
            discarded[0].s.as_dense().unwrap(),
            &[9.0f32],
            "new oracle discarded"
        );
        assert_eq!(asm.collisions(), 1);
        let batch = asm.take_batch(1).unwrap();
        assert_eq!(batch[0].k_read, 1, "must keep the old update");
    }

    #[test]
    fn displaced_sparse_containers_are_handed_back_for_recycling() {
        // Collision handling is representation-agnostic: a displaced
        // sparse oracle comes back with its idx/val buffers intact (the
        // engines' pools then reuse them), under BOTH collision policies.
        let mut asm = BatchAssembler::new();
        assert!(asm.insert(sparse_msg(3, 1)).is_empty());
        let displaced = asm.insert(sparse_msg(3, 2));
        assert_eq!(displaced.len(), 1);
        match &displaced[0].s {
            crate::problems::OraclePayload::Sparse { idx, val, .. } => {
                assert_eq!(idx, &[0u32]);
                assert_eq!(val, &[1.0f32]);
            }
            other => panic!("displaced payload densified: {other:?}"),
        }
        let mut asm = BatchAssembler::new();
        assert!(asm.insert_keep_old(sparse_msg(3, 1)).is_empty());
        let discarded = asm.insert_keep_old(sparse_msg(3, 2));
        assert_eq!(discarded.len(), 1);
        match &discarded[0].s {
            crate::problems::OraclePayload::Sparse { val, .. } => {
                assert_eq!(val, &[2.0f32]);
            }
            other => panic!("discarded payload densified: {other:?}"),
        }
        // A sparse update that wins the collision is applied as-is.
        let batch = asm.take_batch(1).unwrap();
        assert_eq!(batch[0].oracle.s.nnz(), 1);
    }

    #[test]
    fn multi_block_payload_merges_like_single_messages() {
        // One 3-block message must leave the assembler in exactly the
        // state three 1-block messages would.
        let mut grouped = BatchAssembler::new();
        grouped.insert(multi_msg(&[4, 7, 9], 2));
        let mut single = BatchAssembler::new();
        for b in [4usize, 7, 9] {
            single.insert(msg(b, 2));
        }
        let a = grouped.take_batch(3).unwrap();
        let b = single.take_batch(3).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.oracle.block, y.oracle.block);
            assert_eq!(x.oracle.s, y.oracle.s);
            assert_eq!(x.k_read, y.k_read);
        }
        assert_eq!(grouped.collisions(), single.collisions());
    }

    #[test]
    fn batch_never_contains_duplicate_blocks() {
        let mut asm = BatchAssembler::new();
        for i in 0..100 {
            asm.insert(msg(i % 10, i as u64));
        }
        let batch = asm.take_batch(10).unwrap();
        let mut blocks: Vec<usize> =
            batch.iter().map(|m| m.oracle.block).collect();
        blocks.sort_unstable();
        blocks.dedup();
        assert_eq!(blocks.len(), 10);
        assert_eq!(asm.collisions(), 90);
    }

    #[test]
    fn insert_returns_emptied_container_for_recycling() {
        let mut asm = BatchAssembler::new();
        let empties = asm.insert(multi_msg(&[0, 1, 2], 0));
        assert!(empties.is_empty());
        assert!(empties.capacity() >= 3, "container kept for reuse");
    }

    #[test]
    fn remove_worker_discards_only_its_pending_updates() {
        let mut asm = BatchAssembler::new();
        asm.insert(UpdateMsg {
            oracles: vec![
                BlockOracle::dense(1, vec![0.0], 0.0),
                BlockOracle::dense(2, vec![0.0], 0.0),
            ],
            k_read: 0,
            worker: 7,
            generation: 0,
        });
        asm.insert(msg(3, 0)); // worker 0
        assert_eq!(asm.remove_worker(7), 2);
        assert_eq!(asm.remove_worker(7), 0);
        assert_eq!(asm.len(), 1);
        let batch = asm.take_batch(1).unwrap();
        assert_eq!(batch[0].oracle.block, 3);
    }

    #[test]
    fn clear_empties() {
        let mut asm = BatchAssembler::new();
        asm.insert(msg(1, 0));
        asm.clear();
        assert!(asm.is_empty());
        assert!(asm.take_batch(1).is_none());
    }
}
