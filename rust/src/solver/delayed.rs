//! Delayed-oracle BCFW: the paper's §2.3/§3.4 staleness model, simulated
//! deterministically (single thread).
//!
//! Each update's oracle is evaluated on the parameter from `kappa_j`
//! iterations ago, with `kappa_j` iid from a [`DelayModel`]; updates whose
//! delay exceeds `k/2` are dropped (the paper's acceptance rule), counting
//! the oracle work but applying nothing. This isolates the *statistical*
//! effect of staleness from system noise — exactly the Fig 4 experiment.

use super::{schedule_gamma, Monitor, SolveOptions, SolveResult};
use crate::problems::{ApplyOptions, BlockOracle, OracleScratch, Problem};
use crate::sim::delay::{accept_delay, DelayModel, History};
use crate::util::rng::Pcg64;

/// Extra options for the delayed solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DelayOptions {
    pub model: DelayModel,
    /// History capacity (delays beyond this are treated as > k/2 and
    /// dropped; set comfortably above the expected delay).
    pub history: usize,
    /// Enforce the paper's k/2 staleness rule (ablation: set false to
    /// accept arbitrarily stale updates that are still in history).
    pub enforce_drop_rule: bool,
}

impl Default for DelayOptions {
    fn default() -> Self {
        Self {
            model: DelayModel::None,
            history: 512,
            enforce_drop_rule: true,
        }
    }
}

/// Run minibatch BCFW with iid staleness on the oracle inputs.
pub fn solve<P: Problem>(
    problem: &P,
    opts: &SolveOptions,
    dopts: &DelayOptions,
) -> SolveResult {
    solve_observed(problem, opts, dopts, &mut ())
}

/// Run delayed-oracle BCFW, streaming live events to `obs`.
pub fn solve_observed<P: Problem>(
    problem: &P,
    opts: &SolveOptions,
    dopts: &DelayOptions,
    obs: &mut dyn crate::run::Observer,
) -> SolveResult {
    let n = problem.num_blocks();
    let tau = opts.tau.clamp(1, n);
    let mut rng = Pcg64::new(opts.seed, crate::net::rng_stream_for(0));
    let mut param = problem.init_param();
    let mut state = problem.init_server();
    let mut mon = Monitor::new(problem, opts, obs);
    let mut hist = History::new(dopts.history);
    hist.push(0, &param);

    // Persistent scratch: index buffer, caller-owned oracle scratch, and
    // tau oracle slots (in the `run.payload`-requested representation);
    // accepted updates fill slots[..used] in place each iteration (§Perf).
    let pkind = opts.payload.resolve(problem.preferred_payload());
    let mut blocks: Vec<usize> = Vec::new();
    let mut oscratch = OracleScratch::<P>::default();
    let mut slots: Vec<BlockOracle> =
        (0..tau).map(|_| BlockOracle::empty_with(pkind)).collect();

    let mut oracle_calls: u64 = 0;
    let mut dropped: u64 = 0;
    let mut k: u64 = 0;
    loop {
        rng.subset_into(n, tau, &mut blocks);
        let mut used = 0usize;
        for &i in &blocks {
            let delay = dopts.model.sample(&mut rng);
            oracle_calls += 1;
            if dopts.enforce_drop_rule && !accept_delay(k, delay) {
                dropped += 1;
                continue;
            }
            match hist.get(delay) {
                Some(stale) => {
                    problem.oracle_into(stale, i, &mut oscratch, &mut slots[used]);
                    used += 1;
                }
                None => {
                    // Evicted from history: equivalent to an over-stale
                    // update, dropped by the same rule.
                    dropped += 1;
                }
            }
        }
        if used > 0 {
            let batch = &slots[..used];
            let gamma = schedule_gamma(n, tau, k);
            let info = problem.apply(
                &mut state,
                &mut param,
                batch,
                ApplyOptions {
                    gamma,
                    line_search: opts.line_search,
                },
            );
            mon.after_apply(k + 1, &param, &state, info, used);
        }
        k += 1;
        hist.push(k, &param);

        if k % opts.sample_every as u64 == 0
            && mon.sample_and_check(k, oracle_calls, &param, &state)
        {
            break;
        }
        if k % 1024 == 0 {
            let epochs = oracle_calls as f64 / n as f64;
            if opts.stop.exhausted(epochs, mon.watch.elapsed_s()) {
                mon.sample_and_check(k, oracle_calls, &param, &state);
                break;
            }
        }
    }

    let final_param = mon.eval_param(&param).to_vec();
    SolveResult {
        trace: mon.trace,
        param: final_param,
        raw_param: param,
        oracle_calls,
        iterations: k,
        dropped,
        elapsed_s: mon.watch.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::run::{Engine, RunSpec};
    use crate::util::rng::Pcg64;

    fn gfl_instance() -> Gfl {
        let mut rng = Pcg64::seeded(31);
        let (d, n) = (6, 40);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.2, y)
    }

    fn opts() -> SolveOptions {
        RunSpec::new(Engine::delayed(DelayModel::None))
            .tau(1)
            .sample_every(32)
            .exact_gap(true)
            .eps_gap(0.1)
            .max_epochs(3000.0)
            .max_secs(60.0)
            .seed(3)
            .solve_options()
    }

    #[test]
    fn zero_delay_equals_minibatch_solver_quality() {
        let p = gfl_instance();
        let r = solve(&p, &opts(), &DelayOptions::default());
        assert_eq!(r.dropped, 0);
        assert!(r.trace.last().unwrap().gap <= 0.1);
    }

    #[test]
    fn poisson_delay_still_converges_with_modest_slowdown() {
        // Paper Fig 4: with expected delay up to 20, fewer than 2x as many
        // iterations to reach gap 0.1. Allow 3x margin for our instance.
        let p = gfl_instance();
        let r0 = solve(&p, &opts(), &DelayOptions::default());
        let r = solve(
            &p,
            &opts(),
            &DelayOptions {
                model: DelayModel::Poisson { kappa: 10.0 },
                history: 4096,
                ..Default::default()
            },
        );
        assert!(r.trace.last().unwrap().gap <= 0.1, "did not converge");
        let it0 = r0.iterations as f64;
        let it = r.iterations as f64;
        assert!(it < 3.0 * it0, "delay slowdown too large: {it0} -> {it}");
    }

    #[test]
    fn pareto_delay_converges_and_drops_some() {
        let p = gfl_instance();
        let r = solve(
            &p,
            &opts(),
            &DelayOptions {
                model: DelayModel::pareto_with_mean(10.0),
                history: 4096,
                ..Default::default()
            },
        );
        assert!(r.trace.last().unwrap().gap <= 0.1);
        // heavy tail must trigger at least one early drop
        assert!(r.dropped > 0);
    }

    #[test]
    fn feasibility_under_delay() {
        let p = gfl_instance();
        let r = solve(
            &p,
            &opts(),
            &DelayOptions {
                model: DelayModel::Fixed(5),
                history: 64,
                ..Default::default()
            },
        );
        for t in 0..p.m {
            let nrm = crate::util::la::norm2(
                &r.raw_param[t * p.d..(t + 1) * p.d],
            );
            assert!(nrm <= p.lam + 1e-5);
        }
    }

    #[test]
    fn early_iterations_enforce_drop_rule() {
        // With Fixed(4), nothing can be applied before k = 8.
        let p = gfl_instance();
        let mut o = opts();
        o.stop.max_epochs = 1.0;
        let r = solve(
            &p,
            &o,
            &DelayOptions {
                model: DelayModel::Fixed(4),
                history: 64,
                ..Default::default()
            },
        );
        assert!(r.dropped >= 8, "dropped={}", r.dropped);
    }
}
