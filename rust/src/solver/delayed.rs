//! Delayed-oracle BCFW: the paper's §2.3/§3.4 staleness model, simulated
//! deterministically (single thread).
//!
//! Each update's oracle is evaluated on the parameter from `kappa_j`
//! iterations ago, with `kappa_j` iid from a [`DelayModel`]; updates whose
//! delay exceeds `k/2` are dropped (the paper's acceptance rule), counting
//! the oracle work but applying nothing. This isolates the *statistical*
//! effect of staleness from system noise — exactly the Fig 4 experiment.

use super::{schedule_gamma, Monitor, SolveOptions, SolveResult};
use crate::problems::{ApplyOptions, BlockOracle, OracleScratch, Problem};
use crate::sim::adapt::{
    accept_delay_adjusted, damping_factor, AdaptSpec, DelayWindowRing,
    DropPolicy, KappaEma, StepPolicy, DELAY_WINDOW,
};
use crate::sim::delay::{accept_delay, DelayModel, History};
use crate::util::rng::Pcg64;

/// Extra options for the delayed solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DelayOptions {
    pub model: DelayModel,
    /// History capacity (delays beyond this are treated as > k/2 and
    /// dropped; set comfortably above the expected delay).
    pub history: usize,
    /// Enforce the paper's k/2 staleness rule (ablation: set false to
    /// accept arbitrarily stale updates that are still in history).
    pub enforce_drop_rule: bool,
    /// Delay-adaptive policies (`run.adapt.step` / `run.adapt.drop`;
    /// the batch policy is net-only and ignored here). The all-off
    /// default keeps this engine on its historical path bit-for-bit.
    pub adapt: AdaptSpec,
}

impl Default for DelayOptions {
    fn default() -> Self {
        Self {
            model: DelayModel::None,
            history: 512,
            enforce_drop_rule: true,
            adapt: AdaptSpec::default(),
        }
    }
}

/// Run minibatch BCFW with iid staleness on the oracle inputs.
pub fn solve<P: Problem>(
    problem: &P,
    opts: &SolveOptions,
    dopts: &DelayOptions,
) -> SolveResult {
    solve_observed(problem, opts, dopts, &mut ())
}

/// Run delayed-oracle BCFW, streaming live events to `obs`.
pub fn solve_observed<P: Problem>(
    problem: &P,
    opts: &SolveOptions,
    dopts: &DelayOptions,
    obs: &mut dyn crate::run::Observer,
) -> SolveResult {
    let n = problem.num_blocks();
    let tau = opts.tau.clamp(1, n);
    let mut rng = Pcg64::new(opts.seed, crate::net::rng_stream_for(0));
    let mut param = problem.init_param();
    let mut state = problem.init_server();
    let mut mon = Monitor::new(problem, opts, obs);
    let mut hist = History::new(dopts.history);
    hist.push(0, &param);

    // Persistent scratch: index buffer, caller-owned oracle scratch, and
    // tau oracle slots (in the `run.payload`-requested representation);
    // accepted updates fill slots[..used] in place each iteration (§Perf).
    let pkind = opts.payload.resolve(problem.preferred_payload());
    let mut blocks: Vec<usize> = Vec::new();
    let mut oscratch = OracleScratch::<P>::default();
    let mut slots: Vec<BlockOracle> =
        (0..tau).map(|_| BlockOracle::empty_with(pkind)).collect();

    let mut oracle_calls: u64 = 0;
    let mut dropped: u64 = 0;
    let mut gamma_damped_sum: u64 = 0;
    let mut drops_adaptive: u64 = 0;
    // Adaptive-policy state: the smoothed observed kappa (step damping)
    // and the recent-delay window (quantile drop threshold). Both stay
    // untouched under the all-off defaults.
    let mut kappa = KappaEma::new();
    let mut window = DelayWindowRing::new(DELAY_WINDOW);
    let mut k: u64 = 0;
    loop {
        rng.subset_into(n, tau, &mut blocks);
        let mut used = 0usize;
        for &i in &blocks {
            let delay = dopts.model.sample(&mut rng);
            oracle_calls += 1;
            // The staleness verdict: the k2 arm is the historical call;
            // `quantile:Q` re-centers it by the running-quantile
            // adjustment and charges marginal drops to the policy.
            let accepted = match dopts.adapt.drop {
                DropPolicy::K2 => accept_delay(k, delay),
                DropPolicy::Quantile(q) => {
                    let adj = window.adjustment(q);
                    let v = accept_delay_adjusted(k, delay, adj);
                    if dopts.enforce_drop_rule
                        && !v
                        && accept_delay(k, delay)
                    {
                        drops_adaptive += 1;
                    }
                    window.push(delay);
                    v
                }
            };
            if dopts.enforce_drop_rule && !accepted {
                dropped += 1;
                continue;
            }
            match hist.get(delay) {
                Some(stale) => {
                    problem.oracle_into(stale, i, &mut oscratch, &mut slots[used]);
                    if dopts.adapt.step == StepPolicy::Kappa {
                        // Applied updates feed the EMA *before* this
                        // iteration's gamma — a constant injected delay
                        // yields a constant damping factor from the
                        // very first applied update.
                        kappa.observe(delay);
                    }
                    used += 1;
                }
                None => {
                    // Evicted from history: equivalent to an over-stale
                    // update, dropped by the same rule.
                    dropped += 1;
                }
            }
        }
        if used > 0 {
            let batch = &slots[..used];
            let gamma = match dopts.adapt.step {
                // Pinned default: the historical expression verbatim.
                StepPolicy::Off => schedule_gamma(n, tau, k),
                StepPolicy::Kappa => {
                    let damp =
                        damping_factor(tau as f64, kappa.value());
                    gamma_damped_sum +=
                        ((1.0 - damp) * 1000.0).round() as u64;
                    (schedule_gamma(n, tau, k) as f64 * damp) as f32
                }
            };
            let info = problem.apply(
                &mut state,
                &mut param,
                batch,
                ApplyOptions {
                    gamma,
                    line_search: opts.line_search,
                },
            );
            mon.after_apply(k + 1, &param, &state, info, used);
        }
        k += 1;
        hist.push(k, &param);

        if k % opts.sample_every as u64 == 0
            && mon.sample_and_check(k, oracle_calls, &param, &state)
        {
            break;
        }
        if k % 1024 == 0 {
            let epochs = oracle_calls as f64 / n as f64;
            if opts.stop.exhausted(epochs, mon.watch.elapsed_s()) {
                mon.sample_and_check(k, oracle_calls, &param, &state);
                break;
            }
        }
    }

    let final_param = mon.eval_param(&param).to_vec();
    SolveResult {
        trace: mon.trace,
        param: final_param,
        raw_param: param,
        oracle_calls,
        iterations: k,
        dropped,
        gamma_damped_sum,
        drops_adaptive,
        elapsed_s: mon.watch.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::run::{Engine, RunSpec};
    use crate::util::rng::Pcg64;

    fn gfl_instance() -> Gfl {
        let mut rng = Pcg64::seeded(31);
        let (d, n) = (6, 40);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.2, y)
    }

    fn opts() -> SolveOptions {
        RunSpec::new(Engine::delayed(DelayModel::None))
            .tau(1)
            .sample_every(32)
            .exact_gap(true)
            .eps_gap(0.1)
            .max_epochs(3000.0)
            .max_secs(60.0)
            .seed(3)
            .solve_options()
    }

    #[test]
    fn zero_delay_equals_minibatch_solver_quality() {
        let p = gfl_instance();
        let r = solve(&p, &opts(), &DelayOptions::default());
        assert_eq!(r.dropped, 0);
        assert!(r.trace.last().unwrap().gap <= 0.1);
    }

    #[test]
    fn poisson_delay_still_converges_with_modest_slowdown() {
        // Paper Fig 4: with expected delay up to 20, fewer than 2x as many
        // iterations to reach gap 0.1. Allow 3x margin for our instance.
        let p = gfl_instance();
        let r0 = solve(&p, &opts(), &DelayOptions::default());
        let r = solve(
            &p,
            &opts(),
            &DelayOptions {
                model: DelayModel::Poisson { kappa: 10.0 },
                history: 4096,
                ..Default::default()
            },
        );
        assert!(r.trace.last().unwrap().gap <= 0.1, "did not converge");
        let it0 = r0.iterations as f64;
        let it = r.iterations as f64;
        assert!(it < 3.0 * it0, "delay slowdown too large: {it0} -> {it}");
    }

    #[test]
    fn pareto_delay_converges_and_drops_some() {
        let p = gfl_instance();
        let r = solve(
            &p,
            &opts(),
            &DelayOptions {
                model: DelayModel::pareto_with_mean(10.0),
                history: 4096,
                ..Default::default()
            },
        );
        assert!(r.trace.last().unwrap().gap <= 0.1);
        // heavy tail must trigger at least one early drop
        assert!(r.dropped > 0);
    }

    #[test]
    fn feasibility_under_delay() {
        let p = gfl_instance();
        let r = solve(
            &p,
            &opts(),
            &DelayOptions {
                model: DelayModel::Fixed(5),
                history: 64,
                ..Default::default()
            },
        );
        for t in 0..p.m {
            let nrm = crate::util::la::norm2(
                &r.raw_param[t * p.d..(t + 1) * p.d],
            );
            assert!(nrm <= p.lam + 1e-5);
        }
    }

    #[test]
    fn fixed_delay_kappa_registers_constant_damping() {
        let p = gfl_instance();
        let mk = |step| DelayOptions {
            model: DelayModel::Fixed(3),
            history: 64,
            adapt: crate::sim::adapt::AdaptSpec {
                step,
                ..Default::default()
            },
            ..Default::default()
        };
        let off = solve(&p, &opts(), &mk(crate::sim::adapt::StepPolicy::Off));
        assert_eq!(off.gamma_damped_sum, 0, "off run never damps");
        let mut o = opts();
        o.stop.eps_gap = Some(0.2);
        let on =
            solve(&p, &o, &mk(crate::sim::adapt::StepPolicy::Kappa));
        // Fixed(3) at tau = 1: the EMA is 3 from the first applied
        // update, damp = 1/(1+3) = 0.25, deficit = 750 per apply —
        // constant, so the sum is an exact multiple.
        assert!(on.gamma_damped_sum > 0);
        let applied = on.oracle_calls - on.dropped;
        assert_eq!(on.gamma_damped_sum, 750 * applied);
        assert!(on.trace.last().unwrap().gap <= 0.2);
    }

    #[test]
    fn permissive_quantile_never_charges_adaptive_drops() {
        // q > 0.5 makes the adjustment nonnegative, so the accept set is
        // a superset of k/2's — the marginal-drop counter must stay 0.
        let p = gfl_instance();
        let r = solve(
            &p,
            &opts(),
            &DelayOptions {
                model: DelayModel::pareto_with_mean(10.0),
                history: 4096,
                adapt: crate::sim::adapt::AdaptSpec {
                    drop: crate::sim::adapt::DropPolicy::Quantile(0.9),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        assert_eq!(r.drops_adaptive, 0);
        assert!(r.trace.last().unwrap().gap <= 0.1);
    }

    #[test]
    fn strict_quantile_charges_marginal_drops() {
        let p = gfl_instance();
        let mut o = opts();
        o.stop.eps_gap = None;
        o.stop.max_epochs = 50.0;
        let r = solve(
            &p,
            &o,
            &DelayOptions {
                model: DelayModel::pareto_with_mean(10.0),
                history: 4096,
                adapt: crate::sim::adapt::AdaptSpec {
                    drop: crate::sim::adapt::DropPolicy::Quantile(0.0),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        // The heavy tail spreads the window, the min-quantile pulls the
        // threshold below k/2, and the marginal band gets charged.
        assert!(r.drops_adaptive > 0, "no marginal drops charged");
        assert!(r.dropped >= r.drops_adaptive);
    }

    #[test]
    fn early_iterations_enforce_drop_rule() {
        // With Fixed(4), nothing can be applied before k = 8.
        let p = gfl_instance();
        let mut o = opts();
        o.stop.max_epochs = 1.0;
        let r = solve(
            &p,
            &o,
            &DelayOptions {
                model: DelayModel::Fixed(4),
                history: 64,
                ..Default::default()
            },
        );
        assert!(r.dropped >= 8, "dropped={}", r.dropped);
    }
}
