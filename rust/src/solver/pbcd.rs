//! Parallel block-coordinate (projected gradient) descent baseline — the
//! §D.4 comparator (Richtárik & Takáč 2012 / Liu et al. 2014 style).
//!
//! Each iteration picks tau blocks uniformly and updates
//! `x_i <- proj_{M_i}(x_i - (1/L_i) grad_i f(x))` with all gradients read at
//! the same iterate (synchronous parallel model). Requires
//! [`ProjectableProblem`] (block projections).

use super::{Monitor, SolveOptions, SolveResult};
use crate::problems::{OracleScratch, ProjectableProblem};
use crate::run::Observer;
use crate::util::rng::Pcg64;

/// Run parallel BCD on `problem`.
pub fn solve<P: ProjectableProblem>(
    problem: &P,
    opts: &SolveOptions,
) -> SolveResult {
    solve_observed(problem, opts, &mut ())
}

/// Run parallel BCD, streaming live events to `obs`. PBCD has no
/// Frank-Wolfe step size or surrogate gap, so apply events carry NaN for
/// both.
pub fn solve_observed<P: ProjectableProblem>(
    problem: &P,
    opts: &SolveOptions,
    obs: &mut dyn Observer,
) -> SolveResult {
    let n = problem.num_blocks();
    let tau = opts.tau.clamp(1, n);
    let mut rng = Pcg64::new(opts.seed, 3);
    let mut param = problem.init_param();
    let mut state = problem.init_server();
    let mut mon = Monitor::new(problem, opts, obs);

    // Persistent scratch: index buffer, caller-owned problem scratch,
    // gradient buffer, and one (range, block-iterate) slot per batch
    // position (§Perf: the PBCD loop is allocation-free in steady state).
    let mut blocks: Vec<usize> = Vec::new();
    let mut oscratch = OracleScratch::<P>::default();
    let mut g: Vec<f32> = Vec::new();
    let mut updates: Vec<(std::ops::Range<usize>, Vec<f32>)> =
        (0..tau).map(|_| (0..0, Vec::new())).collect();

    let mut oracle_calls: u64 = 0;
    let mut k: u64 = 0;
    loop {
        rng.subset_into(n, tau, &mut blocks);
        // Compute all block updates at the frozen iterate ...
        for (slot, &i) in updates.iter_mut().zip(blocks.iter()) {
            problem.block_grad_into(&param, i, &mut oscratch, &mut g);
            let li = problem.block_lipschitz(i).max(1e-12);
            let range = problem.block_range(i);
            let (slot_range, xi) = slot;
            *slot_range = range.clone();
            xi.clear();
            xi.extend_from_slice(&param[range]);
            for (x, gv) in xi.iter_mut().zip(g.iter()) {
                *x -= (*gv as f64 / li) as f32;
            }
            problem.project_block(i, xi);
            oracle_calls += 1;
        }
        // ... then apply them (synchronous parallel semantics).
        for (range, xi) in &updates {
            param[range.clone()].copy_from_slice(xi);
        }
        k += 1;
        mon.notify_apply(k, f32::NAN, f64::NAN);
        // No FW gap here; report 0 increment so the estimate stays inf and
        // stopping relies on f_star or budget.
        if k % opts.sample_every as u64 == 0
            && mon.sample_and_check(k, oracle_calls, &param, &state)
        {
            break;
        }
        if k % 1024 == 0 {
            let epochs = oracle_calls as f64 / n as f64;
            if opts.stop.exhausted(epochs, mon.watch.elapsed_s()) {
                mon.sample_and_check(k, oracle_calls, &param, &state);
                break;
            }
        }
    }

    let _ = &mut state;
    SolveResult {
        trace: mon.trace,
        param: param.clone(),
        raw_param: param,
        oracle_calls,
        iterations: k,
        dropped: 0,
        gamma_damped_sum: 0,
        drops_adaptive: 0,
        elapsed_s: mon.watch.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::simplex_qp::SimplexQp;
    use crate::problems::Problem;
    use crate::run::{Engine, RunSpec};
    use crate::solver::SolveOptions;

    fn opts(tau: usize) -> SolveOptions {
        RunSpec::new(Engine::Pbcd)
            .tau(tau)
            .sample_every(32)
            .max_epochs(200.0)
            .max_secs(30.0)
            .seed(4)
            .solve_options()
    }

    #[test]
    fn pbcd_descends_and_stays_feasible() {
        let qp = SimplexQp::random(16, 5, 1.0, 0.3, 4, 5);
        let f0 = qp.objective_of(&qp.init_param());
        let r = solve(&qp, &opts(4));
        let f_end = r.trace.last().unwrap().objective;
        assert!(f_end < f0, "{f0} -> {f_end}");
        for b in 0..qp.n {
            let blk = &r.param[b * qp.m..(b + 1) * qp.m];
            let sum: f64 = blk.iter().map(|&v| v as f64).sum();
            assert!((sum - 1.0).abs() < 1e-4, "block {b} sum {sum}");
            assert!(blk.iter().all(|&v| v >= -1e-6));
        }
    }

    #[test]
    fn pbcd_and_fw_reach_similar_objective_on_easy_qp() {
        let qp = SimplexQp::random(12, 4, 1.0, 0.0, 3, 6);
        let r_bcd = solve(&qp, &opts(3));
        let mut fw_opts = opts(3);
        fw_opts.line_search = true;
        let r_fw = crate::solver::minibatch::solve(&qp, &fw_opts);
        let f_bcd = r_bcd.trace.last().unwrap().objective;
        let f_fw = r_fw.trace.last().unwrap().objective;
        assert!(
            (f_bcd - f_fw).abs() < 0.05 * f_bcd.abs().max(1.0),
            "bcd={f_bcd} fw={f_fw}"
        );
    }
}
