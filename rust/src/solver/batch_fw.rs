//! Classical batch Frank-Wolfe (tau = n): every block updated each
//! iteration with gamma_k = 2/(k+2) or exact line search. The paper's
//! convergence guarantee reduces to this case at tau = n (§2.1).

use super::{schedule_gamma_batch, Monitor, SolveOptions, SolveResult};
use crate::problems::{ApplyOptions, BlockOracle, OracleScratch, Problem};
use crate::run::Observer;

/// Run batch FW on `problem`. `opts.tau` is ignored (always n).
pub fn solve<P: Problem>(problem: &P, opts: &SolveOptions) -> SolveResult {
    solve_observed(problem, opts, &mut ())
}

/// Run batch FW, streaming live events to `obs`.
pub fn solve_observed<P: Problem>(
    problem: &P,
    opts: &SolveOptions,
    obs: &mut dyn Observer,
) -> SolveResult {
    let n = problem.num_blocks();
    let mut param = problem.init_param();
    let mut state = problem.init_server();
    let mut mon = Monitor::new(problem, opts, obs);

    // One persistent oracle slot per block (in the `run.payload`-requested
    // representation) plus the caller-owned oracle scratch, refilled in
    // place (§Perf).
    let pkind = opts.payload.resolve(problem.preferred_payload());
    let mut oscratch = OracleScratch::<P>::default();
    let mut batch: Vec<BlockOracle> =
        (0..n).map(|_| BlockOracle::empty_with(pkind)).collect();

    let mut oracle_calls: u64 = 0;
    let mut k: u64 = 0;
    loop {
        for (i, slot) in batch.iter_mut().enumerate() {
            problem.oracle_into(&param, i, &mut oscratch, slot);
        }
        oracle_calls += n as u64;
        let gamma = schedule_gamma_batch(k);
        let info = problem.apply(
            &mut state,
            &mut param,
            &batch,
            ApplyOptions {
                gamma,
                line_search: opts.line_search,
            },
        );
        k += 1;
        mon.after_apply(k, &param, &state, info, n);
        // Every iteration is one full epoch; always sample.
        if mon.sample_and_check(k, oracle_calls, &param, &state) {
            break;
        }
    }

    let final_param = mon.eval_param(&param).to_vec();
    SolveResult {
        trace: mon.trace,
        param: final_param,
        raw_param: param,
        oracle_calls,
        iterations: k,
        dropped: 0,
        gamma_damped_sum: 0,
        drops_adaptive: 0,
        elapsed_s: mon.watch.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::run::{Engine, RunSpec};
    use crate::util::rng::Pcg64;

    fn gfl_instance() -> Gfl {
        let mut rng = Pcg64::seeded(21);
        let (d, n) = (5, 30);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.15, y)
    }

    #[test]
    fn batch_fw_converges_and_gap_shrinks() {
        let p = gfl_instance();
        let opts = RunSpec::new(Engine::Batch)
            .line_search(true)
            .exact_gap(true)
            .eps_gap(1e-3)
            .max_epochs(4000.0)
            .max_secs(30.0)
            .solve_options();
        let r = solve(&p, &opts);
        let last = r.trace.last().unwrap();
        assert!(last.gap <= 1e-3, "gap={}", last.gap);
        // batch FW: oracle calls = n per iteration
        assert_eq!(r.oracle_calls, r.iterations * p.m as u64);
    }

    #[test]
    fn duality_gap_upper_bounds_suboptimality_along_run() {
        let p = gfl_instance();
        let opts = RunSpec::new(Engine::Batch)
            .line_search(true)
            .exact_gap(true)
            .max_epochs(300.0)
            .max_secs(30.0)
            .solve_options();
        let r = solve(&p, &opts);
        let f_best = r.trace.best_objective();
        for s in &r.trace.samples {
            // g(x) >= f(x) - f* >= f(x) - f_best
            assert!(
                s.gap >= s.objective - f_best - 1e-6,
                "gap {} < subopt {}",
                s.gap,
                s.objective - f_best
            );
        }
    }
}
