//! Sequential solver engines and shared solver machinery.
//!
//! [`minibatch`] is the reference (thread-free) implementation of AP-BCFW's
//! update rule — BCFW at tau = 1 — used by the epoch-counting experiments
//! (Fig 1). [`batch_fw`] is classical Frank-Wolfe (tau = n). [`delayed`]
//! adds the paper's iid-staleness model (Fig 4). [`pbcd`] is the parallel
//! block-coordinate-descent baseline of §D.4.
//!
//! These are the engine implementations behind the unified
//! [`crate::run::Runner`] API — prefer launching them through a
//! [`crate::run::RunSpec`], which lowers to the [`SolveOptions`] consumed
//! here and is the one place `--config`/`--set` layering reaches. Each
//! engine exposes a `solve` entry point plus a `solve_observed` variant
//! that streams live [`crate::run::Observer`] events.

pub mod batch_fw;
pub mod delayed;
pub mod minibatch;
pub mod pbcd;

use crate::problems::{ApplyInfo, Problem};
use crate::run::Observer;
use crate::util::metrics::{Sample, Stopwatch, Trace};

/// The paper's step-size schedule gamma_k = 2 n tau / (tau^2 k + 2 n),
/// clamped to [0, 1]: for tau > 1 the raw formula starts at gamma_0 = tau,
/// which would leave the feasible set — iterates must remain convex
/// combinations of extreme points, so any implementation caps at 1 (the
/// descent lemma only improves for gamma <= 1).
#[inline]
pub fn schedule_gamma(n: usize, tau: usize, k: u64) -> f32 {
    let (n, tau) = (n as f64, tau as f64);
    (2.0 * n * tau / (tau * tau * k as f64 + 2.0 * n)).min(1.0) as f32
}

/// Batch Frank-Wolfe schedule gamma_k = 2/(k+2).
#[inline]
pub fn schedule_gamma_batch(k: u64) -> f32 {
    2.0 / (k as f64 + 2.0) as f32
}

/// Stopping conditions; any satisfied condition stops the solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StopCond {
    /// Known/cached optimal value (enables eps_primal).
    pub f_star: Option<f64>,
    /// Stop when f - f_star <= eps_primal.
    pub eps_primal: Option<f64>,
    /// Stop when the (estimated or exact) surrogate gap <= eps_gap.
    pub eps_gap: Option<f64>,
    /// Hard cap on effective data passes (oracle calls / n).
    pub max_epochs: f64,
    /// Hard wall-clock cap in seconds.
    pub max_secs: f64,
}

impl Default for StopCond {
    fn default() -> Self {
        Self {
            f_star: None,
            eps_primal: None,
            eps_gap: None,
            max_epochs: 100.0,
            max_secs: 600.0,
        }
    }
}

impl StopCond {
    /// Whether a (objective, gap) observation satisfies a target condition.
    pub fn target_met(&self, objective: f64, gap: f64) -> bool {
        if let (Some(fs), Some(eps)) = (self.f_star, self.eps_primal) {
            if objective - fs <= eps {
                return true;
            }
        }
        if let Some(eg) = self.eps_gap {
            if gap <= eg {
                return true;
            }
        }
        false
    }

    /// Whether resource limits are exhausted.
    pub fn exhausted(&self, epochs: f64, secs: f64) -> bool {
        epochs >= self.max_epochs || secs >= self.max_secs
    }
}

/// Options shared by the sequential solvers.
///
/// Production call sites never build this directly: a
/// [`crate::run::RunSpec`] lowers to it via `RunSpec::solve_options`, so
/// every knob stays reachable from config layering. Direct construction is
/// reserved for `rust/src/run/` and the equivalence tests that pin the
/// lowering.
#[derive(Debug, Clone, PartialEq)]
pub struct SolveOptions {
    /// Minibatch size tau.
    pub tau: usize,
    /// Oracle payload representation requested from `oracle_into`
    /// (`run.payload`); `Auto` resolves to the problem's natural
    /// representation and is pinned bit-identical to `Dense` — see the
    /// payload representation contract in [`crate::problems`].
    pub payload: crate::problems::PayloadMode,
    /// Exact coordinate line search instead of the schedule.
    pub line_search: bool,
    /// Weighted iterate averaging x-bar_k (rho_k prop. to k), as used for
    /// the structural SVM experiments.
    pub weighted_averaging: bool,
    /// Record a trace sample every this many server iterations.
    pub sample_every: usize,
    /// Compute the exact duality gap at sample points (otherwise the
    /// unbiased n/tau-scaled batch-gap estimate is recorded).
    pub exact_gap: bool,
    pub stop: StopCond,
    pub seed: u64,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            tau: 1,
            payload: crate::problems::PayloadMode::Auto,
            line_search: false,
            weighted_averaging: false,
            sample_every: 64,
            exact_gap: true,
            stop: StopCond::default(),
            seed: 0,
        }
    }
}

/// Result of a sequential solve.
pub struct SolveResult {
    pub trace: Trace,
    /// Final parameter (the averaged iterate when averaging was on).
    pub param: Vec<f32>,
    /// Final raw (non-averaged) parameter.
    pub raw_param: Vec<f32>,
    pub oracle_calls: u64,
    pub iterations: u64,
    /// Oracle calls whose updates were dropped (delay rule; delayed solver).
    pub dropped: u64,
    /// Accumulated step-damping deficit (parts-per-thousand per apply)
    /// under `run.adapt.step = kappa`; 0 for non-adaptive solves.
    pub gamma_damped_sum: u64,
    /// Drops charged to the `quantile:Q` policy that the plain k/2 rule
    /// would have accepted (delayed solver; 0 under `k2`).
    pub drops_adaptive: u64,
    pub elapsed_s: f64,
}

/// Weighted iterate averaging: x-bar_k = (2/(k(k+1))) sum_{j<=k} j x_j,
/// maintained incrementally (k starts at 1 on the first `update`).
pub struct WeightedAverage {
    pub param: Vec<f32>,
    pub aux: f64,
    k: u64,
}

impl WeightedAverage {
    pub fn new(dim: usize) -> Self {
        Self {
            param: vec![0.0; dim],
            aux: 0.0,
            k: 0,
        }
    }

    /// Fold in the iterate of step k (called once per server iteration).
    pub fn update(&mut self, param: &[f32], aux: f64) {
        self.k += 1;
        let c = 2.0 / (self.k as f64 + 1.0);
        let b = 1.0 - c;
        for (avg, &x) in self.param.iter_mut().zip(param.iter()) {
            *avg = (b * *avg as f64 + c * x as f64) as f32;
        }
        self.aux = b * self.aux + c * aux;
    }
}

/// Internal helper: shared trace/stop bookkeeping across solvers, and the
/// single point that drives the live [`Observer`] stream.
pub(crate) struct Monitor<'a, P: Problem> {
    pub problem: &'a P,
    pub opts: &'a SolveOptions,
    pub watch: Stopwatch,
    pub trace: Trace,
    pub avg: Option<WeightedAverage>,
    /// Most recent unbiased gap estimate (n/tau * batch gap).
    pub gap_estimate: f64,
    pub obs: &'a mut dyn Observer,
}

impl<'a, P: Problem> Monitor<'a, P> {
    pub fn new(
        problem: &'a P,
        opts: &'a SolveOptions,
        obs: &'a mut dyn Observer,
    ) -> Self {
        let avg = if opts.weighted_averaging {
            Some(WeightedAverage::new(problem.param_dim()))
        } else {
            None
        };
        Self {
            problem,
            opts,
            watch: Stopwatch::start(),
            trace: Trace::default(),
            avg,
            gap_estimate: f64::INFINITY,
            obs,
        }
    }

    /// Emit a live apply event without FW bookkeeping (PBCD, whose steps
    /// have no Frank-Wolfe gamma/gap — both are reported as NaN).
    pub fn notify_apply(&mut self, iter: u64, gamma: f32, batch_gap: f64) {
        self.obs.on_apply(iter, gamma, batch_gap);
    }

    /// Fold the iterate into the average, update the gap estimate, and
    /// emit the live apply event. `iter` is the server iteration count
    /// after this apply.
    pub fn after_apply(
        &mut self,
        iter: u64,
        param: &[f32],
        state: &P::ServerState,
        info: ApplyInfo,
        tau: usize,
    ) {
        self.obs.on_apply(iter, info.gamma, info.batch_gap);
        if let Some(avg) = &mut self.avg {
            avg.update(param, self.problem.aux(state));
        }
        let n = self.problem.num_blocks() as f64;
        let inst = info.batch_gap * n / tau.max(1) as f64;
        // Smooth the noisy instantaneous estimate a little.
        self.gap_estimate = if self.gap_estimate.is_finite() {
            0.8 * self.gap_estimate + 0.2 * inst
        } else {
            inst
        };
    }

    /// The parameter whose quality we report (averaged if enabled).
    pub fn eval_param<'b>(&'b self, raw: &'b [f32]) -> &'b [f32] {
        match &self.avg {
            Some(avg) => &avg.param,
            None => raw,
        }
    }

    /// Record a sample; returns true if a stop condition is met.
    pub fn sample_and_check(
        &mut self,
        iter: u64,
        oracle_calls: u64,
        raw_param: &[f32],
        state: &P::ServerState,
    ) -> bool {
        let objective = match &self.avg {
            Some(avg) => self.problem.objective_from(&avg.param, avg.aux),
            None => self.problem.objective(state, raw_param),
        };
        let gap = if self.opts.exact_gap {
            match &self.avg {
                Some(avg) => self.problem.full_gap(state, &avg.param),
                None => self.problem.full_gap(state, raw_param),
            }
        } else {
            self.gap_estimate
        };
        let elapsed_s = self.watch.elapsed_s();
        let sample = Sample {
            iter: iter as usize,
            oracle_calls,
            elapsed_s,
            objective,
            gap,
        };
        self.obs.on_sample(&sample);
        self.trace.push(sample);
        let epochs = oracle_calls as f64 / self.problem.num_blocks() as f64;
        self.opts.stop.target_met(objective, gap)
            || self.opts.stop.exhausted(epochs, elapsed_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_matches_paper_formula() {
        // gamma = 2 n tau / (tau^2 k + 2 n) once below the clamp
        let g = schedule_gamma(100, 4, 100);
        let expect = 2.0 * 100.0 * 4.0 / (16.0 * 100.0 + 200.0);
        assert!((g as f64 - expect).abs() < 1e-6);
        // tau = 1 reduces to BCFW's 2n/(k+2n)
        let g1 = schedule_gamma(50, 1, 7);
        assert!((g1 as f64 - 100.0 / 107.0).abs() < 1e-6);
        // early iterations clamp to 1 (raw formula would be tau at k=0)
        assert_eq!(schedule_gamma(10, 10, 0), 1.0);
        assert_eq!(schedule_gamma(100, 8, 0), 1.0);
    }

    #[test]
    fn schedule_is_decreasing_and_in_unit_interval() {
        let mut prev = f32::INFINITY;
        for k in 0..1000u64 {
            let g = schedule_gamma(200, 8, k);
            assert!(g > 0.0 && g <= 1.0_f32.min(prev));
            prev = g;
        }
    }

    #[test]
    fn weighted_average_formula() {
        // x-bar_k = 2/(k(k+1)) sum j x_j ; with x_j = j: sum j^2 * 2/(k(k+1))
        let mut wa = WeightedAverage::new(1);
        for j in 1..=10u64 {
            wa.update(&[j as f32], j as f64);
        }
        let k = 10.0f64;
        let sum_j2 = (1..=10).map(|j| (j * j) as f64).sum::<f64>();
        let expect = 2.0 / (k * (k + 1.0)) * sum_j2;
        assert!((wa.param[0] as f64 - expect).abs() < 1e-4);
        assert!((wa.aux - expect).abs() < 1e-9);
    }

    #[test]
    fn stop_conditions() {
        let st = StopCond {
            f_star: Some(1.0),
            eps_primal: Some(0.1),
            eps_gap: Some(0.01),
            max_epochs: 5.0,
            max_secs: 10.0,
        };
        assert!(st.target_met(1.05, 1.0)); // primal met
        assert!(st.target_met(2.0, 0.005)); // gap met
        assert!(!st.target_met(2.0, 1.0));
        assert!(st.exhausted(5.0, 0.0));
        assert!(st.exhausted(0.0, 10.0));
        assert!(!st.exhausted(4.9, 9.9));
    }
}
