//! Sequential minibatch BCFW — the reference implementation of AP-BCFW's
//! update rule with no threads and no delay (paper Algorithm 1 semantics,
//! "perfect server"). tau = 1 is exactly BCFW [Lacoste-Julien et al. 2013].
//!
//! Used by the epoch-counting experiments (Fig 1a/1b), where speedup is
//! measured in *epochs to convergence* rather than wall-clock.

use super::{schedule_gamma, Monitor, SolveOptions, SolveResult};
use crate::problems::{ApplyOptions, BlockOracle, OracleScratch, Problem};
use crate::run::Observer;
use crate::util::rng::Pcg64;

/// Run minibatch BCFW on `problem`.
pub fn solve<P: Problem>(problem: &P, opts: &SolveOptions) -> SolveResult {
    solve_observed(problem, opts, &mut ())
}

/// Run minibatch BCFW, streaming live events to `obs`.
pub fn solve_observed<P: Problem>(
    problem: &P,
    opts: &SolveOptions,
    obs: &mut dyn Observer,
) -> SolveResult {
    let n = problem.num_blocks();
    let tau = opts.tau.clamp(1, n);
    let mut rng = Pcg64::new(opts.seed, 1);
    let mut param = problem.init_param();
    let mut state = problem.init_server();
    let mut mon = Monitor::new(problem, opts, obs);

    // Persistent per-iteration scratch: block indices, the caller-owned
    // oracle scratch, and one oracle slot per batch position (in the
    // `run.payload`-requested representation), refilled in place (§Perf:
    // no allocation after the first iteration).
    let pkind = opts.payload.resolve(problem.preferred_payload());
    let mut blocks: Vec<usize> = Vec::new();
    let mut oscratch = OracleScratch::<P>::default();
    let mut batch: Vec<BlockOracle> =
        (0..tau).map(|_| BlockOracle::empty_with(pkind)).collect();

    let mut oracle_calls: u64 = 0;
    let mut k: u64 = 0;
    loop {
        // Uniform size-tau subset of blocks (disjoint by construction, as
        // the perfect server would assemble after collision handling).
        rng.subset_into(n, tau, &mut blocks);
        for (slot, &i) in batch.iter_mut().zip(blocks.iter()) {
            problem.oracle_into(&param, i, &mut oscratch, slot);
        }
        oracle_calls += tau as u64;
        let gamma = schedule_gamma(n, tau, k);
        let info = problem.apply(
            &mut state,
            &mut param,
            &batch,
            ApplyOptions {
                gamma,
                line_search: opts.line_search,
            },
        );
        k += 1;
        mon.after_apply(k, &param, &state, info, tau);

        if k % opts.sample_every as u64 == 0
            && mon.sample_and_check(k, oracle_calls, &param, &state)
        {
            break;
        }
        // Safety: always stop on resource exhaustion even between samples.
        if k % 1024 == 0 {
            let epochs = oracle_calls as f64 / n as f64;
            if opts.stop.exhausted(epochs, mon.watch.elapsed_s()) {
                mon.sample_and_check(k, oracle_calls, &param, &state);
                break;
            }
        }
    }

    let final_param = mon.eval_param(&param).to_vec();
    SolveResult {
        trace: mon.trace,
        param: final_param,
        raw_param: param,
        oracle_calls,
        iterations: k,
        dropped: 0,
        gamma_damped_sum: 0,
        drops_adaptive: 0,
        elapsed_s: mon.watch.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::problems::simplex_qp::SimplexQp;
    use crate::run::{Engine, RunSpec};
    use crate::util::rng::Pcg64;

    fn gfl_instance() -> Gfl {
        let mut rng = Pcg64::seeded(5);
        let (d, n) = (6, 40);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.2, y)
    }

    fn opts(tau: usize, max_epochs: f64) -> SolveOptions {
        RunSpec::new(Engine::Seq)
            .tau(tau)
            .sample_every(16)
            .exact_gap(true)
            .max_epochs(max_epochs)
            .max_secs(30.0)
            .seed(7)
            .solve_options()
    }

    #[test]
    fn bcfw_converges_on_gfl() {
        let p = gfl_instance();
        let r = solve(&p, &opts(1, 200.0));
        let f_end = r.trace.last().unwrap().objective;
        // f(0) = 0; must be well below after 200 epochs
        assert!(f_end < -0.1, "f_end={f_end}");
        let gap = r.trace.last().unwrap().gap;
        assert!(gap >= -1e-8);
        assert!(gap < 1.0, "gap={gap}");
    }

    #[test]
    fn objective_trend_is_decreasing_overall() {
        let p = gfl_instance();
        let r = solve(&p, &opts(4, 100.0));
        let objs: Vec<f64> =
            r.trace.samples.iter().map(|s| s.objective).collect();
        assert!(objs.last().unwrap() < &objs[0]);
        // monotone up to small noise
        for w in objs.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{objs:?}");
        }
    }

    #[test]
    fn larger_tau_converges_in_fewer_iterations_on_incoherent_qp() {
        // mu=0: fully separable, minibatching should give near-linear
        // speedup in iterations (not oracle calls).
        let qp = SimplexQp::random(32, 4, 1.0, 0.0, 3, 2);
        let f1 = solve(&qp, &opts(1, 60.0));
        let f8 = solve(&qp, &opts(8, 60.0));
        let t1 = f1.trace.last().unwrap();
        let t8 = f8.trace.last().unwrap();
        // similar epochs; tau=8 used ~8x fewer server iterations
        assert!(
            (f8.iterations as f64) < 0.25 * f1.iterations as f64,
            "{} vs {}",
            f8.iterations,
            f1.iterations
        );
        // and reached at least comparable objective
        assert!(t8.objective < t1.objective + 0.05);
    }

    #[test]
    fn line_search_at_least_as_good_per_epoch() {
        let p = gfl_instance();
        let mut o1 = opts(2, 30.0);
        let mut o2 = o1.clone();
        o1.line_search = false;
        o2.line_search = true;
        let r_fixed = solve(&p, &o1);
        let r_ls = solve(&p, &o2);
        assert!(
            r_ls.trace.last().unwrap().objective
                <= r_fixed.trace.last().unwrap().objective + 1e-6
        );
    }

    #[test]
    fn weighted_averaging_returns_averaged_param() {
        let p = gfl_instance();
        let mut o = opts(1, 10.0);
        o.weighted_averaging = true;
        let r = solve(&p, &o);
        assert_ne!(r.param, r.raw_param);
        // averaged iterate should be feasible too (convex combination)
        for t in 0..p.m {
            let nrm =
                crate::util::la::norm2(&r.param[t * p.d..(t + 1) * p.d]);
            assert!(nrm <= p.lam + 1e-5);
        }
    }

    #[test]
    fn stops_on_primal_target() {
        let p = gfl_instance();
        // compute a reference optimum first
        let r_ref = solve(&p, &opts(1, 400.0));
        let f_star = r_ref.trace.last().unwrap().objective;
        let mut o = opts(1, 1e9);
        o.stop.f_star = Some(f_star);
        o.stop.eps_primal = Some(0.05);
        o.stop.max_secs = 60.0;
        let r = solve(&p, &o);
        let f_end = r.trace.last().unwrap().objective;
        assert!(f_end - f_star <= 0.06, "didn't stop at target");
    }
}
