//! `apbcfw` launcher: experiments, single solves, artifact checks.

use anyhow::Result;
use apbcfw::cli::{self, Command};
use apbcfw::coordinator::{apbcfw as coord, lockfree, sync, RunConfig};
use apbcfw::data::{mixture, ocr_like, signal};
use apbcfw::problems::gfl::Gfl;
use apbcfw::problems::simplex_qp::SimplexQp;
use apbcfw::problems::ssvm::chain::ChainSsvm;
use apbcfw::problems::ssvm::multiclass::MulticlassSsvm;
use apbcfw::sim::straggler::StragglerModel;
use apbcfw::solver::{minibatch, SolveOptions, StopCond};
use apbcfw::util::config::Config;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = cli::parse(args)?;
    match cli.command {
        Command::Help => {
            println!("{}", cli::USAGE);
            Ok(())
        }
        Command::Info => {
            println!("apbcfw v{}", env!("CARGO_PKG_VERSION"));
            println!("xla available: {}", apbcfw::xla_available());
            println!(
                "available parallelism: {}",
                std::thread::available_parallelism()
                    .map(|v| v.get())
                    .unwrap_or(0)
            );
            Ok(())
        }
        Command::Exp { id } => apbcfw::experiments::run(&id, &cli.config),
        Command::ArtifactsCheck { dir } => artifacts_check(&dir),
        Command::Solve {
            problem,
            mode,
            tau,
            workers,
            epochs,
            line_search,
        } => solve(
            &cli.config,
            &problem,
            &mode,
            tau,
            workers,
            epochs,
            line_search,
        ),
    }
}

fn artifacts_check(dir: &str) -> Result<()> {
    let store = apbcfw::runtime::ArtifactStore::open(dir)?;
    println!("manifest lists {} artifacts:", store.names().len());
    for name in store.names().to_vec() {
        let t0 = std::time::Instant::now();
        store.get(&name)?;
        println!("  {name}  (compiled in {:.2}s)", t0.elapsed().as_secs_f64());
    }
    println!("artifacts OK");
    Ok(())
}

fn summarize(name: &str, trace: &apbcfw::util::metrics::Trace) {
    if let Some(last) = trace.last() {
        println!(
            "[{name}] iters={} oracle_calls={} f={:.6e} gap={:.4e} t={:.2}s",
            last.iter, last.oracle_calls, last.objective, last.gap,
            last.elapsed_s
        );
    }
}

fn solve(
    cfg: &Config,
    problem: &str,
    mode: &str,
    tau: usize,
    workers: usize,
    epochs: f64,
    line_search: bool,
) -> Result<()> {
    let seed = cfg.get_u64("run.seed", 1);
    let stop = StopCond {
        max_epochs: epochs,
        max_secs: cfg.get_f64("run.max_secs", 300.0),
        ..Default::default()
    };
    let sopts = SolveOptions {
        tau,
        line_search,
        sample_every: cfg.get_usize("run.sample_every", 64),
        exact_gap: cfg.get_bool("run.exact_gap", false),
        stop,
        seed,
        ..Default::default()
    };
    let rcfg = RunConfig {
        workers,
        tau,
        line_search,
        straggler: StragglerModel::none(workers),
        sample_every: sopts.sample_every,
        exact_gap: sopts.exact_gap,
        stop,
        seed,
        ..Default::default()
    };

    match problem {
        "gfl" => {
            let d = cfg.get_usize("gfl.d", 10);
            let n = cfg.get_usize("gfl.n", 100);
            let lam = cfg.get_f64("gfl.lambda", 0.01);
            let sig =
                signal::piecewise_constant(d, n, 6, 2.0, 0.5, seed);
            let p = Gfl::new(d, n, lam, sig.noisy.clone());
            match mode {
                "seq" => summarize("gfl/seq", &minibatch::solve(&p, &sopts).trace),
                "async" => summarize("gfl/async", &coord::run(&p, &rcfg).trace),
                "sync" => summarize("gfl/sync", &sync::run(&p, &rcfg).trace),
                "lockfree" => {
                    summarize("gfl/lockfree", &lockfree::run(&p, &rcfg).trace)
                }
                _ => unreachable!(),
            }
        }
        "ssvm" => {
            let n = cfg.get_usize("ssvm.n", 600);
            let k = cfg.get_usize("ssvm.k", 26);
            let d = cfg.get_usize("ssvm.d", 128);
            let ell = cfg.get_usize("ssvm.ell", 9);
            let lam = cfg.get_f64("ssvm.lambda", 1.0);
            let data =
                Arc::new(ocr_like::generate(n, k, d, ell, 0.15, seed));
            let p = ChainSsvm::new(data, lam);
            match mode {
                "seq" => {
                    summarize("ssvm/seq", &minibatch::solve(&p, &sopts).trace)
                }
                "async" => summarize("ssvm/async", &coord::run(&p, &rcfg).trace),
                "sync" => summarize("ssvm/sync", &sync::run(&p, &rcfg).trace),
                "lockfree" => anyhow::bail!(
                    "lockfree mode requires a parameter-space problem (gfl/qp)"
                ),
                _ => unreachable!(),
            }
        }
        "multiclass" => {
            let n = cfg.get_usize("multiclass.n", 800);
            let k = cfg.get_usize("multiclass.k", 10);
            let d = cfg.get_usize("multiclass.d", 64);
            let lam = cfg.get_f64("multiclass.lambda", 0.01);
            let data = Arc::new(mixture::generate(n, k, d, 0.05, seed));
            let p = MulticlassSsvm::new(data, lam);
            match mode {
                "seq" => summarize(
                    "multiclass/seq",
                    &minibatch::solve(&p, &sopts).trace,
                ),
                "async" => {
                    summarize("multiclass/async", &coord::run(&p, &rcfg).trace)
                }
                "sync" => {
                    summarize("multiclass/sync", &sync::run(&p, &rcfg).trace)
                }
                "lockfree" => anyhow::bail!(
                    "lockfree mode requires a parameter-space problem (gfl/qp)"
                ),
                _ => unreachable!(),
            }
        }
        "qp" => {
            let n = cfg.get_usize("qp.n", 64);
            let m = cfg.get_usize("qp.m", 5);
            let mu = cfg.get_f64("qp.mu", 0.1);
            let p = SimplexQp::random(n, m, 1.0, mu, 4, seed);
            match mode {
                "seq" => summarize("qp/seq", &minibatch::solve(&p, &sopts).trace),
                "async" => summarize("qp/async", &coord::run(&p, &rcfg).trace),
                "sync" => summarize("qp/sync", &sync::run(&p, &rcfg).trace),
                "lockfree" => {
                    summarize("qp/lockfree", &lockfree::run(&p, &rcfg).trace)
                }
                _ => unreachable!(),
            }
        }
        _ => unreachable!(),
    }
    Ok(())
}
