//! `apbcfw` launcher: experiments, single solves, artifact checks.
//!
//! Solves go through the unified [`apbcfw::run`] API: the CLI lowers its
//! flags into `run.*` config keys, `RunSpec::from_config` builds the spec,
//! the problem registry builds the instance, and `Runner` dispatches —
//! engine x problem without a hand-written match matrix.

use anyhow::Result;
use apbcfw::cli::{self, Command};
use apbcfw::run::{ProblemInstance, Report, Runner, RunSpec};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = cli::parse(args)?;
    match cli.command {
        Command::Help => {
            println!("{}", cli::USAGE);
            Ok(())
        }
        Command::Info => {
            println!("apbcfw v{}", env!("CARGO_PKG_VERSION"));
            println!("xla available: {}", apbcfw::xla_available());
            println!(
                "available parallelism: {}",
                std::thread::available_parallelism()
                    .map(|v| v.get())
                    .unwrap_or(0)
            );
            println!(
                "engines: {:?}",
                apbcfw::run::ENGINE_NAMES
            );
            println!(
                "problems: {:?}",
                apbcfw::run::PROBLEM_NAMES
            );
            Ok(())
        }
        Command::Exp { id } => apbcfw::experiments::run(&id, &cli.config),
        Command::ArtifactsCheck { dir } => artifacts_check(&dir),
        Command::Solve { problem } => solve(&cli.config, &problem),
        Command::Serve {
            problem,
            addr,
            self_host,
        } => serve(&cli.config, &problem, &addr, self_host),
        Command::Worker {
            addr,
            connect_timeout_secs,
        } => worker(&addr, connect_timeout_secs),
    }
}

fn serve(
    cfg: &apbcfw::util::config::Config,
    problem: &str,
    addr: &str,
    self_host: bool,
) -> Result<()> {
    let spec = RunSpec::from_config(cfg)?;
    let workers = spec.engine.workers();
    let report = if self_host {
        println!(
            "[serve] self-hosted loopback: {workers} worker(s) over {addr}"
        );
        apbcfw::net::solve_loopback(spec, problem, cfg, addr)?
    } else {
        let server = apbcfw::net::BoundServer::bind(spec, problem, cfg, addr)?;
        println!(
            "[serve] listening on {}; waiting for {workers} worker(s) \
             (`apbcfw worker --connect {}`)",
            server.local_addr()?,
            server.local_addr()?
        );
        server.run(&mut ())?
    };
    summarize(&format!("{problem}/{}", report.engine), &report);
    Ok(())
}

fn worker(addr: &str, connect_timeout_secs: f64) -> Result<()> {
    println!("[worker] connecting to {addr}");
    let s = apbcfw::net::run_resilient(
        addr,
        std::time::Duration::from_secs_f64(connect_timeout_secs),
    )?;
    println!(
        "[worker {}] done: {} rounds, {} oracle calls, \
         reconnects={}, tx={} B, rx={} B{}",
        s.worker_id,
        s.rounds,
        s.oracle_calls,
        s.reconnects,
        s.tx_bytes,
        s.rx_bytes,
        if s.clean { "" } else { " (connection lost, not shut down)" }
    );
    Ok(())
}

fn artifacts_check(dir: &str) -> Result<()> {
    let store = apbcfw::runtime::ArtifactStore::open(dir)?;
    println!("manifest lists {} artifacts:", store.names().len());
    for name in store.names().to_vec() {
        let t0 = std::time::Instant::now();
        store.get(&name)?;
        println!("  {name}  (compiled in {:.2}s)", t0.elapsed().as_secs_f64());
    }
    println!("artifacts OK");
    Ok(())
}

fn solve(cfg: &apbcfw::util::config::Config, problem: &str) -> Result<()> {
    let spec = RunSpec::from_config(cfg)?;
    let instance = ProblemInstance::from_config(problem, cfg)?;
    let runner = Runner::new(spec)?;
    let report = runner.solve(&instance)?;
    summarize(&format!("{problem}/{}", report.engine), &report);
    Ok(())
}

fn summarize(name: &str, r: &Report) {
    if let Some(last) = r.last() {
        println!(
            "[{name}] iters={} oracle_calls={} f={:.6e} gap={:.4e} t={:.2}s",
            last.iter, last.oracle_calls, last.objective, last.gap,
            last.elapsed_s
        );
    }
    println!(
        "  applied={} dropped={} collisions={} secs/pass={:.3}",
        r.counters.updates_applied,
        r.counters.dropped,
        r.counters.collisions,
        r.secs_per_pass
    );
    if r.counters.payload_bytes > 0 {
        println!(
            "  payload: {:.1} bytes/update, {:.1} nnz/oracle",
            r.counters.payload_bytes as f64
                / r.counters.updates_applied.max(1) as f64,
            r.counters.payload_nnz as f64
                / r.counters.oracle_calls.max(1) as f64
        );
    }
    if r.counters.wire_tx_bytes + r.counters.wire_rx_bytes > 0 {
        println!(
            "  wire: tx={} B rx={} B ({:.1} rx-bytes/update)",
            r.counters.wire_tx_bytes,
            r.counters.wire_rx_bytes,
            r.counters.wire_rx_bytes as f64
                / r.counters.updates_applied.max(1) as f64,
        );
    }
    // Observed-delay telemetry is stamped by the delayed-update servers
    // (in-process async AND the net transport); engines without it keep
    // the summary short.
    if matches!(r.engine, "async" | "net") {
        println!(
            "  delay: mean {:.2}, max {} (empirical expected-delay kappa)",
            r.counters.mean_delay(),
            r.counters.delay_max
        );
        // Adaptive-control telemetry (run.adapt.*). Always printed for
        // these engines — all-zero under the off/k2 defaults — so CI's
        // adaptive smokes can grep one stable line.
        println!("  {}", r.counters.adapt_summary());
    }
    // Fleet-membership telemetry only the net serve role populates; CI's
    // chaos smokes grep these fields, so keep the format stable.
    if r.engine == "net" {
        println!(
            "  fleet: workers_joined={} workers_lost={} blocks_requeued={} \
             reconnects={} event_stalls={}",
            r.counters.workers_joined,
            r.counters.workers_lost,
            r.counters.blocks_requeued,
            r.counters.reconnects,
            r.counters.event_stalls
        );
        // Crash-recovery telemetry (v5): a separate line so the fleet
        // line above stays byte-stable for the existing CI greps; the
        // crash-recovery smokes grep these fields the same way.
        println!(
            "  recovery: checkpoints_written={} restores={} stale_fenced={}",
            r.counters.checkpoints_written,
            r.counters.restores,
            r.counters.stale_fenced
        );
    }
}
