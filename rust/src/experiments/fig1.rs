//! Figure 1: epoch-speedup from minibatching (paper §3.1).
//!
//! (a) structural SVM on the OCR-like dataset (lambda = 1, line search +
//!     weighted averaging), speedup in epochs-to-threshold relative to
//!     tau = 1 (BCFW), for several primal-suboptimality thresholds.
//! (b) Group Fused Lasso on a synthetic piecewise-constant dataset
//!     (n = 100, d = 10, lambda = 0.01), same measurement.

use super::{print_table, reference_optimum};
use crate::data::{ocr_like, signal};
use crate::problems::gfl::Gfl;
use crate::problems::ssvm::chain::ChainSsvm;
use crate::problems::Problem;
use crate::run::{Engine, Runner, RunSpec};
use crate::solver::StopCond;
use crate::util::config::Config;
use crate::util::csv::CsvWriter;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// Shared sweep logic.
///
/// The paper measures speedup as the reduction in *server iterations*
/// (Algorithm 1 steps, each consuming tau disjoint-block updates) needed to
/// reach a suboptimality threshold, relative to tau = 1: with tau-way
/// parallel oracle solves, each iteration costs one (parallel) oracle
/// round, so perfect speedup equals tau. We sample the trace every
/// iteration (objective evaluation is O(param) for all problems here) so
/// the crossing point is exact.
fn speedup_sweep<P: Problem>(
    problem: &P,
    f_star: f64,
    f0: f64,
    taus: &[usize],
    thresholds: &[f64],
    line_search: bool,
    weighted_averaging: bool,
    max_epochs: f64,
    seed: u64,
    out_csv: &Path,
) -> Result<CsvWriter> {
    let mut w = CsvWriter::to_file(
        out_csv,
        &["tau", "threshold", "iterations", "epochs", "speedup"],
    )?;
    let gap0 = f0 - f_star;
    // iterations(threshold) at the baseline tau (first entry, usually 1).
    let mut base: Vec<Option<f64>> = vec![None; thresholds.len()];
    for &tau in taus {
        let spec = RunSpec::new(Engine::Seq)
            .tau(tau)
            .line_search(line_search)
            .weighted_averaging(weighted_averaging)
            .sample_every(1)
            .stop(StopCond {
                f_star: Some(f_star),
                eps_primal: Some(thresholds.iter().cloned().fold(
                    f64::INFINITY,
                    f64::min,
                ) * gap0),
                max_epochs,
                max_secs: 300.0,
                ..Default::default()
            })
            .seed(seed);
        let r = Runner::new(spec)?.solve_problem(problem)?;
        for (ti, &th) in thresholds.iter().enumerate() {
            let eps = th * gap0;
            let hit = r.trace.first_below(f_star, eps);
            let row = match hit {
                Some(s) => {
                    let iters = s.iter as f64;
                    if tau == taus[0] && base[ti].is_none() {
                        base[ti] = Some(iters);
                    }
                    let sp = base[ti].map(|b| b / iters.max(1e-12));
                    [
                        tau.to_string(),
                        th.to_string(),
                        format!("{iters:.0}"),
                        format!(
                            "{:.2}",
                            s.oracle_calls as f64
                                / problem.num_blocks() as f64
                        ),
                        sp.map(|s| format!("{s:.2}"))
                            .unwrap_or_else(|| "-".into()),
                    ]
                }
                None => [
                    tau.to_string(),
                    th.to_string(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ],
            };
            w.row(&row);
        }
    }
    w.flush()?;
    Ok(w)
}

/// Fig 1(a): structural SVM epoch speedup vs tau.
pub fn fig1a(cfg: &Config, out: &Path) -> Result<()> {
    let n = cfg.get_usize("fig1a.n", 600);
    let k = cfg.get_usize("fig1a.k", 26);
    let d = cfg.get_usize("fig1a.d", 128);
    let ell = cfg.get_usize("fig1a.ell", 9);
    let lam = cfg.get_f64("fig1a.lambda", 1.0);
    let seed = cfg.get_u64("fig1a.seed", 1);
    let taus = cfg.get_usize_list(
        "fig1a.taus",
        &[1, 2, 4, 8, 16, 32, 64, 128],
    );
    let thresholds =
        cfg.get_f64_list("fig1a.thresholds", &[0.1, 0.02, 0.01]);
    let max_epochs = cfg.get_f64("fig1a.max_epochs", 150.0);
    let fstar_epochs = cfg.get_f64("fig1a.fstar_epochs", 400.0);

    let data = Arc::new(ocr_like::generate(n, k, d, ell, 0.15, seed));
    let problem = ChainSsvm::new(data, lam);
    let key = format!("ssvm_n{n}_k{k}_d{d}_l{ell}_lam{lam}_s{seed}");
    let f_star = reference_optimum(&problem, &key, out, fstar_epochs)?;
    let f0 = 0.0; // BCFW init: f(alpha_0) = 0

    let w = speedup_sweep(
        &problem,
        f_star,
        f0,
        &taus,
        &thresholds,
        true,
        true,
        max_epochs,
        seed,
        &out.join("fig1a.csv"),
    )?;
    println!("Fig 1(a): structural SVM epoch speedup vs tau (n={n})");
    print_table(&w);
    Ok(())
}

/// Fig 1(b): Group Fused Lasso epoch speedup vs tau.
pub fn fig1b(cfg: &Config, out: &Path) -> Result<()> {
    let n = cfg.get_usize("fig1b.n", 100);
    let d = cfg.get_usize("fig1b.d", 10);
    let lam = cfg.get_f64("fig1b.lambda", 0.01);
    let segments = cfg.get_usize("fig1b.segments", 6);
    let noise = cfg.get_f64("fig1b.noise", 0.5);
    let seed = cfg.get_u64("fig1b.seed", 2);
    let taus = cfg.get_usize_list(
        "fig1b.taus",
        &[1, 2, 4, 8, 16, 32, 55, 80, 99],
    );
    let thresholds =
        cfg.get_f64_list("fig1b.thresholds", &[0.1, 0.02, 0.01]);
    let max_epochs = cfg.get_f64("fig1b.max_epochs", 2000.0);
    let fstar_epochs = cfg.get_f64("fig1b.fstar_epochs", 8000.0);

    let sig = signal::piecewise_constant(d, n, segments, 2.0, noise, seed);
    let problem = Gfl::new(d, n, lam, sig.noisy.clone());
    let key = format!("gfl_n{n}_d{d}_lam{lam}_s{seed}");
    let f_star = reference_optimum(&problem, &key, out, fstar_epochs)?;
    let f0 = 0.0;

    let line_search = cfg.get_bool("fig1b.line_search", true);
    let w = speedup_sweep(
        &problem,
        f_star,
        f0,
        &taus,
        &thresholds,
        line_search,
        false,
        max_epochs,
        seed,
        &out.join("fig1b.csv"),
    )?;
    println!("Fig 1(b): Group Fused Lasso epoch speedup vs tau (n={n})");
    print_table(&w);
    Ok(())
}
