//! Ablations over the coordinator's design choices (DESIGN.md §6):
//!
//! (a) the k/2 staleness drop rule under heavy-tailed delay — on vs off;
//! (b) collision policy — overwrite-with-fresher (Algorithm 1) vs keep-old;
//! (c) backpressure queue depth (multiples of tau).
//!
//! Each row reports convergence cost under identical budgets, isolating
//! one design decision at a time.

use super::print_table;
use crate::data::signal;
use crate::problems::gfl::Gfl;
use crate::run::{Engine, Runner, RunSpec};
use crate::sim::delay::DelayModel;
use crate::util::config::Config;
use crate::util::csv::CsvWriter;
use anyhow::Result;
use std::path::Path;

pub fn run(cfg: &Config, out: &Path) -> Result<()> {
    let n = cfg.get_usize("ablation.n", 100);
    let d = cfg.get_usize("ablation.d", 10);
    let lam = cfg.get_f64("ablation.lambda", 0.01);
    let seed = cfg.get_u64("ablation.seed", 13);
    let gap_target = cfg.get_f64("ablation.gap_target", 0.1);
    let reps = cfg.get_usize("ablation.reps", 3);

    let sig = signal::piecewise_constant(d, n, 6, 2.0, 0.5, seed);
    let problem = Gfl::new(d, n, lam, sig.noisy.clone());

    let mut w = CsvWriter::to_file(
        &out.join("ablation.csv"),
        &["ablation", "variant", "metric", "value"],
    )?;

    // ---------- (a) staleness drop rule under Pareto delay ----------
    // Heavy-tailed delay, kappa = 15: the rule discards catastrophically
    // stale updates; without it they are applied and slow convergence.
    for enforce in [true, false] {
        let mut calls = 0.0f64;
        let mut failures = 0usize;
        for r in 0..reps {
            let spec = RunSpec::new(
                Engine::delayed(DelayModel::pareto_with_mean(15.0))
                    .with_delay_history(1 << 14)
                    .with_drop_rule(enforce),
            )
            .tau(1)
            .sample_every(32)
            .exact_gap(true)
            .eps_gap(gap_target)
            .max_epochs(5e4)
            .max_secs(60.0)
            .seed(seed + 100 * r as u64);
            let res = Runner::new(spec)?.solve_problem(&problem)?;
            match res.trace.first_gap_below(gap_target) {
                Some(s) => calls += s.oracle_calls as f64,
                None => failures += 1,
            }
        }
        let label = if enforce { "k/2 rule ON" } else { "k/2 rule OFF" };
        let value = if failures > 0 {
            format!("{failures}/{reps} runs failed to converge")
        } else {
            format!("{:.0}", calls / reps as f64)
        };
        w.row(&[
            "drop_rule".into(),
            label.into(),
            "oracle_calls_to_gap".into(),
            value,
        ]);
    }

    // ---------- (b) collision policy ----------
    for overwrite in [true, false] {
        let spec = RunSpec::new(
            Engine::asynchronous(3).with_collision_overwrite(overwrite),
        )
        .tau(8)
        .line_search(true)
        .sample_every(8)
        .exact_gap(true)
        .eps_gap(gap_target)
        .max_epochs(5e4)
        .max_secs(60.0)
        .seed(seed);
        let r = Runner::new(spec)?.solve_problem(&problem)?;
        let label = if overwrite {
            "overwrite (paper)"
        } else {
            "keep-old"
        };
        w.row(&[
            "collision".into(),
            label.into(),
            "iterations_to_gap".into(),
            r.trace
                .first_gap_below(gap_target)
                .map(|s| s.iter.to_string())
                .unwrap_or_else(|| "did not converge".into()),
        ]);
        w.row(&[
            "collision".into(),
            label.into(),
            "collisions".into(),
            r.counters.collisions.to_string(),
        ]);
    }

    // ---------- (c) backpressure queue depth ----------
    for qf in [1usize, 4, 16, 64] {
        let spec =
            RunSpec::new(Engine::asynchronous(3).with_queue_factor(qf))
                .tau(8)
                .line_search(true)
                .sample_every(8)
                .exact_gap(true)
                .eps_gap(gap_target)
                .max_epochs(5e4)
                .max_secs(60.0)
                .seed(seed);
        let r = Runner::new(spec)?.solve_problem(&problem)?;
        w.row(&[
            "queue_depth".into(),
            format!("{qf}x tau"),
            "oracle_calls_to_gap".into(),
            r.trace
                .first_gap_below(gap_target)
                .map(|s| s.oracle_calls.to_string())
                .unwrap_or_else(|| "did not converge".into()),
        ]);
        w.row(&[
            "queue_depth".into(),
            format!("{qf}x tau"),
            "staleness_drops".into(),
            r.counters.dropped.to_string(),
        ]);
    }

    w.flush()?;
    println!("Ablations: coordinator design choices");
    print_table(&w);
    Ok(())
}
