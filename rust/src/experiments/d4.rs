//! §D.4: AP-BCFW vs parallel block-coordinate descent on the simplex QP.
//!
//! The paper's table argues both methods achieve O(n E[L_i] R^2 / (tau k))
//! under mu = O(B/tau); here we measure epochs-to-threshold empirically for
//! both, over a range of tau, on the same instance.

use super::print_table;
use crate::problems::simplex_qp::SimplexQp;
use crate::problems::Problem;
use crate::run::{Engine, Runner, RunSpec};
use crate::solver::StopCond;
use crate::util::config::Config;
use crate::util::csv::CsvWriter;
use anyhow::Result;
use std::path::Path;

pub fn run(cfg: &Config, out: &Path) -> Result<()> {
    let n = cfg.get_usize("d4.n", 64);
    let m = cfg.get_usize("d4.m", 5);
    let b = cfg.get_f64("d4.b", 1.0);
    let mu = cfg.get_f64("d4.mu", 0.05);
    let p = cfg.get_usize("d4.p", 4);
    let seed = cfg.get_u64("d4.seed", 12);
    let taus = cfg.get_usize_list("d4.taus", &[1, 2, 4, 8, 16]);
    let thresh = cfg.get_f64("d4.threshold", 0.02);
    let max_epochs = cfg.get_f64("d4.max_epochs", 3000.0);

    let qp = SimplexQp::random(n, m, b, mu, p, seed);
    // Reference optimum via a long line-search FW run.
    let f_star = {
        let spec = RunSpec::new(Engine::Seq)
            .tau(1)
            .line_search(true)
            .sample_every(256)
            .max_epochs(20_000.0)
            .max_secs(120.0)
            .seed(999);
        Runner::new(spec)?
            .solve_problem(&qp)?
            .trace
            .last()
            .unwrap()
            .objective
    };
    let f0 = qp.objective(&(), &qp.init_param());
    let eps = thresh * (f0 - f_star);

    let mut w = CsvWriter::to_file(
        &out.join("d4.csv"),
        &["tau", "apbcfw_epochs", "pbcd_epochs"],
    )?;
    for &tau in &taus {
        let mk = |engine: Engine, line_search: bool| {
            RunSpec::new(engine)
                .tau(tau)
                .line_search(line_search)
                .sample_every(16)
                .stop(StopCond {
                    f_star: Some(f_star),
                    eps_primal: Some(eps),
                    max_epochs,
                    max_secs: 60.0,
                    ..Default::default()
                })
                .seed(seed)
        };
        let r_fw =
            Runner::new(mk(Engine::Seq, true))?.solve_problem(&qp)?;
        let r_bcd = Runner::new(mk(Engine::Pbcd, false))?
            .solve_projectable(&qp)?;
        let fmt = |e: Option<f64>| {
            e.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into())
        };
        w.row(&[
            tau.to_string(),
            fmt(r_fw.trace.epochs_to(f_star, eps, n)),
            fmt(r_bcd.trace.epochs_to(f_star, eps, n)),
        ]);
    }
    w.flush()?;
    println!(
        "§D.4: epochs to {:.0}% suboptimality — AP-BCFW vs P-BCD (mu={mu})",
        thresh * 100.0
    );
    print_table(&w);
    Ok(())
}
