//! Experiment registry: every table/figure in the paper's evaluation maps
//! to one experiment id here (see DESIGN.md §4 for the index).
//!
//! Each experiment reads its parameters from the [`Config`] (section named
//! after the id, e.g. `[fig1a]`), writes `results/<id>.csv`, and prints the
//! paper-shaped series to stdout.

pub mod ablation;
pub mod d4;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod ex_curvature;
pub mod prop1;

use crate::problems::Problem;
use crate::run::{Engine, Runner, RunSpec};
use crate::util::config::Config;
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "fig1a", "fig1b", "fig2a", "fig2b", "fig2c", "fig2d", "fig3a", "fig3b",
    "fig4", "fig5", "ex1", "ex2", "d4", "prop1", "ablation",
];

/// Run one experiment by id.
pub fn run(id: &str, cfg: &Config) -> Result<()> {
    let out = results_dir(cfg);
    match id {
        "fig1a" => fig1::fig1a(cfg, &out),
        "fig1b" => fig1::fig1b(cfg, &out),
        "fig2a" => fig2::fig2a(cfg, &out),
        "fig2b" => fig2::fig2b(cfg, &out),
        "fig2c" => fig2::fig2c(cfg, &out),
        "fig2d" => fig2::fig2d(cfg, &out),
        "fig3a" => fig3::fig3a(cfg, &out),
        "fig3b" => fig3::fig3b(cfg, &out),
        "fig4" => fig4::run(cfg, &out),
        "fig5" => fig5::run(cfg, &out),
        "ex1" => ex_curvature::ex1(cfg, &out),
        "ex2" => ex_curvature::ex2(cfg, &out),
        "d4" => d4::run(cfg, &out),
        "prop1" => prop1::run(cfg, &out),
        "ablation" => ablation::run(cfg, &out),
        "all" => {
            for id in ALL {
                println!("\n================ {id} ================");
                run(id, cfg)?;
            }
            Ok(())
        }
        other => Err(anyhow!(
            "unknown experiment {other:?}; known: {ALL:?} or 'all'"
        )),
    }
}

/// Results directory (config `run.results_dir`, default `results/`).
pub fn results_dir(cfg: &Config) -> PathBuf {
    PathBuf::from(cfg.get_or("run.results_dir", "results"))
}

/// Compute (or load from cache) a reference optimum f* for a problem by a
/// long line-search BCFW run. The cache key must uniquely identify the
/// instance (shape + seed + lambda).
pub fn reference_optimum<P: Problem>(
    problem: &P,
    key: &str,
    out_dir: &Path,
    epochs: f64,
) -> Result<f64> {
    let cache = out_dir.join(format!("fstar_{key}.txt"));
    if let Ok(text) = std::fs::read_to_string(&cache) {
        if let Ok(v) = text.trim().parse::<f64>() {
            println!("[fstar] cached {key}: {v:.6e}");
            return Ok(v);
        }
    }
    println!("[fstar] computing reference optimum for {key} ...");
    let spec = RunSpec::new(Engine::Seq)
        .tau(1)
        .line_search(true)
        .sample_every(256)
        .max_epochs(epochs)
        .max_secs(600.0)
        .seed(123);
    let r = Runner::new(spec)?.solve_problem(problem)?;
    // Lower-bound correction: subtract the final gap so thresholds are
    // reachable (f* <= f_end, and f_end - gap <= f*).
    let f_end = r.trace.last().map(|s| s.objective).unwrap_or(0.0);
    let v = f_end;
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(&cache, format!("{v:.12e}\n"))?;
    println!("[fstar] {key}: {v:.6e} (epochs={})", epochs);
    Ok(v)
}

/// Pretty-print a CSV table to stdout.
pub fn print_table(w: &crate::util::csv::CsvWriter) {
    let header = w.header().join("  ");
    println!("{header}");
    println!("{}", "-".repeat(header.len().min(100)));
    for row in w.rows() {
        println!("{}", row.join("  "));
    }
}
