//! Figure 4: convergence under unbounded / heavy-tailed delay (paper §3.4).
//!
//! BCFW (tau = 1) on the Group Fused Lasso instance with iid update delays
//! drawn from Poisson(kappa) or Pareto(alpha = 2, x_m = kappa/2) (infinite
//! variance), updates older than k/2 dropped; measures iterations to reach
//! duality gap <= 0.1 as a function of the expected delay kappa.

use super::print_table;
use crate::data::signal;
use crate::problems::gfl::Gfl;
use crate::run::{Engine, Runner, RunSpec};
use crate::sim::delay::DelayModel;
use crate::util::config::Config;
use crate::util::csv::CsvWriter;
use anyhow::Result;
use std::path::Path;

pub fn run(cfg: &Config, out: &Path) -> Result<()> {
    let n = cfg.get_usize("fig4.n", 100);
    let d = cfg.get_usize("fig4.d", 10);
    let lam = cfg.get_f64("fig4.lambda", 0.01);
    let seed = cfg.get_u64("fig4.seed", 7);
    let gap_target = cfg.get_f64("fig4.gap_target", 0.1);
    let kappas =
        cfg.get_f64_list("fig4.kappas", &[0.0, 2.0, 5.0, 10.0, 15.0, 20.0]);
    let reps = cfg.get_usize("fig4.reps", 3);

    let sig = signal::piecewise_constant(d, n, 6, 2.0, 0.5, seed);
    let problem = Gfl::new(d, n, lam, sig.noisy.clone());

    let mut w = CsvWriter::to_file(
        &out.join("fig4.csv"),
        &["distribution", "kappa", "iters_mean", "ratio_vs_zero"],
    )?;

    let solve_one = |model: DelayModel, rep: u64| -> Result<f64> {
        let spec = RunSpec::new(
            Engine::delayed(model).with_delay_history(1 << 14),
        )
        .tau(1)
        .sample_every(32)
        .exact_gap(true)
        .eps_gap(gap_target)
        .max_epochs(1e5)
        .max_secs(120.0)
        .seed(seed + 1000 * rep);
        let r = Runner::new(spec)?.solve_problem(&problem)?;
        Ok(r
            .trace
            .first_gap_below(gap_target)
            .map(|s| s.oracle_calls as f64)
            .unwrap_or(f64::NAN))
    };

    for dist in ["poisson", "pareto"] {
        let mut base: Option<f64> = None;
        for &kappa in &kappas {
            let model = if kappa == 0.0 {
                DelayModel::None
            } else if dist == "poisson" {
                DelayModel::Poisson { kappa }
            } else {
                DelayModel::pareto_with_mean(kappa)
            };
            let mut acc = 0.0f64;
            for r in 0..reps {
                acc += solve_one(model, r as u64)?;
            }
            let mean = acc / reps as f64;
            if base.is_none() {
                base = Some(mean);
            }
            w.row(&[
                dist.to_string(),
                format!("{kappa}"),
                format!("{mean:.0}"),
                format!("{:.2}", mean / base.unwrap()),
            ]);
        }
    }
    w.flush()?;
    println!(
        "Fig 4: iterations to duality gap <= {gap_target} under delay"
    );
    print_table(&w);
    Ok(())
}
