//! Figure 2: shared-memory wall-clock experiments (paper §3.2).
//!
//! (a) primal suboptimality vs wall-clock at T = 8 for several tau.
//! (b) suboptimality vs wall-clock for varying T with the best tau each.
//! (c) speedup vs T (best tau among multiples of T).
//! (d) the same with harder subproblems (m ~ Uniform(5,15) redundant
//!     solves per oracle call).

use super::{print_table, reference_optimum};
use crate::data::ocr_like;
use crate::problems::ssvm::chain::ChainSsvm;
use crate::run::{Engine, Report, Runner, RunSpec};
use crate::solver::StopCond;
use crate::util::config::Config;
use crate::util::csv::CsvWriter;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

struct Fig2Setup {
    problem: ChainSsvm,
    f_star: f64,
    /// Target suboptimality (fraction of the initial gap).
    eps_abs: f64,
    max_secs: f64,
    seed: u64,
}

fn setup(cfg: &Config, section: &str, out: &Path) -> Result<Fig2Setup> {
    let n = cfg.get_usize(&format!("{section}.n"), 800);
    let k = cfg.get_usize(&format!("{section}.k"), 26);
    let d = cfg.get_usize(&format!("{section}.d"), 128);
    let ell = cfg.get_usize(&format!("{section}.ell"), 9);
    let lam = cfg.get_f64(&format!("{section}.lambda"), 1.0);
    let seed = cfg.get_u64(&format!("{section}.seed"), 3);
    let thresh = cfg.get_f64(&format!("{section}.threshold"), 0.05);
    let max_secs = cfg.get_f64(&format!("{section}.max_secs"), 60.0);
    let fstar_epochs =
        cfg.get_f64(&format!("{section}.fstar_epochs"), 300.0);
    let data = Arc::new(ocr_like::generate(n, k, d, ell, 0.15, seed));
    let problem = ChainSsvm::new(data, lam);
    let key = format!("ssvm_n{n}_k{k}_d{d}_l{ell}_lam{lam}_s{seed}");
    let f_star = reference_optimum(&problem, &key, out, fstar_epochs)?;
    let f0 = 0.0;
    Ok(Fig2Setup {
        problem,
        f_star,
        eps_abs: thresh * (f0 - f_star),
        max_secs,
        seed,
    })
}

fn run_async(
    s: &Fig2Setup,
    workers: usize,
    tau: usize,
    work_multiplier: (u32, u32),
) -> Result<Report> {
    let (lo, hi) = work_multiplier;
    let spec = RunSpec::new(
        Engine::asynchronous(workers).with_work_multiplier(lo, hi),
    )
    .tau(tau)
    .line_search(true)
    .sample_every(8)
    .stop(StopCond {
        f_star: Some(s.f_star),
        eps_primal: Some(s.eps_abs),
        max_epochs: 1e9,
        max_secs: s.max_secs,
        ..Default::default()
    })
    .seed(s.seed);
    Runner::new(spec)?.solve_problem(&s.problem)
}

/// Fig 2(a): suboptimality vs wall-clock, T = 8, tau in {1T, 3T, 5T}.
pub fn fig2a(cfg: &Config, out: &Path) -> Result<()> {
    let s = setup(cfg, "fig2a", out)?;
    let t = cfg.get_usize("fig2a.workers", 8);
    let mults = cfg.get_usize_list("fig2a.tau_multiples", &[1, 3, 5]);
    let mut w = CsvWriter::to_file(
        &out.join("fig2a.csv"),
        &["variant", "elapsed_s", "suboptimality"],
    )?;
    for &m in &mults {
        let tau = m * t;
        let r = run_async(&s, t, tau, (1, 1))?;
        for smp in &r.trace.samples {
            w.row(&[
                format!("T{t}_tau{tau}"),
                format!("{:.4}", smp.elapsed_s),
                format!("{:.6e}", smp.objective - s.f_star),
            ]);
        }
    }
    // single-thread BCFW reference
    let r = run_async(&s, 1, 1, (1, 1))?;
    for smp in &r.trace.samples {
        w.row(&[
            "BCFW_T1".into(),
            format!("{:.4}", smp.elapsed_s),
            format!("{:.6e}", smp.objective - s.f_star),
        ]);
    }
    w.flush()?;
    println!("Fig 2(a): suboptimality vs wall-clock (T={t})");
    print_table(&w);
    Ok(())
}

/// Search the best tau (fastest to target) among multiples of T.
fn best_tau(
    s: &Fig2Setup,
    workers: usize,
    mults: &[usize],
    work: (u32, u32),
) -> Result<(usize, f64)> {
    let mut best = (workers, f64::INFINITY);
    for &m in mults {
        let tau = (m * workers).max(1);
        let r = run_async(s, workers, tau, work)?;
        let t = r
            .trace
            .secs_to(s.f_star, s.eps_abs)
            .unwrap_or(f64::INFINITY);
        if t < best.1 {
            best = (tau, t);
        }
    }
    Ok(best)
}

/// Fig 2(b): suboptimality vs wall-clock for varying T (best tau each).
pub fn fig2b(cfg: &Config, out: &Path) -> Result<()> {
    let s = setup(cfg, "fig2b", out)?;
    let ts = cfg.get_usize_list("fig2b.workers", &[1, 2, 4, 8]);
    let mults = cfg.get_usize_list("fig2b.tau_multiples", &[1, 2, 3]);
    let mut w = CsvWriter::to_file(
        &out.join("fig2b.csv"),
        &["T", "best_tau", "elapsed_s", "suboptimality"],
    )?;
    for &t in &ts {
        let (tau, _) = best_tau(&s, t, &mults, (1, 1))?;
        let r = run_async(&s, t, tau, (1, 1))?;
        for smp in &r.trace.samples {
            w.row(&[
                t.to_string(),
                tau.to_string(),
                format!("{:.4}", smp.elapsed_s),
                format!("{:.6e}", smp.objective - s.f_star),
            ]);
        }
    }
    w.flush()?;
    println!("Fig 2(b): suboptimality vs wall-clock, best tau per T");
    print_table(&w);
    Ok(())
}

fn speedup_vs_workers(
    cfg: &Config,
    section: &str,
    out: &Path,
    work: (u32, u32),
) -> Result<()> {
    let s = setup(cfg, section, out)?;
    let ts = cfg
        .get_usize_list(&format!("{section}.workers"), &[1, 2, 4, 8]);
    let mults =
        cfg.get_usize_list(&format!("{section}.tau_multiples"), &[1, 2, 3]);
    let mut w = CsvWriter::to_file(
        &out.join(format!("{section}.csv")),
        &["T", "best_tau", "secs_to_target", "speedup"],
    )?;
    let mut base: Option<f64> = None;
    for &t in &ts {
        let (tau, secs) = best_tau(&s, t, &mults, work)?;
        if base.is_none() {
            base = Some(secs);
        }
        let sp = base.unwrap() / secs.max(1e-12);
        w.row(&[
            t.to_string(),
            tau.to_string(),
            if secs.is_finite() {
                format!("{secs:.3}")
            } else {
                "-".into()
            },
            format!("{sp:.2}"),
        ]);
    }
    w.flush()?;
    println!("{section}: speedup vs workers (work multiplier {work:?})");
    print_table(&w);
    Ok(())
}

/// Fig 2(c): speedup vs T with the best tau per T.
pub fn fig2c(cfg: &Config, out: &Path) -> Result<()> {
    speedup_vs_workers(cfg, "fig2c", out, (1, 1))
}

/// Fig 2(d): speedup vs T with harder subproblems (m ~ Uniform(5, 15)).
pub fn fig2d(cfg: &Config, out: &Path) -> Result<()> {
    speedup_vs_workers(cfg, "fig2d", out, (5, 15))
}
