//! Figure 5 (appendix): Group Fused Lasso signal-recovery illustration —
//! original, noisy and recovered signal series.

use super::print_table;
use crate::data::signal;
use crate::problems::gfl::Gfl;
use crate::run::{Engine, Runner, RunSpec};
use crate::util::config::Config;
use crate::util::csv::CsvWriter;
use anyhow::Result;
use std::path::Path;

pub fn run(cfg: &Config, out: &Path) -> Result<()> {
    let n = cfg.get_usize("fig5.n", 100);
    let d = cfg.get_usize("fig5.d", 10);
    let lam = cfg.get_f64("fig5.lambda", 1.0);
    let segments = cfg.get_usize("fig5.segments", 5);
    let noise = cfg.get_f64("fig5.noise", 0.8);
    let seed = cfg.get_u64("fig5.seed", 8);
    let epochs = cfg.get_f64("fig5.epochs", 3000.0);

    let sig = signal::piecewise_constant(d, n, segments, 3.0, noise, seed);
    let problem = Gfl::new(d, n, lam, sig.noisy.clone());
    let spec = RunSpec::new(Engine::Seq)
        .tau(8)
        .line_search(true)
        .sample_every(64)
        .max_epochs(epochs)
        .max_secs(120.0)
        .seed(seed);
    let r = Runner::new(spec)?.solve_problem(&problem)?;
    let x = problem.primal_signal(&r.raw_param);

    // Per-time-point CSV with the first dimension of each series.
    let mut w = CsvWriter::to_file(
        &out.join("fig5.csv"),
        &["t", "original_dim0", "noisy_dim0", "recovered_dim0"],
    )?;
    for t in 0..n {
        w.row(&[
            t.to_string(),
            format!("{:.4}", sig.clean[t * d]),
            format!("{:.4}", sig.noisy[t * d]),
            format!("{:.4}", x[t * d]),
        ]);
    }
    w.flush()?;

    // Quality summary: recovery MSE must beat the noisy MSE.
    let mse = |a: &[f32]| -> f64 {
        a.iter()
            .zip(&sig.clean)
            .map(|(v, c)| ((v - c) as f64).powi(2))
            .sum::<f64>()
            / (d * n) as f64
    };
    let mse_noisy = mse(&sig.noisy);
    let mse_rec = mse(&x);
    println!("Fig 5: GFL signal recovery (d={d}, n={n}, lambda={lam})");
    println!("  noisy MSE     = {mse_noisy:.4}");
    println!("  recovered MSE = {mse_rec:.4}");
    println!(
        "  (series in results/fig5.csv; denoising factor {:.2}x)",
        mse_noisy / mse_rec.max(1e-12)
    );
    let mut summary = CsvWriter::in_memory(&["metric", "value"]);
    summary.row(&["mse_noisy".into(), format!("{mse_noisy:.5}")]);
    summary.row(&["mse_recovered".into(), format!("{mse_rec:.5}")]);
    print_table(&summary);
    Ok(())
}
