//! Figure 3: asynchronous vs synchronous robustness to stragglers
//! (paper §3.3): average time per effective data pass, AP-BCFW vs SP-BCFW.
//!
//! (a) one straggler with return probability p; x-axis slowdown 1/p.
//! (b) heterogeneous workers p_i = theta + i/T; x-axis 1/theta.

use super::print_table;
use crate::data::ocr_like;
use crate::problems::ssvm::chain::ChainSsvm;
use crate::run::{Engine, Runner, RunSpec, StragglerSpec};
use crate::util::config::Config;
use crate::util::csv::CsvWriter;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

fn problem(cfg: &Config, section: &str) -> ChainSsvm {
    let n = cfg.get_usize(&format!("{section}.n"), 400);
    let k = cfg.get_usize(&format!("{section}.k"), 16);
    let d = cfg.get_usize(&format!("{section}.d"), 64);
    let ell = cfg.get_usize(&format!("{section}.ell"), 7);
    let lam = cfg.get_f64(&format!("{section}.lambda"), 1.0);
    let seed = cfg.get_u64(&format!("{section}.seed"), 4);
    let data = Arc::new(ocr_like::generate(n, k, d, ell, 0.15, seed));
    ChainSsvm::new(data, lam)
}

fn run_pair(
    p: &ChainSsvm,
    workers: usize,
    tau: usize,
    straggler: StragglerSpec,
    passes: f64,
    seed: u64,
) -> Result<(f64, f64)> {
    let mk = |engine: Engine| {
        RunSpec::new(engine)
            .tau(tau)
            .line_search(true)
            .sample_every(64)
            .max_epochs(passes)
            .max_secs(60.0)
            .seed(seed)
    };
    let ra = Runner::new(mk(
        Engine::asynchronous(workers).with_straggler(straggler.clone()),
    ))?
    .solve_problem(p)?;
    let rs = Runner::new(mk(
        Engine::synchronous(workers).with_straggler(straggler),
    ))?
    .solve_problem(p)?;
    Ok((ra.secs_per_pass, rs.secs_per_pass))
}

/// Fig 3(a): one straggler with return probability p.
pub fn fig3a(cfg: &Config, out: &Path) -> Result<()> {
    let p = problem(cfg, "fig3a");
    let workers = cfg.get_usize("fig3a.workers", 14);
    let tau = cfg.get_usize("fig3a.tau", 14);
    let passes = cfg.get_f64("fig3a.passes", 10.0);
    let seed = cfg.get_u64("fig3a.seed", 5);
    let probs =
        cfg.get_f64_list("fig3a.probs", &[1.0, 0.5, 0.25, 0.167, 0.125]);

    let mut w = CsvWriter::to_file(
        &out.join("fig3a.csv"),
        &["slowdown_1_over_p", "async_norm", "sync_norm"],
    )?;
    let mut base: Option<(f64, f64)> = None;
    for &prob in &probs {
        let (a, s) = run_pair(
            &p,
            workers,
            tau,
            StragglerSpec::Single { p: prob },
            passes,
            seed,
        )?;
        if base.is_none() {
            base = Some((a, s));
        }
        let (ba, bs) = base.unwrap();
        w.row(&[
            format!("{:.2}", 1.0 / prob),
            format!("{:.3}", a / ba),
            format!("{:.3}", s / bs),
        ]);
    }
    w.flush()?;
    println!(
        "Fig 3(a): time/effective-pass (normalized) vs straggler slowdown"
    );
    print_table(&w);
    Ok(())
}

/// Fig 3(b): heterogeneous workers p_i = theta + i/T.
pub fn fig3b(cfg: &Config, out: &Path) -> Result<()> {
    let p = problem(cfg, "fig3b");
    let workers = cfg.get_usize("fig3b.workers", 14);
    let tau = cfg.get_usize("fig3b.tau", 14);
    let passes = cfg.get_f64("fig3b.passes", 10.0);
    let seed = cfg.get_u64("fig3b.seed", 6);
    let thetas =
        cfg.get_f64_list("fig3b.thetas", &[1.0, 0.5, 0.33, 0.2, 0.1, 0.0]);

    let mut w = CsvWriter::to_file(
        &out.join("fig3b.csv"),
        &["one_over_theta", "async_norm", "sync_norm"],
    )?;
    let mut base: Option<(f64, f64)> = None;
    for &theta in &thetas {
        let (a, s) = run_pair(
            &p,
            workers,
            tau,
            StragglerSpec::Heterogeneous { theta },
            passes,
            seed,
        )?;
        if base.is_none() {
            base = Some((a, s));
        }
        let (ba, bs) = base.unwrap();
        let x = if theta > 0.0 {
            format!("{:.2}", 1.0 / theta)
        } else {
            "inf".into()
        };
        w.row(&[
            x,
            format!("{:.3}", a / ba),
            format!("{:.3}", s / bs),
        ]);
    }
    w.flush()?;
    println!("Fig 3(b): time/effective-pass vs heterogeneity 1/theta");
    print_table(&w);
    Ok(())
}
