//! Examples 1-3: curvature studies.
//!
//! ex1 — multiclass SSVM with random-sphere classes (paper Example 1): the
//!       rule-of-thumb says minibatching helps up to tau ~ K; we sweep tau
//!       and report epochs-to-threshold plus the analytic bound
//!       C tau/(n^2 lambda).
//! ex2 — expected set curvature C_f^tau: empirical estimates vs the
//!       Theorem-3 bound on (i) the simplex QP with tunable incoherence and
//!       (ii) GFL (Example 2 bound 4 tau lam^2 d).

use super::{print_table, reference_optimum};
use crate::analysis::curvature;
use crate::data::{mixture, signal};
use crate::problems::gfl::Gfl;
use crate::problems::simplex_qp::SimplexQp;
use crate::problems::ssvm::multiclass::MulticlassSsvm;
use crate::run::{Engine, Runner, RunSpec};
use crate::solver::StopCond;
use crate::util::config::Config;
use crate::util::csv::CsvWriter;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// Example 1: multiclass SSVM speedup saturates near tau = K.
pub fn ex1(cfg: &Config, out: &Path) -> Result<()> {
    let n = cfg.get_usize("ex1.n", 800);
    let k = cfg.get_usize("ex1.k", 10);
    let d = cfg.get_usize("ex1.d", 64);
    let lam = cfg.get_f64("ex1.lambda", 0.01);
    let noise = cfg.get_f64("ex1.noise", 0.05);
    let seed = cfg.get_u64("ex1.seed", 10);
    let taus =
        cfg.get_usize_list("ex1.taus", &[1, 2, 5, 10, 20, 40, 80]);
    let thresh = cfg.get_f64("ex1.threshold", 0.05);
    let max_epochs = cfg.get_f64("ex1.max_epochs", 400.0);

    let data = Arc::new(mixture::generate(n, k, d, noise, seed));
    let problem = MulticlassSsvm::new(data, lam);
    let key = format!("mc_n{n}_k{k}_d{d}_lam{lam}_s{seed}");
    let f_star = reference_optimum(&problem, &key, out, 1500.0)?;
    let gap0 = 0.0 - f_star;
    let eps = thresh * gap0;

    let mut w = CsvWriter::to_file(
        &out.join("ex1.csv"),
        &["tau", "epochs", "iter_speedup", "efficiency", "tau_le_K"],
    )?;
    let mut base: Option<f64> = None;
    for &tau in &taus {
        let spec = RunSpec::new(Engine::Seq)
            .tau(tau)
            .line_search(true)
            .sample_every(8.max(64 / tau.max(1)))
            .stop(StopCond {
                f_star: Some(f_star),
                eps_primal: Some(eps),
                max_epochs,
                max_secs: 120.0,
                ..Default::default()
            })
            .seed(seed);
        let r = Runner::new(spec)?.solve_problem(&problem)?;
        let epochs = r.trace.epochs_to(f_star, eps, n);
        // Iteration speedup (consistent with Fig 1): iterations(tau=1) /
        // iterations(tau) = tau * epochs(1)/epochs(tau); efficiency is the
        // fraction of perfect (tau) speedup retained — the paper's
        // rule-of-thumb predicts it stays near 1 while tau <= K.
        let (e_s, sp_s, eff_s) = match epochs {
            Some(e) => {
                if base.is_none() {
                    base = Some(e);
                }
                let sp = tau as f64 * base.unwrap() / e.max(1e-12);
                (
                    format!("{e:.2}"),
                    format!("{sp:.2}"),
                    format!("{:.2}", sp / tau as f64),
                )
            }
            None => ("-".into(), "-".into(), "-".into()),
        };
        w.row(&[
            tau.to_string(),
            e_s,
            sp_s,
            eff_s,
            (tau <= k).to_string(),
        ]);
    }
    w.flush()?;
    println!(
        "Example 1: multiclass SSVM (K={k}) — speedup should saturate near tau=K"
    );
    print_table(&w);
    Ok(())
}

/// Example 2 + Theorem 3: curvature scaling in tau.
pub fn ex2(cfg: &Config, out: &Path) -> Result<()> {
    let seed = cfg.get_u64("ex2.seed", 11);
    let taus = cfg.get_usize_list("ex2.taus", &[1, 2, 4, 8, 16]);
    let subsets = cfg.get_usize("ex2.subsets", 6);
    let samples = cfg.get_usize("ex2.samples", 20);
    let mut rng = Pcg64::seeded(seed);

    let mut w = CsvWriter::to_file(
        &out.join("ex2.csv"),
        &["problem", "tau", "C_tau_estimate", "theorem3_bound"],
    )?;

    // (i) simplex QP: coupled vs separable.
    for (label, mu) in [("qp_mu0", 0.0), ("qp_mu05", 0.5)] {
        let qp = SimplexQp::random(24, 5, 1.0, mu, 4, seed);
        let n = qp.n;
        let b: f64 =
            (0..n).map(|i| qp.boundedness(i)).sum::<f64>() / n as f64;
        let mut mu_acc = 0.0;
        let mut cnt = 0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    mu_acc += qp.incoherence(i, j);
                    cnt += 1;
                }
            }
        }
        let mu_avg = (mu_acc / cnt as f64).max(0.0);
        for &tau in &taus {
            let est = curvature::estimate_expected_curvature(
                &qp, tau, subsets, samples, &mut rng,
            );
            let bound = curvature::theorem3_bound(tau, b, mu_avg);
            w.row(&[
                label.to_string(),
                tau.to_string(),
                format!("{est:.4}"),
                format!("{bound:.4}"),
            ]);
        }
    }

    // (ii) GFL: Example 2's bound 4 tau lam^2 d (linear in tau).
    let (d, n, lam) = (
        cfg.get_usize("ex2.gfl_d", 10),
        cfg.get_usize("ex2.gfl_n", 50),
        cfg.get_f64("ex2.gfl_lambda", 0.5),
    );
    let sig = signal::piecewise_constant(d, n, 5, 2.0, 0.5, seed);
    let gfl = Gfl::new(d, n, lam, sig.noisy.clone());
    for &tau in &taus {
        let est = curvature::estimate_expected_curvature(
            &gfl, tau, subsets, samples, &mut rng,
        );
        let bound = 4.0 * tau as f64 * lam * lam * d as f64;
        w.row(&[
            "gfl".to_string(),
            tau.to_string(),
            format!("{est:.4}"),
            format!("{bound:.4}"),
        ]);
    }
    w.flush()?;
    println!("Example 2 / Theorem 3: C_f^tau estimates vs bounds");
    print_table(&w);
    Ok(())
}
