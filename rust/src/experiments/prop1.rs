//! Proposition 1 (§D.1): collision / coupon-collector accounting for the
//! distributed update scheme — expected oracle calls to fill tau disjoint
//! blocks, and the P(> 2 tau draws) tail bound.

use super::print_table;
use crate::util::config::Config;
use crate::util::csv::CsvWriter;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::path::Path;

/// Expected draws to collect tau distinct of n: tau + sum_{i<tau} i/(n-i).
pub fn expected_draws(n: usize, tau: usize) -> f64 {
    let mut acc = tau as f64;
    for i in 1..tau {
        acc += i as f64 / (n - i) as f64;
    }
    acc
}

/// Simulate the draws needed to see tau distinct blocks of n.
pub fn simulate_draws(n: usize, tau: usize, rng: &mut Pcg64) -> u64 {
    let mut seen = vec![false; n];
    let mut distinct = 0usize;
    let mut draws = 0u64;
    while distinct < tau {
        let i = rng.below(n);
        draws += 1;
        if !seen[i] {
            seen[i] = true;
            distinct += 1;
        }
    }
    draws
}

pub fn run(cfg: &Config, out: &Path) -> Result<()> {
    let n = cfg.get_usize("prop1.n", 1000);
    let taus = cfg.get_usize_list(
        "prop1.taus",
        &[10, 50, 100, 200, 400, 600],
    );
    let reps = cfg.get_usize("prop1.reps", 2000);
    let seed = cfg.get_u64("prop1.seed", 9);

    let mut rng = Pcg64::seeded(seed);
    let mut w = CsvWriter::to_file(
        &out.join("prop1.csv"),
        &["tau", "expected", "simulated_mean", "p_gt_2tau"],
    )?;
    for &tau in &taus {
        let mut acc = 0.0f64;
        let mut tail = 0usize;
        for _ in 0..reps {
            let d = simulate_draws(n, tau, &mut rng);
            acc += d as f64;
            if d > 2 * tau as u64 {
                tail += 1;
            }
        }
        w.row(&[
            tau.to_string(),
            format!("{:.2}", expected_draws(n, tau)),
            format!("{:.2}", acc / reps as f64),
            format!("{:.4}", tail as f64 / reps as f64),
        ]);
    }
    w.flush()?;
    println!("Prop 1 (§D.1): oracle calls per iteration vs tau (n={n})");
    print_table(&w);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formula_matches_simulation() {
        let mut rng = Pcg64::seeded(31);
        for (n, tau) in [(100, 10), (100, 60), (50, 25)] {
            let expect = expected_draws(n, tau);
            let reps = 4000;
            let mean: f64 = (0..reps)
                .map(|_| simulate_draws(n, tau, &mut rng) as f64)
                .sum::<f64>()
                / reps as f64;
            assert!(
                (mean - expect).abs() < 0.05 * expect,
                "n={n} tau={tau}: sim {mean} vs formula {expect}"
            );
        }
    }

    #[test]
    fn tail_bound_regime() {
        // Prop 1(ii): for 0.02n < tau < 0.6n, P(draws > 2 tau) is tiny.
        let mut rng = Pcg64::seeded(32);
        let (n, tau) = (500, 200);
        let reps = 2000;
        let tail = (0..reps)
            .filter(|_| simulate_draws(n, tau, &mut rng) > 2 * tau as u64)
            .count();
        assert!(tail == 0, "tail events: {tail}");
    }

    #[test]
    fn expected_draws_monotone_in_tau() {
        let mut prev = 0.0;
        for tau in [1usize, 10, 100, 500, 900] {
            let e = expected_draws(1000, tau);
            assert!(e > prev);
            assert!(e >= tau as f64);
            prev = e;
        }
    }
}
