//! The `serve` role: the distributed delayed-update server loop over TCP.
//!
//! [`BoundServer`] hosts the same delayed-update semantics as the
//! in-process async engine ([`crate::coordinator::apbcfw`]): workers solve
//! block subproblems against (possibly stale) parameter snapshots, the
//! server assembles tau disjoint blocks across their payloads — reusing
//! the [`BatchAssembler`] collision-overwrite machinery — applies with the
//! paper's step size, and drops anything staler than `k/2` (Theorem 4).
//! What changes is the transport: updates arrive as wire frames from
//! remote workers instead of in-process channel messages, snapshots leave
//! as full vectors or dirty-range deltas, and every update is stamped with
//! its observed delay at apply time (the `delay_sum`/`delay_max` counters
//! backing the expected-delay analysis of the paper's §2.3/§3.4).
//!
//! The loop stays single-threaded over the master parameter; one reader
//! thread per connection decodes frames into the server's event channel,
//! and every write (handshake, snapshots, shutdown) is issued by the loop
//! itself. Per connection the protocol strictly alternates — a worker has
//! at most one request in flight — which is what rules out write-write
//! deadlocks and, at one worker, makes the whole solve deterministic (the
//! loopback equivalence tests pin it bit-identical to the in-process
//! delayed engine).
//!
//! The fleet is **elastic** (protocol v2): the listener stays open for
//! the whole run, so workers can join mid-run (each gets a fresh
//! server-issued id and therefore a fresh block-sampling rng stream) and
//! leave or crash without stalling the solve — a dead connection's
//! in-flight blocks are requeued into the sampling pool (`workers_lost` /
//! `blocks_requeued` telemetry). With `run.liveness_ms` set, a connection
//! silent for that long is declared dead even if the socket never errors
//! (the unplugged-cable case); workers send heartbeats at a third of that
//! window. The loop waits on the earliest of its deadlines (event
//! arrival, accept poll, liveness scan, empty-fleet grace, time budget)
//! instead of busy-polling, and readers feed the bounded event channel
//! with counted backpressure (`event_stalls`) rather than unbounded
//! buffering. All of it is strictly no-op by default: with no joiners, no
//! deaths, no liveness and no chaos, the frames exchanged and the event
//! ordering are exactly those of the fixed-fleet v1 loop.

use super::wire::{self, Hello, Msg, SnapshotBody};
use super::{merge_ranges, payload_mode_tag, NetOptions};
use crate::coordinator::buffer::BatchAssembler;
use crate::coordinator::{RunResult, UpdateMsg};
use crate::problems::{ApplyOptions, Problem};
use crate::run::{
    Engine, Observer, ProblemInstance, Report, Runner, RunSpec, StragglerSpec,
};
use crate::solver::{schedule_gamma, WeightedAverage};
use crate::util::config::Config;
use crate::util::metrics::{Counters, Sample, Stopwatch, Trace};
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How often the server loop polls the (nonblocking) listener for mid-run
/// joiners; also the ceiling on how long an idle loop sleeps between
/// housekeeping passes.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Dirty-range history depth: a worker more than this many versions
/// behind is resynced with a full snapshot instead of a delta.
const DELTA_LOG_CAP: usize = 256;

/// Parameter ranges one apply dirtied; `None` marks a dense
/// whole-parameter write (no delta possible across it).
type DirtyRanges = Option<Vec<std::ops::Range<usize>>>;

/// Events the per-connection reader threads feed the server loop.
enum Event {
    /// A decoded multi-block update payload from connection `conn`.
    Update { conn: usize, msg: UpdateMsg },
    /// A snapshot request from connection `conn` holding `have`.
    SnapReq { conn: usize, have: u64 },
    /// Connection `conn` closed or failed.
    Gone { conn: usize },
}

/// Server-side state of one worker connection. Slots are never removed —
/// a dead connection keeps its index (with `stream` taken) so the `conn`
/// indices carried by reader events stay stable for the whole run.
struct ConnState {
    /// Write half owned by the server loop; `None` once dead.
    stream: Option<TcpStream>,
    /// Server-issued worker id: the rng stream selector and the key under
    /// which the assembler tracks this worker's pending updates.
    worker_id: u32,
    /// Milliseconds since the loop epoch of the last frame this
    /// connection's reader decoded (any frame — heartbeats included).
    last_seen: Arc<AtomicU64>,
    /// Blocks handed out with the last snapshot answer and not yet
    /// returned as an update — requeued if the worker dies mid-round.
    outstanding: usize,
}

/// Declare connection `idx` dead (idempotent): shut the socket down so
/// its reader unblocks, return its in-flight blocks to the sampling pool
/// (the outstanding fan-out round plus anything of its still buffered in
/// the assembler — block sampling is with replacement, so freed blocks
/// are immediately drawable again), and count the loss.
fn kill_conn(
    conns: &mut [ConnState],
    idx: usize,
    alive: &mut usize,
    asm: &mut BatchAssembler,
    counters: &Counters,
) {
    let c = &mut conns[idx];
    if let Some(stream) = c.stream.take() {
        stream.shutdown(std::net::Shutdown::Both).ok();
        *alive -= 1;
        Counters::bump(&counters.workers_lost);
        let requeued =
            c.outstanding + asm.remove_worker(c.worker_id as usize);
        c.outstanding = 0;
        Counters::add(&counters.blocks_requeued, requeued as u64);
    }
}

/// A validated, bound (but not yet running) serve-role instance. Binding
/// is split from running so callers can learn the listen address — port 0
/// resolves to an ephemeral port — before starting workers against it
/// (the loopback self-hosted mode does exactly that).
pub struct BoundServer {
    listener: TcpListener,
    spec: RunSpec,
    instance: ProblemInstance,
    /// Flattened config shipped in the handshake so workers rebuild the
    /// identical problem instance.
    config_pairs: Vec<(String, String)>,
    /// Fleet-management knobs (accept deadline, liveness, chaos) —
    /// validated at bind time, shipped to workers via the handshake.
    opts: NetOptions,
}

impl BoundServer {
    /// Validate `spec` against the serve role and `problem`, and bind the
    /// listen socket. The spec must name the `async` engine (its tau,
    /// staleness-rule, collision and sampling knobs drive the server
    /// loop); the in-process simulation knobs (stragglers, work
    /// multipliers) are rejected — on a real transport the network itself
    /// supplies the delays the paper models.
    pub fn bind(
        spec: RunSpec,
        problem: &str,
        cfg: &Config,
        addr: &str,
    ) -> Result<BoundServer> {
        // Full spec validation (worker counts, cadences, batch scoping).
        let runner = Runner::new(spec.clone())?;
        match &spec.engine {
            Engine::Async {
                straggler,
                work_multiplier,
                ..
            } => {
                ensure!(
                    *straggler == StragglerSpec::None,
                    "run.straggler simulates slow workers in-process; the \
                     network transport gets real stragglers — remove the knob"
                );
                ensure!(
                    *work_multiplier == (1, 1),
                    "run.work_multiplier is an in-process simulation knob; \
                     it does not apply to network workers"
                );
            }
            other => bail!(
                "serve requires the async engine (run.mode=async); engine \
                 `{}` has no delayed-update server loop to host",
                other.name()
            ),
        }
        let instance = ProblemInstance::from_config(problem, cfg)?;
        instance.supports(&spec.engine)?;
        // The same problem-dependent fan-out rule the Runner applies at
        // dispatch (one rule, one implementation).
        runner.check_batch(instance.num_blocks())?;
        // Fail fast on a bad fleet knob — workers would otherwise reject
        // the handshake config one by one.
        let opts = NetOptions::from_config(cfg)?;
        let listener = TcpListener::bind(addr)?;
        let config_pairs = cfg
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        Ok(BoundServer {
            listener,
            spec,
            instance,
            config_pairs,
            opts,
        })
    }

    /// The bound listen address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept the expected worker fleet, run the delayed-update server
    /// loop to completion, and return the unified [`Report`] (engine name
    /// `"net"`). Live events stream to `obs` exactly as for the
    /// in-process engines.
    pub fn run(self, obs: &mut dyn Observer) -> Result<Report> {
        match &self.instance {
            ProblemInstance::Gfl(p) => self.run_inner(p, obs),
            ProblemInstance::Qp(p) => self.run_inner(p, obs),
            ProblemInstance::Chain(p) => self.run_inner(p, obs),
            ProblemInstance::Multiclass(p) => self.run_inner(p, obs),
        }
    }

    /// The handshake frame for worker `worker_id` — identical for the
    /// initial fleet and mid-run joiners.
    fn make_hello(&self, worker_id: u32, n_blocks: usize) -> Msg {
        Msg::Hello(Hello {
            worker_id,
            seed: self.spec.seed,
            tau: self.spec.tau as u32,
            batch: self.spec.batch as u32,
            payload_mode: payload_mode_tag(self.spec.payload),
            n_blocks: n_blocks as u32,
            problem: registry_name(&self.instance).to_string(),
            config: self.config_pairs.clone(),
        })
    }

    /// Accept `workers` connections (within the configurable
    /// `run.accept_timeout_secs` deadline) and complete the handshake on
    /// each in accept order — the accept index is the worker id and rng
    /// stream selector.
    fn accept_fleet<P: Problem>(
        &self,
        problem: &P,
        counters: &Counters,
    ) -> Result<Vec<TcpStream>> {
        let workers = self.spec.engine.workers();
        self.listener.set_nonblocking(true)?;
        let deadline = Instant::now() + self.opts.accept_timeout;
        let mut conns: Vec<TcpStream> = Vec::with_capacity(workers);
        while conns.len() < workers {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_nodelay(true).ok();
                    stream.set_nonblocking(false)?;
                    conns.push(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "timed out waiting for {workers} worker \
                             connections ({} connected)",
                            conns.len()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        let mut ebuf = Vec::new();
        for (id, stream) in conns.iter_mut().enumerate() {
            let hello = self.make_hello(id as u32, problem.num_blocks());
            let n = wire::write_frame(stream, &hello, &mut ebuf)?;
            Counters::add(&counters.wire_tx_bytes, n as u64);
        }
        Ok(conns)
    }

    fn run_inner<P: Problem>(
        &self,
        problem: &P,
        obs: &mut dyn Observer,
    ) -> Result<Report> {
        let spec = &self.spec;
        let (staleness_rule, collision_overwrite, queue_factor) =
            match &spec.engine {
                Engine::Async {
                    staleness_rule,
                    collision_overwrite,
                    queue_factor,
                    ..
                } => (*staleness_rule, *collision_overwrite, *queue_factor),
                _ => unreachable!("bind() accepts only the async engine"),
            };
        let workers = spec.engine.workers();
        let n = problem.num_blocks();
        let tau = spec.tau.clamp(1, n);
        // Blocks a worker owes per answered snapshot — the in-flight
        // round requeued if it dies before the update lands.
        let batch_eff = spec.batch.clamp(1, n);
        let counters = Counters::new();
        // Millisecond origin for the per-connection last-seen stamps.
        let epoch = Instant::now();
        let mut conns: Vec<ConnState> = self
            .accept_fleet(problem, &counters)?
            .into_iter()
            .enumerate()
            .map(|(id, stream)| ConnState {
                stream: Some(stream),
                worker_id: id as u32,
                // Stamped "now", not 0: accepting the fleet may itself
                // take a while, and a worker must get a full liveness
                // window from handshake, not from the epoch.
                last_seen: Arc::new(AtomicU64::new(
                    epoch.elapsed().as_millis() as u64,
                )),
                outstanding: 0,
            })
            .collect();
        // Mid-run joiners get ids above the initial fleet — an id is
        // never recycled, so rng streams and assembler keys stay unique
        // across the whole run.
        let mut next_worker_id = conns.len() as u32;

        let mut master = problem.init_param();
        let mut state = problem.init_server();
        // Instance-level frame validation bound: payload dimensions are
        // block-independent for every registered problem, so one probe
        // oracle fixes the dimension every wire update must carry. The
        // codec checks only a frame's self-consistency; this is what
        // keeps a codec-valid but malformed frame (config drift, hostile
        // peer) out of the apply path.
        let payload_dim = problem.oracle(&master, 0).s.dim();
        let mut trace = Trace::default();
        let mut avg: Option<WeightedAverage> = if spec.weighted_averaging {
            Some(WeightedAverage::new(problem.param_dim()))
        } else {
            None
        };
        let mut gap_estimate = f64::INFINITY;
        let mut k: u64 = 0;
        let mut asm = BatchAssembler::new();
        // Dirty ranges per applied version, newest at the back (`None` =
        // a full-parameter write, e.g. SSVM's dense w update).
        let mut delta_log: VecDeque<(u64, DirtyRanges)> =
            VecDeque::with_capacity(DELTA_LOG_CAP);
        let watch = Stopwatch::start();

        // Each worker has at most one request in flight (the protocol
        // strictly alternates), so 2 slots per worker never blocks a
        // reader; the queue_factor headroom mirrors the in-process
        // engine's backpressure depth.
        let queue_cap = (queue_factor.max(1) * tau).max(2 * workers);
        let (tx, rx) = mpsc::sync_channel::<Event>(queue_cap);
        let mut ebuf: Vec<u8> = Vec::new();

        // Clone the read halves before spawning anything: once a reader
        // thread exists, this function must reach the shutdown sequence
        // (which unblocks readers) before returning, so no fallible work
        // is allowed inside the scope.
        let mut reader_streams: Vec<TcpStream> =
            Vec::with_capacity(conns.len());
        for c in conns.iter() {
            reader_streams.push(
                c.stream
                    .as_ref()
                    .expect("all connections start alive")
                    .try_clone()?,
            );
        }

        std::thread::scope(|scope| {
            // ---------------- connection readers ----------------
            for (conn, reader) in reader_streams.into_iter().enumerate() {
                let tx = tx.clone();
                let counters = &counters;
                let last_seen = Arc::clone(&conns[conn].last_seen);
                scope.spawn(move || {
                    read_loop(conn, reader, tx, counters, last_seen, epoch)
                });
            }
            // `tx` stays alive here: mid-run joiners need fresh clones.

            // ---------------- server loop ----------------
            // One deadline-aware wait per turn: the loop blocks on the
            // event channel until the earliest of (accept poll, liveness
            // scan) is due — no 2 ms busy-spin, yet update ingestion
            // still wakes it immediately.
            let mut alive = conns.len();
            let mut next_accept = Instant::now() + ACCEPT_POLL;
            let liveness_period = self
                .opts
                .liveness
                .map(|d| (d / 4).max(Duration::from_millis(1)));
            let mut next_liveness =
                liveness_period.map(|p| Instant::now() + p);
            // When the whole fleet is gone, wait this grace window (the
            // accept deadline again) for a rejoin before giving up —
            // a crashed-and-restarting worker must not kill the run.
            let mut empty_since: Option<Instant> = None;
            'serve: loop {
                let now = Instant::now();

                // -- accept mid-run joiners (nonblocking poll) --
                if now >= next_accept {
                    next_accept = now + ACCEPT_POLL;
                    while let Ok((stream, _peer)) = self.listener.accept() {
                        stream.set_nodelay(true).ok();
                        if stream.set_nonblocking(false).is_err() {
                            continue;
                        }
                        let mut stream = stream;
                        let worker_id = next_worker_id;
                        let hello = self.make_hello(worker_id, n);
                        // A joiner lost mid-handshake is simply dropped —
                        // nothing fallible may escape this scope.
                        let nb = match wire::write_frame(
                            &mut stream,
                            &hello,
                            &mut ebuf,
                        ) {
                            Ok(nb) => nb,
                            Err(_) => continue,
                        };
                        let reader = match stream.try_clone() {
                            Ok(r) => r,
                            Err(_) => continue,
                        };
                        Counters::add(&counters.wire_tx_bytes, nb as u64);
                        next_worker_id += 1;
                        let last_seen = Arc::new(AtomicU64::new(
                            epoch.elapsed().as_millis() as u64,
                        ));
                        let conn = conns.len();
                        conns.push(ConnState {
                            stream: Some(stream),
                            worker_id,
                            last_seen: Arc::clone(&last_seen),
                            outstanding: 0,
                        });
                        let tx = tx.clone();
                        let counters = &counters;
                        scope.spawn(move || {
                            read_loop(
                                conn, reader, tx, counters, last_seen, epoch,
                            )
                        });
                        alive += 1;
                        empty_since = None;
                        Counters::bump(&counters.workers_joined);
                    }
                }

                // -- liveness scan: reap silent connections --
                if let (Some(window), Some(period)) =
                    (self.opts.liveness, liveness_period)
                {
                    if next_liveness.is_some_and(|t| now >= t) {
                        next_liveness = Some(now + period);
                        let now_ms = epoch.elapsed().as_millis() as u64;
                        let cutoff = window.as_millis() as u64;
                        for i in 0..conns.len() {
                            let silent_ms = now_ms.saturating_sub(
                                conns[i].last_seen.load(Ordering::Relaxed),
                            );
                            if conns[i].stream.is_some() && silent_ms > cutoff
                            {
                                kill_conn(
                                    &mut conns, i, &mut alive, &mut asm,
                                    &counters,
                                );
                            }
                        }
                    }
                }

                // -- empty-fleet grace --
                if alive == 0 {
                    match empty_since {
                        None => empty_since = Some(now),
                        Some(t0)
                            if now.duration_since(t0)
                                >= self.opts.accept_timeout =>
                        {
                            break 'serve;
                        }
                        Some(_) => {}
                    }
                } else {
                    empty_since = None;
                }

                // -- deadline-aware event wait --
                let mut deadline = next_accept;
                if let Some(t) = next_liveness {
                    deadline = deadline.min(t);
                }
                let wait =
                    deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(wait) {
                    Ok(Event::Update { conn, msg }) => {
                        // Reject oracles the instance cannot apply (block
                        // out of range, payload of the wrong dimension)
                        // and kill the connection — a protocol violation,
                        // not a recoverable update. The later `Gone` from
                        // its reader is then a no-op.
                        let valid = msg.oracles.iter().all(|o| {
                            o.block < n && o.s.dim() == payload_dim
                        });
                        if !valid {
                            kill_conn(
                                &mut conns, conn, &mut alive, &mut asm,
                                &counters,
                            );
                            continue;
                        }
                        // The outstanding fan-out round came back.
                        conns[conn].outstanding = 0;
                        let (mut nnz, mut bytes) = (0u64, 0u64);
                        for o in &msg.oracles {
                            nnz += o.s.nnz() as u64;
                            bytes += o.s.wire_bytes() as u64;
                        }
                        Counters::add(&counters.payload_nnz, nnz);
                        Counters::add(&counters.payload_bytes, bytes);
                        Counters::add(
                            &counters.oracle_calls,
                            msg.oracles.len() as u64,
                        );
                        // Staleness rule (paper Thm 4): drop if the whole
                        // payload's snapshot is older than k/2.
                        let delay = k.saturating_sub(msg.k_read);
                        if staleness_rule && 2 * delay > k && delay > 0 {
                            Counters::add(
                                &counters.dropped,
                                msg.oracles.len() as u64,
                            );
                        } else if collision_overwrite {
                            asm.insert(msg);
                        } else {
                            asm.insert_keep_old(msg);
                        }
                    }
                    Ok(Event::SnapReq { conn, have }) => {
                        let body =
                            snapshot_body(&master, &delta_log, k, have);
                        let msg = Msg::Snapshot { version: k, body };
                        let sent = match &mut conns[conn].stream {
                            Some(stream) => {
                                wire::write_frame(stream, &msg, &mut ebuf)
                            }
                            None => continue, // already declared dead
                        };
                        match sent {
                            Ok(nb) => {
                                Counters::add(
                                    &counters.wire_tx_bytes,
                                    nb as u64,
                                );
                                // The worker now owes one fan-out round.
                                conns[conn].outstanding = batch_eff;
                            }
                            // kill_conn shuts the socket down before
                            // dropping our clone: the reader thread holds
                            // its own dup and would otherwise block in
                            // read forever (scope would never join).
                            Err(_) => kill_conn(
                                &mut conns, conn, &mut alive, &mut asm,
                                &counters,
                            ),
                        }
                    }
                    Ok(Event::Gone { conn }) => {
                        kill_conn(
                            &mut conns, conn, &mut alive, &mut asm,
                            &counters,
                        );
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break 'serve,
                }

                while let Some(batch_msgs) = asm.take_batch(tau) {
                    // Observed delay of every applied update, stamped at
                    // apply time — the expected-delay telemetry.
                    for m in &batch_msgs {
                        let d = m.delay(k);
                        Counters::add(&counters.delay_sum, d);
                        Counters::max_of(&counters.delay_max, d);
                    }
                    let batch: Vec<_> =
                        batch_msgs.into_iter().map(|m| m.oracle).collect();
                    let applied = batch.len();
                    let gamma = schedule_gamma(n, applied, k);
                    let info = problem.apply(
                        &mut state,
                        &mut master,
                        &batch,
                        ApplyOptions {
                            gamma,
                            line_search: spec.line_search,
                        },
                    );
                    k += 1;
                    if delta_log.len() == DELTA_LOG_CAP {
                        delta_log.pop_front();
                    }
                    delta_log.push_back((k, problem.touched_ranges(&batch)));
                    Counters::add(&counters.updates_applied, applied as u64);
                    counters
                        .iterations
                        .store(k, std::sync::atomic::Ordering::Relaxed);
                    obs.on_apply(k, info.gamma, info.batch_gap);
                    if let Some(a) = &mut avg {
                        a.update(&master, problem.aux(&state));
                    }
                    let inst = info.batch_gap * n as f64 / applied as f64;
                    gap_estimate = if gap_estimate.is_finite() {
                        0.8 * gap_estimate + 0.2 * inst
                    } else {
                        inst
                    };

                    if k % spec.sample_every as u64 == 0 {
                        let objective = match &avg {
                            Some(a) => problem.objective_from(&a.param, a.aux),
                            None => problem.objective(&state, &master),
                        };
                        let gap = if spec.exact_gap {
                            match &avg {
                                Some(a) => problem.full_gap(&state, &a.param),
                                None => problem.full_gap(&state, &master),
                            }
                        } else {
                            gap_estimate
                        };
                        let snap = counters.snapshot();
                        let sample = Sample {
                            iter: k as usize,
                            oracle_calls: snap.oracle_calls,
                            elapsed_s: watch.elapsed_s(),
                            objective,
                            gap,
                        };
                        obs.on_sample(&sample);
                        trace.push(sample);
                        let epochs = snap.oracle_calls as f64 / n as f64;
                        if spec.stop.target_met(objective, gap)
                            || spec.stop.exhausted(epochs, watch.elapsed_s())
                        {
                            break 'serve;
                        }
                    }
                }

                // Budget check even while starved of updates.
                let snap = counters.snapshot();
                let epochs = snap.oracle_calls as f64 / n as f64;
                if spec.stop.exhausted(epochs, watch.elapsed_s()) {
                    break 'serve;
                }
            }

            // Orderly shutdown: tell every live worker, then close both
            // socket halves so blocked reader threads unblock and exit.
            for stream in conns.iter_mut().filter_map(|c| c.stream.as_mut())
            {
                if let Ok(nb) =
                    wire::write_frame(stream, &Msg::Shutdown, &mut ebuf)
                {
                    Counters::add(&counters.wire_tx_bytes, nb as u64);
                }
                stream.shutdown(std::net::Shutdown::Both).ok();
            }
            // Dropping the receiver errors out any reader still sending,
            // so blocked backpressure sends cannot outlive the loop.
            drop(tx);
            drop(rx);
        });

        Counters::add(&counters.collisions, asm.collisions());
        let mut snap = counters.snapshot();
        snap.iterations = k;
        let elapsed_s = watch.elapsed_s();
        let passes = snap.updates_applied as f64 / n as f64;
        let secs_per_pass = if passes > 0.0 {
            elapsed_s / passes
        } else {
            f64::INFINITY
        };
        let objective = match &avg {
            Some(a) => problem.objective_from(&a.param, a.aux),
            None => problem.objective(&state, &master),
        };
        let gap = if spec.exact_gap {
            match &avg {
                Some(a) => problem.full_gap(&state, &a.param),
                None => problem.full_gap(&state, &master),
            }
        } else {
            gap_estimate
        };
        let sample = Sample {
            iter: k as usize,
            oracle_calls: snap.oracle_calls,
            elapsed_s,
            objective,
            gap,
        };
        obs.on_sample(&sample);
        trace.push(sample);
        let (param, raw_param) = match avg {
            Some(a) => (a.param, master),
            None => {
                let raw = master.clone();
                (master, raw)
            }
        };
        Ok(Report::from_run(
            "net",
            RunResult {
                trace,
                param,
                raw_param,
                counters: snap,
                elapsed_s,
                secs_per_pass,
            },
        ))
    }
}

/// Decode frames off one connection into the server's event channel,
/// stamping `last_seen` (ms since `epoch`) on every decoded frame.
/// Heartbeats and join announcements are absorbed right here — they
/// refresh liveness (and the `reconnects` counter) without ever entering
/// the loop's event ordering, which is part of what keeps the fixed-fleet
/// path bit-identical to v1. Exits on any read error, a clean close, a
/// protocol violation, or a hung-up server loop — always announcing
/// `Gone` (best-effort) first.
///
/// Backpressure: a full event channel is counted (`event_stalls`, logged
/// on first occurrence) and then waited out with a blocking send — a slow
/// consumer stalls readers instead of growing an unbounded buffer, and
/// nothing panics.
fn read_loop(
    conn: usize,
    mut stream: TcpStream,
    tx: mpsc::SyncSender<Event>,
    counters: &Counters,
    last_seen: Arc<AtomicU64>,
    epoch: Instant,
) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some((msg, nbytes))) => {
                Counters::add(&counters.wire_rx_bytes, nbytes as u64);
                last_seen.store(
                    epoch.elapsed().as_millis() as u64,
                    Ordering::Relaxed,
                );
                let event = match msg {
                    Msg::Update {
                        k_read,
                        worker,
                        oracles,
                    } => Event::Update {
                        conn,
                        msg: UpdateMsg {
                            oracles,
                            k_read,
                            worker: worker as usize,
                        },
                    },
                    Msg::SnapshotRequest { have_version } => Event::SnapReq {
                        conn,
                        have: have_version,
                    },
                    Msg::Heartbeat => continue,
                    Msg::Join { resumed } => {
                        if resumed {
                            Counters::bump(&counters.reconnects);
                        }
                        continue;
                    }
                    // Anything else from a worker is a protocol violation;
                    // drop the connection.
                    _ => break,
                };
                match tx.try_send(event) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(event)) => {
                        if counters
                            .event_stalls
                            .fetch_add(1, Ordering::Relaxed)
                            == 0
                        {
                            eprintln!(
                                "[serve] event channel full; reader {conn} \
                                 applying backpressure"
                            );
                        }
                        if tx.send(event).is_err() {
                            return; // server loop is gone
                        }
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => return,
                }
            }
            Ok(None) | Err(_) => break,
        }
    }
    tx.send(Event::Gone { conn }).ok();
}

/// Build the snapshot body for a worker holding `have`: an empty delta if
/// it is current, a dirty-range delta when the log covers the gap (and it
/// is actually smaller than the full vector), a full snapshot otherwise.
fn snapshot_body(
    master: &[f32],
    log: &VecDeque<(u64, DirtyRanges)>,
    k: u64,
    have: u64,
) -> SnapshotBody {
    if have == k {
        return SnapshotBody::Delta(Vec::new());
    }
    if have > k {
        // `u64::MAX` sentinel (nothing held) or a confused peer: resync.
        return SnapshotBody::Full(master.to_vec());
    }
    let covered = log
        .front()
        .map(|(oldest, _)| *oldest <= have + 1)
        .unwrap_or(false);
    if covered {
        let mut ranges: Vec<std::ops::Range<usize>> = Vec::new();
        let mut full = false;
        for (v, r) in log.iter() {
            if *v <= have {
                continue;
            }
            match r {
                Some(rs) => ranges.extend(rs.iter().cloned()),
                None => {
                    full = true;
                    break;
                }
            }
        }
        if !full {
            let merged = merge_ranges(ranges);
            let total: usize = merged.iter().map(|r| r.len()).sum();
            if total < master.len() {
                let runs = merged
                    .iter()
                    .map(|r| (r.start as u32, master[r.clone()].to_vec()))
                    .collect();
                return SnapshotBody::Delta(runs);
            }
        }
    }
    SnapshotBody::Full(master.to_vec())
}

/// The registry name a worker passes back to
/// [`ProblemInstance::from_config`] (the CLI `solve` vocabulary, not the
/// inner problem's display name).
fn registry_name(instance: &ProblemInstance) -> &'static str {
    match instance {
        ProblemInstance::Gfl(_) => "gfl",
        ProblemInstance::Qp(_) => "qp",
        ProblemInstance::Chain(_) => "ssvm",
        ProblemInstance::Multiclass(_) => "multiclass",
    }
}

/// Bind on `addr`, accept the spec's worker fleet, and run the solve to
/// completion — the CLI `apbcfw serve` entry point.
pub fn serve(
    spec: RunSpec,
    problem: &str,
    cfg: &Config,
    addr: &str,
    obs: &mut dyn Observer,
) -> Result<Report> {
    BoundServer::bind(spec, problem, cfg, addr)?.run(obs)
}

/// Self-hosted loopback mode: bind on `addr` (use port 0 for an ephemeral
/// port), spawn the spec's worker fleet as in-process threads that connect
/// back over real TCP (127.0.0.1), and run the solve — one process, but
/// every oracle payload crosses the wire codec. This is the mode the
/// distributed==in-process equivalence tests pin.
pub fn solve_loopback(
    spec: RunSpec,
    problem: &str,
    cfg: &Config,
    addr: &str,
) -> Result<Report> {
    let workers = spec.engine.workers();
    let server = BoundServer::bind(spec, problem, cfg, addr)?;
    let bound = server.local_addr()?;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            // Resilient workers: under `run.chaos` an injected disconnect
            // mid-run is survived by reconnecting (the server's listener
            // stays open for joiners); once the run ends and the listener
            // drops, a reconnect attempt is refused and the worker exits
            // with its summed summary. Without chaos this is exactly the
            // single-session worker.
            handles.push(scope.spawn(move || {
                super::worker::run_resilient(
                    &bound.to_string(),
                    Duration::from_secs(10),
                )
            }));
        }
        let report = server.run(&mut ())?;
        for h in handles {
            h.join()
                .map_err(|_| anyhow!("loopback worker thread panicked"))??;
        }
        Ok(report)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        Config::parse("[gfl]\nd = 4\nn = 20\n").unwrap()
    }

    #[test]
    fn bind_rejects_non_async_engines() {
        let spec = RunSpec::new(Engine::sequential());
        let err = BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("async"), "{err}");
    }

    #[test]
    fn bind_rejects_simulation_knobs() {
        let spec = RunSpec::new(
            Engine::asynchronous(1)
                .with_straggler(StragglerSpec::Single { p: 0.5 }),
        );
        let err = BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("straggler"), "{err}");
        let spec =
            RunSpec::new(Engine::asynchronous(1).with_work_multiplier(2, 5));
        let err = BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("work_multiplier"), "{err}");
    }

    #[test]
    fn bind_rejects_oversized_fanout() {
        // gfl d=4 n=20 -> 19 blocks; 8 x 4 > 19.
        let spec = RunSpec::new(Engine::asynchronous(4)).batch(8);
        let err = BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn bind_rejects_bad_fleet_knobs() {
        for (key, bad, needle) in [
            ("run.chaos", "bogus", "run.chaos"),
            ("run.liveness_ms", "soon", "liveness"),
            ("run.accept_timeout_secs", "0", "accept_timeout"),
        ] {
            let mut c = cfg();
            c.set(key, bad);
            let spec = RunSpec::new(Engine::asynchronous(1));
            let err = BoundServer::bind(spec, "gfl", &c, "127.0.0.1:0")
                .map(|_| ())
                .unwrap_err()
                .to_string();
            assert!(err.contains(needle), "{key}={bad}: {err}");
        }
    }

    #[test]
    fn bind_resolves_ephemeral_port() {
        let spec = RunSpec::new(Engine::asynchronous(1));
        let server =
            BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0").unwrap();
        assert_ne!(server.local_addr().unwrap().port(), 0);
    }

    #[test]
    fn snapshot_body_selects_delta_vs_full() {
        let master: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let mut log = VecDeque::new();
        log.push_back((1u64, Some(vec![0..2usize])));
        log.push_back((2u64, Some(vec![4..6usize])));
        // Current worker: empty delta.
        assert_eq!(
            snapshot_body(&master, &log, 2, 2),
            SnapshotBody::Delta(Vec::new())
        );
        // One behind: only version 2's ranges.
        assert_eq!(
            snapshot_body(&master, &log, 2, 1),
            SnapshotBody::Delta(vec![(4, vec![4.0, 5.0])])
        );
        // Two behind: both versions' ranges.
        assert_eq!(
            snapshot_body(&master, &log, 2, 0),
            SnapshotBody::Delta(vec![
                (0, vec![0.0, 1.0]),
                (4, vec![4.0, 5.0])
            ])
        );
        // Sentinel / uncovered: full.
        assert_eq!(
            snapshot_body(&master, &log, 2, u64::MAX),
            SnapshotBody::Full(master.clone())
        );
        log.push_back((3u64, None)); // dense write
        assert_eq!(
            snapshot_body(&master, &log, 3, 2),
            SnapshotBody::Full(master.clone())
        );
    }
}
